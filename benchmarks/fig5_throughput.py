"""Figure 5: training speed-up of Terra co-execution (and the full-jit
AutoGraph analogue, where it works) relative to imperative execution, plus
the Appendix-F phase-transition counters."""

from __future__ import annotations

import time

import numpy as np

from benchmarks.programs import NON_CONVERTIBLE, REGISTRY
from repro.core import function as terra_function, imperative


def time_variant(name: str, variant: str, warmup: int = 12,
                 measure: int = 40):
    step, _ = REGISTRY[name](variant)
    stats = {}
    if variant == "terra":
        tf = terra_function(step)
        for i in range(warmup):
            tf(i)
        tf.wait()
        t0 = time.perf_counter()
        for i in range(warmup, warmup + measure):
            tf(i)
        tf.wait()
        dt = time.perf_counter() - t0
        stats = dict(tf.stats)
        stats["phase"] = tf.phase
        tf.close()
    elif variant == "imperative":
        with imperative() as imp:
            for i in range(warmup):
                step(i)
                imp.step()
            t0 = time.perf_counter()
            for i in range(warmup, warmup + measure):
                step(i)
                imp.step()
            dt = time.perf_counter() - t0
    else:  # fulljit
        for i in range(warmup):
            step(i)
        t0 = time.perf_counter()
        for i in range(warmup, warmup + measure):
            step(i)
        dt = time.perf_counter() - t0
    return dt / measure, stats


def main():
    print("program,imperative_us,terra_us,fulljit_us,"
          "terra_speedup,fulljit_speedup,traced_iters,transitions,replays")
    rows = []
    for name in sorted(REGISTRY):
        imp_t, _ = time_variant(name, "imperative")
        terra_t, st = time_variant(name, "terra")
        if name in NON_CONVERTIBLE:
            fj_t = float("nan")
        else:
            try:
                fj_t, _ = time_variant(name, "fulljit")
            except Exception:  # noqa: BLE001
                fj_t = float("nan")
        row = (name, imp_t * 1e6, terra_t * 1e6, fj_t * 1e6,
               imp_t / terra_t,
               imp_t / fj_t if np.isfinite(fj_t) else float("nan"),
               st.get("traced_iterations", 0), st.get("transitions", 0),
               st.get("replays", 0))
        rows.append(row)
        print(f"{name},{row[1]:.0f},{row[2]:.0f},{row[3]:.0f},"
              f"{row[4]:.2f},{row[5]:.2f},{row[6]},{row[7]},{row[8]}")
    sp = [r[4] for r in rows]
    print(f"# terra speedup over imperative: min {min(sp):.2f}x, "
          f"max {max(sp):.2f}x, mean {np.mean(sp):.2f}x "
          f"(paper: up to 1.73x with XLA)")
    return rows


if __name__ == "__main__":
    main()
