"""Table 1: imperative-program coverage — Terra runs all ten programs; the
whole-program-jit (AutoGraph analogue) fails five of them, for the same
reasons as the paper's Table 1."""

from __future__ import annotations

import numpy as np

from benchmarks.programs import NON_CONVERTIBLE, REGISTRY
from repro.core import function as terra_function


def classify_fulljit(name: str, steps: int = 10):
    """Run the full-jit variant; classify the failure mode."""
    try:
        step, _ = REGISTRY[name]("fulljit")
    except Exception as e:  # noqa: BLE001
        return "error-at-build", type(e).__name__
    try:
        losses = [step(i) for i in range(steps)]
    except Exception as e:  # noqa: BLE001
        return "error-at-trace", type(e).__name__
    if getattr(step, "_mutation_visible", lambda: True)() is False:
        return "silently-incorrect", "stale Python state baked into graph"
    return "ok", ""


def run_terra(name: str, steps: int = 10):
    step, _ = REGISTRY[name]("terra")
    tf = terra_function(step)
    losses = []
    for i in range(steps):
        l = tf(i)
        losses.append(float(l) if hasattr(l, "__float__") else l)
    phase = tf.phase
    tf.close()
    ok = all(np.isfinite(losses))
    return ok, phase


def main():
    rows = []
    print("program,terra,fulljit,failure_reason")
    for name in sorted(REGISTRY):
        t_ok, phase = run_terra(name)
        fj_status, fj_detail = classify_fulljit(name)
        expected = NON_CONVERTIBLE.get(name, "")
        reason = expected if fj_status != "ok" else ""
        row = (name, "ok" if t_ok else "FAIL",
               fj_status, reason or fj_detail)
        rows.append(row)
        print(",".join(row))
    n_terra = sum(r[1] == "ok" for r in rows)
    n_fj_fail = sum(r[2] != "ok" for r in rows)
    print(f"# terra handles {n_terra}/10; full-jit fails {n_fj_fail}/10 "
          f"(paper: AutoGraph fails 5/10)")
    return rows


if __name__ == "__main__":
    main()
