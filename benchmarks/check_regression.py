"""Perf-regression guard: compare fresh ``BENCH_*.json`` benchmark output
against the committed baselines with per-metric tolerances (DESIGN.md §15).

The committed repo-root ``BENCH_hotpath.json`` / ``BENCH_serving.json`` /
``BENCH_warmboot.json`` are smoke-profile runs, so a CI smoke run is
directly comparable.  Three spec kinds cover the three metric classes:

* ``bool``      — a gate that held at the baseline must still hold
                  (token equality, paged-vs-dense equality, warm-boot
                  hydration).  Skipped when the baseline itself was
                  false: the guard freezes achieved properties, it does
                  not ratchet new ones.
* ``min_frac``  — higher-is-better ratio metrics (speedups, the tracing
                  overhead ratio) must stay within a fraction of the
                  baseline.  Fractions are generous (0.6–0.9) because CI
                  timing noise on shared runners is real; the guard
                  catches collapses, not jitter.
* ``max_count`` — lower-is-better integer counters (retraces, replays,
                  recompiles, cache misses) must not exceed baseline +
                  ``slack``.  Default slack 0: a counter regression is a
                  behavioural regression, not noise.

Paths are dotted keys into the JSON; a ``*`` segment fans out over every
key at that level.  A path missing from the *baseline* is skipped (the
schema is allowed to grow); a path present in the baseline but missing
from the *fresh* output fails (the output schema regressed).

CLI::

    python -m benchmarks.check_regression --base ci-baselines --fresh .

exits non-zero listing every violated spec.  ``compare()`` is the
library entry point tests/test_obs.py drives with injected regressions.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

_MISSING = object()


# --------------------------------------------------------------------------
# metric specs
# --------------------------------------------------------------------------

def _bool(path: str) -> dict:
    return {"kind": "bool", "path": path}


def _min_frac(path: str, frac: float) -> dict:
    return {"kind": "min_frac", "path": path, "frac": frac}


def _max_count(path: str, slack: int = 0) -> dict:
    return {"kind": "max_count", "path": path, "slack": slack}


# Per-bench spec tables.  Only gate on metrics that are stable under CI
# timing noise: booleans, counters, and ratio-of-ratios with headroom.
SPECS: Dict[str, List[dict]] = {
    "BENCH_hotpath.json": [
        # python-side overhead is the paper's headline hot-path metric;
        # 2x headroom tolerates shared-runner jitter, catches collapse
        _min_frac("comparison.*.baseline_py_overhead_us", 0.0),  # schema only
        _max_count("programs.*.replays"),
        _max_count("programs.*.segments_dispatched"),
        _min_frac("programs.*.walker_fast_hits", 1.0),
        {"kind": "max_ratio", "path": "programs.*.py_overhead_us_median",
         "ratio": 2.0},
    ],
    "BENCH_serving.json": [
        _bool("gates.token_equality"),
        _bool("gates.shape_stable"),
        _bool("gates.paged_equal_vs_dense"),
        _bool("gates.paged_beyond_dense_capacity"),
        _max_count("gates.retraces_post_warmup"),
        _max_count("gates.paged_retraces_post_warmup"),
        _max_count("gates.families"),
        # throughput ratios: terra arm must stay near the baseline's
        # relative standing; absolute tokens/s is not gated (CI noise)
        _min_frac("gates.speedup_vs_lockstep", 0.6),
        _min_frac("gates.terra_vs_noterra", 0.7),
        # sampled profiling + timeline export must stay near-free
        # (ISSUE acceptance: >= 0.98x; guard at 0.9x of baseline ratio)
        _min_frac("gates.tracing_ratio", 0.9),
    ],
    "BENCH_warmboot.json": [
        _bool("warmboot.gates.warm_zero_retraces"),
        _bool("warmboot.gates.warm_zero_recompiles"),
        _bool("warmboot.gates.warm_hydrated"),
        _bool("warmboot.gates.warm_aot_loaded"),
        _bool("warmboot.gates.outputs_equal"),
        _bool("checkpoint.gates.token_equal"),
        _bool("checkpoint.gates.ckpt_mid_decode"),
        _max_count("warmboot.warm.retraces"),
        _max_count("warmboot.warm.segments_recompiled"),
        _max_count("warmboot.warm.artifact_misses"),
        _min_frac("warmboot.tts_speedup", 0.5),
    ],
}


# --------------------------------------------------------------------------
# dotted-path resolution with * fan-out
# --------------------------------------------------------------------------

def resolve(doc: Any, path: str) -> List[Tuple[str, Any]]:
    """All (concrete_path, value) pairs ``path`` names in ``doc``; a
    ``*`` segment expands over the dict keys present at that level."""
    out: List[Tuple[str, Any]] = [("", doc)]
    for seg in path.split("."):
        nxt: List[Tuple[str, Any]] = []
        for prefix, node in out:
            if not isinstance(node, dict):
                continue
            keys = sorted(node) if seg == "*" else \
                ([seg] if seg in node else [])
            for k in keys:
                if seg == "*" and str(k).startswith("_"):
                    continue          # private/annotation keys
                nxt.append((f"{prefix}.{k}" if prefix else str(k), node[k]))
        out = nxt
    return out


def _check_one(kind: str, spec: dict, cpath: str,
               base: Any, fresh: Any) -> Optional[str]:
    """None if the spec holds at one concrete path, else the failure."""
    if fresh is _MISSING:
        return f"{cpath}: present in baseline but missing from fresh output"
    if kind == "bool":
        if base and not fresh:
            return f"{cpath}: gate held at baseline but is now " \
                   f"{fresh!r}"
        return None
    if not isinstance(base, (int, float)) or isinstance(base, bool) or \
            not isinstance(fresh, (int, float)) or isinstance(fresh, bool):
        return f"{cpath}: expected numeric, got {base!r} vs {fresh!r}"
    if kind == "min_frac":
        floor = spec["frac"] * base
        if fresh < floor:
            return f"{cpath}: {fresh:g} < {spec['frac']:g} x baseline " \
                   f"{base:g} (floor {floor:g})"
    elif kind == "max_ratio":
        ceil = spec["ratio"] * base
        if base > 0 and fresh > ceil:
            return f"{cpath}: {fresh:g} > {spec['ratio']:g} x baseline " \
                   f"{base:g} (ceiling {ceil:g})"
    elif kind == "max_count":
        ceil = base + spec.get("slack", 0)
        if fresh > ceil:
            return f"{cpath}: counter {fresh:g} > baseline {base:g} " \
                   f"+ slack {spec.get('slack', 0)}"
    else:
        return f"{cpath}: unknown spec kind {kind!r}"
    return None


def compare(fresh: dict, baseline: dict,
            specs: List[dict]) -> List[str]:
    """Failure messages for every violated spec (empty list = pass).

    Baseline-side misses are skipped — the guard only enforces what the
    committed baseline actually achieved."""
    failures: List[str] = []
    for spec in specs:
        for cpath, bval in resolve(baseline, spec["path"]):
            fvals = dict(resolve(fresh, cpath))
            fval = fvals.get(cpath, _MISSING)
            msg = _check_one(spec["kind"], spec, cpath, bval, fval)
            if msg:
                failures.append(msg)
    return failures


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def check_files(base_dir: str, fresh_dir: str,
                names: Optional[List[str]] = None) -> Dict[str, List[str]]:
    """Compare every spec'd bench file present in both dirs; returns
    {name: failures}.  A bench file absent from either side is reported
    as skipped on stderr, not failed (jobs may run a subset)."""
    results: Dict[str, List[str]] = {}
    for name in (names or sorted(SPECS)):
        bpath = os.path.join(base_dir, name)
        fpath = os.path.join(fresh_dir, name)
        if not os.path.exists(bpath) or not os.path.exists(fpath):
            missing = bpath if not os.path.exists(bpath) else fpath
            print(f"[check_regression] skip {name}: {missing} not found",
                  file=sys.stderr)
            continue
        with open(bpath) as f:
            baseline = json.load(f)
        with open(fpath) as f:
            fresh = json.load(f)
        results[name] = compare(fresh, baseline, SPECS[name])
    return results


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--base", default="ci-baselines",
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh", default=".",
                    help="directory holding the fresh benchmark output")
    ap.add_argument("names", nargs="*",
                    help="bench files to check (default: all spec'd)")
    args = ap.parse_args(argv)
    results = check_files(args.base, args.fresh, args.names or None)
    if not results:
        print("[check_regression] nothing compared", file=sys.stderr)
        return 2
    bad = 0
    for name, failures in sorted(results.items()):
        status = "FAIL" if failures else "ok"
        print(f"[check_regression] {name}: {status}")
        for msg in failures:
            print(f"  - {msg}")
        bad += len(failures)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
