"""Warm-boot benchmark: persistent artifact store + checkpoint/restore.

Measures and gates the ISSUE-9 contract (DESIGN.md §14) across real
process boundaries:

* **warm boot** — a training-style workload runs twice in fresh
  subprocesses sharing one ``$TERRA_CACHE_DIR``.  ``tts`` is the
  time-to-steady-state: wall time from the first ``step()`` call until
  the call that completes in co-execution returns (cold: trace + pass
  pipeline + XLA compile; warm: hydrate + AOT deserialize + first walker
  validation).  Gates: the warm run does zero retraces and zero segment
  recompiles, hydrates at least one family, loads at least one AOT
  segment, produces bit-identical outputs, and reaches steady state
  >= 5x faster than the cold run (full mode only; ``--smoke`` records
  without enforcing the speedup on shared CI machines).
* **checkpoint/restore** — a continuous-batching scheduler is stopped
  mid-decode (requests in flight AND queued), checkpointed, and restored
  in a fresh process; every request must finish with exactly the greedy
  tokens an uninterrupted reference produced.

CI's ``warm-cache`` job uses ``--cache-run`` (one training run against
the ambient ``$TERRA_CACHE_DIR``, no tempdir) twice: the second
invocation adds ``--expect-warm``, which fails the job if anything was
retraced or recompiled.

Writes ``BENCH_warmboot.json``.

Usage:
    python -m benchmarks.bench_warmboot [--smoke] [--out BENCH_warmboot.json]
    python -m benchmarks.bench_warmboot --cache-run [--expect-warm]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# --------------------------------------------------------------------------
# child roles (run in fresh subprocesses)
# --------------------------------------------------------------------------

def _role_train(args) -> None:
    """Training-style workload: several matmul layers with gating fetches
    (multiple compiled segments), variables updated every iteration."""
    import numpy as np
    from repro.core import Variable, function, ops

    dim, iters = args.dim, args.iters
    ws = [Variable(np.eye(dim, dtype=np.float32) * (0.9 + 0.05 * i),
                   name=f"w{i}") for i in range(args.layers)]

    @function
    def step(x):
        h = x
        for w in ws:
            h = ops.matmul(h, w.read())
            # gating fetch: a host-visible scalar per layer forces a
            # segment boundary, so the cold run compiles several segments
            g = float(ops.reduce_sum(h)) * 0.0
            w.assign(ops.add(w.read(), ops.mul(h, 1e-4 + g)))
        return float(ops.reduce_sum(h))

    outs, tts = [], None
    t0 = time.perf_counter()
    for i in range(iters):
        outs.append(step(np.full((dim, dim), 0.01 * (i + 1), np.float32)))
        if tts is None and step.phase == "co-execution":
            tts = time.perf_counter() - t0
    step.wait()
    if tts is None:                     # never transitioned: report total
        tts = time.perf_counter() - t0
    st = step.stats
    print(json.dumps({
        "tts_s": tts, "outs": outs,
        "retraces": st["retraces"],
        "segments_recompiled": st["segments_recompiled"],
        "artifact_hits": st["artifact_hits"],
        "artifact_misses": st["artifact_misses"],
        "artifacts_stored": st["artifacts_stored"],
        "warm_families": st["warm_families"],
        "aot_loads": st["aot_loads"]}))
    step.close()


def _role_sched(args) -> None:
    """Scheduler roles: ref (uninterrupted), ckpt (stop mid-decode and
    checkpoint), resume (restore in a fresh process and drain)."""
    import numpy as np
    import jax
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request
    from repro.serve.scheduler import ContinuousBatchingScheduler

    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, 4 + i)
                    .astype(np.int32),
                    max_new_tokens=args.max_new, arrival_time=0.0)
            for i in range(args.requests)]
    kw = dict(max_slots=4, max_len=128, temperature=0.0)

    if args.role == "sched-ref":
        sch = ContinuousBatchingScheduler(cfg, params, **kw)
        sch.serve(reqs)
        print(json.dumps({"toks": [r.out_tokens for r in reqs]}))
    elif args.role == "sched-ckpt":
        sch = ContinuousBatchingScheduler(cfg, params, **kw)
        for r in reqs:
            sch.submit(r)
        sch.run(max_steps=args.ckpt_steps)      # stop mid-decode
        sch.checkpoint(args.ckpt)
        print(json.dumps({"partial": {r.rid: r.out_tokens or []
                                      for r in reqs},
                          "in_flight": sch.pool.active_count,
                          "queued": len(sch.queue)}))
    else:                                       # sched-resume
        sch = ContinuousBatchingScheduler.restore(args.ckpt, cfg, params)
        with open(os.path.join(args.ckpt, "partial.json")) as f:
            partial = {int(k): v for k, v in json.load(f).items()}
        tracked = {r.rid: r for _, r in sch.pool.active_items()}
        tracked.update({r.rid: r for r in sch.queue._queue})
        sch.run()
        for rid, r in tracked.items():
            partial[rid] = r.out_tokens
        print(json.dumps({"toks": [partial[k] for k in sorted(partial)],
                          "restores": sch.sched_stats.get(
                              "checkpoint_restores", 0)}))
    sch.close()


# --------------------------------------------------------------------------
# orchestration
# --------------------------------------------------------------------------

def _spawn(role: str, cache_dir: str, extra) -> dict:
    env = {**os.environ, "PYTHONPATH": f"{os.path.join(ROOT, 'src')}:{ROOT}"}
    if cache_dir:
        env["TERRA_CACHE_DIR"] = cache_dir
    else:
        env.pop("TERRA_CACHE_DIR", None)
    cmd = [sys.executable, "-m", "benchmarks.bench_warmboot",
           "--role", role] + extra
    out = subprocess.run(cmd, env=env, cwd=ROOT, capture_output=True,
                         text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError(f"{role} failed:\n{out.stderr[-3000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def run_warmboot(smoke: bool) -> dict:
    # full mode sizes the workload so XLA compile dominates the cold
    # boot (the regime the store exists for); smoke just checks wiring.
    # tts is best-of-2 per side: process wall times on a shared machine
    # carry 2x noise tails that would make a single-shot ratio flaky.
    dim, layers, iters = (64, 3, 6) if smoke else (512, 12, 8)
    extra = ["--dim", str(dim), "--iters", str(iters),
             "--layers", str(layers)]
    with tempfile.TemporaryDirectory() as c1, \
            tempfile.TemporaryDirectory() as c2:
        cold = _spawn("train", c1, extra)
        cold2 = _spawn("train", c2, extra)
        warm = _spawn("train", c1, extra)
        warm2 = _spawn("train", c1, extra)
    cold_tts = min(cold["tts_s"], cold2["tts_s"])
    warm_tts = min(warm["tts_s"], warm2["tts_s"])
    cold["tts_s"], warm["tts_s"] = cold_tts, warm_tts
    speedup = cold_tts / max(warm_tts, 1e-9)
    gates = {
        "warm_zero_retraces": warm["retraces"] == 0,
        "warm_zero_recompiles": warm["segments_recompiled"] == 0,
        "warm_hydrated": warm["warm_families"] >= 1,
        "warm_aot_loaded": warm["aot_loads"] >= 1,
        "outputs_equal": warm["outs"] == cold["outs"],
        "speedup_5x": speedup >= 5.0,
    }
    return {"cold": cold, "warm": warm,
            "tts_speedup": round(speedup, 2), "gates": gates}


def run_checkpoint(smoke: bool) -> dict:
    # 5 requests over 4 slots: the checkpoint catches 4 in flight AND one
    # still queued, covering both restore paths
    n_req, max_new, steps = (5, 8, 4) if smoke else (6, 10, 7)
    extra = ["--requests", str(n_req), "--max-new", str(max_new),
             "--ckpt-steps", str(steps)]
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "sched_ck")
        ref = _spawn("sched-ref", "", extra)
        part = _spawn("sched-ckpt", "", extra + ["--ckpt", ck])
        with open(os.path.join(ck, "partial.json"), "w") as f:
            json.dump(part["partial"], f)
        res = _spawn("sched-resume", "", extra + ["--ckpt", ck])
    return {"requests": n_req,
            "in_flight_at_ckpt": part["in_flight"],
            "queued_at_ckpt": part["queued"],
            "restores": res["restores"],
            "gates": {"token_equal": res["toks"] == ref["toks"],
                      "ckpt_mid_decode": part["in_flight"] > 0}}


def run_cache_run(args) -> dict:
    """One training run against the ambient $TERRA_CACHE_DIR (CI job)."""
    if not os.environ.get("TERRA_CACHE_DIR"):
        raise SystemExit("--cache-run requires $TERRA_CACHE_DIR")
    extra = ["--dim", "64", "--iters", "6"]
    res = _spawn("train", os.environ["TERRA_CACHE_DIR"], extra)
    res["gates"] = {}
    if args.expect_warm:
        res["gates"] = {
            "warm_zero_retraces": res["retraces"] == 0,
            "warm_zero_recompiles": res["segments_recompiled"] == 0,
            "warm_hits": res["artifact_hits"] > 0,
        }
    return res


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--role", default=None,
                    help="internal: subprocess role")
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--layers", type=int, default=3)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-steps", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes; record the 5x speedup, don't gate it")
    ap.add_argument("--cache-run", action="store_true",
                    help="one run against $TERRA_CACHE_DIR (CI warm-cache)")
    ap.add_argument("--expect-warm", action="store_true",
                    help="with --cache-run: fail unless fully warm")
    ap.add_argument("--out", default=None, help="write report JSON here")
    args = ap.parse_args(argv)

    if args.role == "train":
        return _role_train(args)
    if args.role in ("sched-ref", "sched-ckpt", "sched-resume"):
        return _role_sched(args)

    if args.cache_run:
        report = {"mode": "cache-run", "run": run_cache_run(args)}
        gates = report["run"]["gates"]
    else:
        report = {"mode": "smoke" if args.smoke else "full",
                  "warmboot": run_warmboot(args.smoke),
                  "checkpoint": run_checkpoint(args.smoke)}
        gates = {**report["warmboot"]["gates"],
                 **report["checkpoint"]["gates"]}
        if args.smoke:      # shared CI machines: record, don't enforce
            gates.pop("speedup_5x")
    print(json.dumps(report, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2)
    failed = sorted(k for k, ok in gates.items() if not ok)
    if failed:
        raise SystemExit(f"warm-boot gates failed: {failed}")
    print("all warm-boot gates passed:", sorted(gates))


if __name__ == "__main__":
    main()
