"""The ten imperative DL programs of the paper's evaluation (§5.1),
re-created on the repro.core op layer with the same failure-inducing
Python features:

    DropBlock        — Python object mutation (drop prob schedule)
    MusicTransformer — Python object mutation (cached numpy rel-pos mask)
    SDPoint          — stochastic downsample point chosen by Python RNG
    BERT-CLS         — third-party (numpy) call on a materialized tensor
    FasterRCNN       — tensor materialization steering Python control flow
    BERT-Q&A, GPT2, DCGAN, ResNet, YOLOv3 — convertible programs

Each program exposes:
    make_step(variant) -> (step_fn, batch_fn)
      variant in {"terra", "imperative", "fulljit"}
"terra"/"imperative" run through the instrumented op layer (Variables and
GradientTape); "fulljit" is the AutoGraph analogue — the whole step
compiled as one jax.jit function (functional state threading, exactly what
tf.function(autograph) does to TF programs).  The five non-convertible
programs raise/或 silently corrupt under "fulljit"; benchmarks.table1
classifies the failures.
"""

from __future__ import annotations

import functools
import types
from typing import Callable, Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GradientTape, Variable, ops

REGISTRY: Dict[str, Callable] = {}


def program(name):
    def deco(f):
        REGISTRY[name] = f
        return f
    return deco


def _sgd(tape, loss, variables, lr=0.05):
    grads = tape.gradient(loss, variables)
    for v, g in zip(variables, grads):
        v.assign_sub(ops.mul(g, lr))


def _mlp_vars(rng, sizes, prefix):
    vs = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        vs.append(Variable((rng.randn(a, b) * (2.0 / a) ** 0.5)
                           .astype(np.float32), f"{prefix}_w{i}"))
    return vs


# ==========================================================================
# 1. DropBlock — object mutation of the drop probability schedule
# ==========================================================================

@program("dropblock")
def dropblock(variant, d=64, batch=16):
    rng = np.random.RandomState(0)

    class DropBlock:                       # the mutated Python object
        drop_prob = 0.0

    db = DropBlock()
    ws = _mlp_vars(rng, [d, d, d, 10], "db")
    step_count = [0]

    def batch_fn(i):
        r = np.random.RandomState(i)
        return (r.randn(batch, d).astype(np.float32),
                r.randint(0, 10, batch).astype(np.int32))

    if variant == "fulljit":
        w0 = [np.asarray(v._value) for v in ws]

        def loss_fn(p, x, y, key):
            keep = 1.0 - db.drop_prob       # BAKED at first trace
            h = x
            for w in p[:-1]:
                h = jax.nn.relu(h @ w)
                h = jnp.where(jax.random.bernoulli(key, keep, h.shape),
                              h / max(keep, 1e-6), 0.0)
            logits = h @ p[-1]
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits)
                                     * jax.nn.one_hot(y, 10), -1))

        @jax.jit
        def js(p, x, y, key):
            l, g = jax.value_and_grad(loss_fn)(p, x, y, key)
            return [a - 0.05 * b for a, b in zip(p, g)], l

        def step(i):
            nonlocal w0
            db.drop_prob = 0.1 if i >= 5 else 0.0     # mutation IGNORED
            x, y = batch_fn(i)
            w0, loss = js(w0, x, y, jax.random.PRNGKey(i))
            return float(loss)
        step._mutation_visible = lambda: False        # silently stale
        return step, batch_fn

    def step(i):
        db.drop_prob = 0.1 if i >= 5 else 0.0         # object mutation
        x, y = batch_fn(i)
        with GradientTape() as tape:
            h = x
            for w in ws[:-1]:
                h = ops.relu(ops.matmul(h, w.read()))
                h = ops.dropout(h, db.drop_prob)
            logits = ops.matmul(h, ws[-1].read())
            loss = ops.softmax_xent(logits, y)
        _sgd(tape, loss, ws)
        return loss
    return step, batch_fn


# ==========================================================================
# 2. MusicTransformer — mutation: numpy-cached relative mask object
# ==========================================================================

@program("musictransformer")
def musictransformer(variant, d=64, seq=32, batch=8, heads=4):
    rng = np.random.RandomState(1)
    wq, wk, wv, wo = _mlp_vars(rng, [d, d, d, d, d], "mt")[:4]
    w_out = Variable((rng.randn(d, 32) * 0.1).astype(np.float32), "mt_out")

    class RelMask:                         # python-side cached mask object
        window = seq

        def get(self):
            m = np.tril(np.ones((seq, seq), np.float32))
            m *= (np.abs(np.subtract.outer(np.arange(seq),
                                           np.arange(seq)))
                  < self.window).astype(np.float32)
            return m

    rel = RelMask()

    def batch_fn(i):
        r = np.random.RandomState(100 + i)
        return (r.randn(batch, seq, d).astype(np.float32),
                r.randint(0, 32, (batch, seq)).astype(np.int32))

    def model(x, mask, read):
        q = ops.matmul(x, read(wq))
        k = ops.matmul(x, read(wk))
        v = ops.matmul(x, read(wv))
        s = ops.einsum(q, k, expr="bsd,btd->bst")
        s = ops.add(ops.mul(s, 1.0 / d ** 0.5),
                    ops.mul(ops.sub(mask, 1.0), 1e9))
        a = ops.softmax(s, axis=-1)
        h = ops.einsum(a, v, expr="bst,btd->bsd")
        h = ops.matmul(h, read(wo))
        return ops.matmul(h, read(w_out))

    if variant == "fulljit":
        params = [np.asarray(v._value) for v in (wq, wk, wv, wo, w_out)]
        mask0 = rel.get()                  # BAKED: later window mutation lost

        def loss_fn(p, x, y):
            q, k, v_ = x @ p[0], x @ p[1], x @ p[2]
            s = jnp.einsum("bsd,btd->bst", q, k) / d ** 0.5
            s = s + (mask0 - 1.0) * 1e9
            h = jnp.einsum("bst,btd->bsd", jax.nn.softmax(s), v_) @ p[3]
            logits = h @ p[4]
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits)
                                     * jax.nn.one_hot(y, 32), -1))

        @jax.jit
        def jstep(p, x, y):
            l, g = jax.value_and_grad(loss_fn)(p, x, y)
            return [a - 0.05 * b for a, b in zip(p, g)], l

        def step(i):
            nonlocal params
            rel.window = 8 if i >= 5 else seq
            x, y = batch_fn(i)
            params, l = jstep(params, x, y)
            return float(l)
        step._mutation_visible = lambda: False
        return step, batch_fn

    def step(i):
        rel.window = 8 if i >= 5 else seq          # mutation
        x, y = batch_fn(i)
        with GradientTape() as tape:
            logits = model(x, rel.get(), lambda v: v.read())
            loss = ops.softmax_xent(
                ops.reshape(logits, new_shape=(batch * seq, 32)),
                y.reshape(batch * seq))
        _sgd(tape, loss, [wq, wk, wv, wo, w_out])
        return loss
    return step, batch_fn


# ==========================================================================
# 3. SDPoint — stochastic downsampling point picked by the Python RNG
# ==========================================================================

@program("sdpoint")
def sdpoint(variant, d=64, batch=16):
    rng = np.random.RandomState(2)
    ws = _mlp_vars(rng, [d, d, d, d, 10], "sd")
    pyrng = np.random.RandomState(42)

    def batch_fn(i):
        r = np.random.RandomState(200 + i)
        return (r.randn(batch, d).astype(np.float32),
                r.randint(0, 10, batch).astype(np.int32))

    def fwd(x, point, read):
        h = x
        for j, w in enumerate(ws[:-1]):
            h = ops.relu(ops.matmul(h, read(w)))
            if j == point:                       # python-chosen downsample
                h = ops.mul(h, 0.5)
        return ops.matmul(h, read(ws[-1]))

    if variant == "fulljit":
        params = [np.asarray(v._value) for v in ws]
        first_point = pyrng.randint(0, 3)        # BAKED single path

        def loss_fn(p, x, y):
            h = x
            for j in range(3):
                h = jax.nn.relu(h @ p[j])
                if j == first_point:
                    h = h * 0.5
            logits = h @ p[-1]
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits)
                                     * jax.nn.one_hot(y, 10), -1))

        @jax.jit
        def jstep(p, x, y):
            l, g = jax.value_and_grad(loss_fn)(p, x, y)
            return [a - 0.05 * b for a, b in zip(p, g)], l

        def step(i):
            nonlocal params
            _ = pyrng.randint(0, 3)              # choice IGNORED by graph
            x, y = batch_fn(i)
            params, l = jstep(params, x, y)
            return float(l)
        step._mutation_visible = lambda: False
        return step, batch_fn

    def step(i):
        point = pyrng.randint(0, 3)              # dynamic python control
        x, y = batch_fn(i)
        with GradientTape() as tape:
            logits = fwd(x, point, lambda v: v.read())
            loss = ops.softmax_xent(logits, y)
        _sgd(tape, loss, ws)
        return loss
    return step, batch_fn


# ==========================================================================
# 4. BERT-CLS — third-party numpy call inside the step
# ==========================================================================

@program("bert_cls")
def bert_cls(variant, d=64, batch=16):
    rng = np.random.RandomState(3)
    ws = _mlp_vars(rng, [d, d, d, 4], "bc")

    def batch_fn(i):
        r = np.random.RandomState(300 + i)
        return (r.randn(batch, d).astype(np.float32),
                r.randint(0, 4, batch).astype(np.int32))

    if variant == "fulljit":
        params = [np.asarray(v._value) for v in ws]

        @jax.jit
        def jstep(p, x, y):
            h = jax.nn.relu(jax.nn.relu(x @ p[0]) @ p[1])
            logits = h @ p[2]
            # third-party call on a tracer -> TracerArrayConversionError
            weights = np.bincount(np.asarray(y), minlength=4)  # BOOM
            loss = -jnp.mean(jnp.sum(jax.nn.log_softmax(logits)
                                     * jax.nn.one_hot(y, 4), -1))
            return p, loss

        def step(i):
            x, y = batch_fn(i)
            _, l = jstep(params, x, y)
            return float(l)
        return step, batch_fn

    def step(i):
        x, y = batch_fn(i)
        with GradientTape() as tape:
            h = ops.relu(ops.matmul(ops.relu(ops.matmul(x, ws[0].read())),
                                    ws[1].read()))
            logits = ops.matmul(h, ws[2].read())
            # third-party library use on materialized values (Fig. 1a)
            preds = np.argmax(logits.numpy(), axis=-1)
            acc = float((preds == y).mean())          # sklearn-style metric
            loss = ops.softmax_xent(logits, y)
        _sgd(tape, loss, ws)
        return loss
    return step, batch_fn


# ==========================================================================
# 5. FasterRCNN — tensor materialization steering Python control flow
# ==========================================================================

@program("fasterrcnn")
def fasterrcnn(variant, d=64, batch=8, n_anchors=32):
    rng = np.random.RandomState(4)
    w_rpn = _mlp_vars(rng, [d, d, 1], "rpn")
    w_head = _mlp_vars(rng, [d, d, 5], "head")

    def batch_fn(i):
        r = np.random.RandomState(400 + i)
        return (r.randn(batch, n_anchors, d).astype(np.float32),
                r.randint(0, 5, batch).astype(np.int32))

    if variant == "fulljit":
        params = ([np.asarray(v._value) for v in w_rpn]
                  + [np.asarray(v._value) for v in w_head])

        @jax.jit
        def jstep(p, x, y):
            s = jax.nn.relu(x @ p[0]) @ p[1]
            # materialization during conversion -> ConcretizationTypeError
            k = int(jnp.sum(jax.nn.sigmoid(s) > 0.5))   # BOOM
            top = x[:, :max(k, 1)]
            logits = jnp.mean(jax.nn.relu(top @ p[2]) @ p[3], axis=1)
            return p, logits.sum()

        def step(i):
            x, y = batch_fn(i)
            _, l = jstep(params, x, y)
            return float(l)
        return step, batch_fn

    def step(i):
        x, y = batch_fn(i)
        with GradientTape() as tape:
            s = ops.matmul(ops.relu(ops.matmul(x, w_rpn[0].read())),
                           w_rpn[1].read())
            # materialize proposal count, feed it back (GraphRunner stall
            # pattern from the paper's FasterRCNN analysis); proposal counts
            # are bucketed to powers of two as real detectors do, so the
            # TraceGraph converges to 4 branches
            n_pos = int((ops.sigmoid(s).numpy() > 0.5).sum())
            k = 4
            while k < min(max(n_pos // batch, 4), n_anchors):
                k *= 2
            top = ops.getitem(x, idx=(slice(None), slice(0, k)))
            h = ops.relu(ops.matmul(top, w_head[0].read()))
            logits = ops.reduce_mean(ops.matmul(h, w_head[1].read()), axis=1)
            loss = ops.softmax_xent(logits, y)
        _sgd(tape, loss, w_rpn + w_head)
        return loss
    return step, batch_fn


# ==========================================================================
# 6-10. convertible programs (both Terra and full-jit succeed)
# ==========================================================================

def _simple_classifier(name, sizes, n_cls, seed):
    @program(name)
    def prog(variant, batch=16):
        rng = np.random.RandomState(seed)
        ws = _mlp_vars(rng, sizes + [n_cls], name)

        def batch_fn(i):
            r = np.random.RandomState(seed * 100 + i)
            return (r.randn(batch, sizes[0]).astype(np.float32),
                    r.randint(0, n_cls, batch).astype(np.int32))

        if variant == "fulljit":
            params = [np.asarray(v._value) for v in ws]

            def loss_fn(p, x, y):
                h = x
                for w in p[:-1]:
                    h = jax.nn.relu(h @ w)
                return -jnp.mean(jnp.sum(jax.nn.log_softmax(h @ p[-1])
                                         * jax.nn.one_hot(y, n_cls), -1))

            @jax.jit
            def jstep(p, x, y):
                l, g = jax.value_and_grad(loss_fn)(p, x, y)
                return [a - 0.05 * b for a, b in zip(p, g)], l

            def step(i):
                nonlocal params
                x, y = batch_fn(i)
                params, l = jstep(params, x, y)
                return float(l)
            return step, batch_fn

        def step(i):
            x, y = batch_fn(i)
            with GradientTape() as tape:
                h = x
                for w in ws[:-1]:
                    h = ops.relu(ops.matmul(h, w.read()))
                loss = ops.softmax_xent(ops.matmul(h, ws[-1].read()), y)
            _sgd(tape, loss, ws)
            return loss
        return step, batch_fn
    return prog


_simple_classifier("bert_qa", [96, 96, 96], 8, 5)
_simple_classifier("resnet", [128, 128, 128, 128], 10, 6)
_simple_classifier("yolov3", [128, 192, 128], 16, 7)


@program("gpt2")
def gpt2(variant, d=64, seq=32, batch=8):
    rng = np.random.RandomState(8)
    wq, wk, wv, wo = _mlp_vars(rng, [d, d, d, d, d], "g2")[:4]
    w_out = Variable((rng.randn(d, 64) * 0.1).astype(np.float32), "g2o")
    mask = np.tril(np.ones((seq, seq), np.float32))

    def batch_fn(i):
        r = np.random.RandomState(800 + i)
        return (r.randn(batch, seq, d).astype(np.float32),
                r.randint(0, 64, (batch, seq)).astype(np.int32))

    if variant == "fulljit":
        params = [np.asarray(v._value) for v in (wq, wk, wv, wo, w_out)]

        def loss_fn(p, x, y):
            q, k, v_ = x @ p[0], x @ p[1], x @ p[2]
            s = jnp.einsum("bsd,btd->bst", q, k) / d ** 0.5
            s = s + (mask - 1.0) * 1e9
            h = jnp.einsum("bst,btd->bsd", jax.nn.softmax(s), v_) @ p[3]
            logits = h @ p[4]
            return -jnp.mean(jnp.sum(jax.nn.log_softmax(logits)
                                     * jax.nn.one_hot(y, 64), -1))

        @jax.jit
        def jstep(p, x, y):
            l, g = jax.value_and_grad(loss_fn)(p, x, y)
            return [a - 0.05 * b for a, b in zip(p, g)], l

        def step(i):
            nonlocal params
            x, y = batch_fn(i)
            params, l = jstep(params, x, y)
            return float(l)
        return step, batch_fn

    def step(i):
        x, y = batch_fn(i)
        with GradientTape() as tape:
            q = ops.matmul(x, wq.read())
            k = ops.matmul(x, wk.read())
            v = ops.matmul(x, wv.read())
            s = ops.einsum(q, k, expr="bsd,btd->bst")
            s = ops.add(ops.mul(s, 1.0 / d ** 0.5),
                        ops.mul(ops.sub(mask, 1.0), 1e9))
            h = ops.einsum(ops.softmax(s, axis=-1), v, expr="bst,btd->bsd")
            logits = ops.matmul(ops.matmul(h, wo.read()), w_out.read())
            loss = ops.softmax_xent(
                ops.reshape(logits, new_shape=(batch * seq, 64)),
                y.reshape(batch * seq))
        _sgd(tape, loss, [wq, wk, wv, wo, w_out])
        return loss
    return step, batch_fn


@program("dcgan")
def dcgan(variant, dz=32, d=64, batch=16):
    rng = np.random.RandomState(9)
    gw = _mlp_vars(rng, [dz, d, d], "gen")
    dw = _mlp_vars(rng, [d, d, 1], "dis")

    def batch_fn(i):
        r = np.random.RandomState(900 + i)
        return (r.randn(batch, d).astype(np.float32),
                r.randn(batch, dz).astype(np.float32))

    if variant == "fulljit":
        gp = [np.asarray(v._value) for v in gw]
        dp = [np.asarray(v._value) for v in dw]

        def d_loss(dp_, gp_, real, z):
            fake = jax.nn.relu(z @ gp_[0]) @ gp_[1]
            dr = jax.nn.relu(real @ dp_[0]) @ dp_[1]
            df = jax.nn.relu(fake @ dp_[0]) @ dp_[1]
            return (jnp.mean(jax.nn.softplus(-dr))
                    + jnp.mean(jax.nn.softplus(df)))

        def g_loss(gp_, dp_, z):
            fake = jax.nn.relu(z @ gp_[0]) @ gp_[1]
            df = jax.nn.relu(fake @ dp_[0]) @ dp_[1]
            return jnp.mean(jax.nn.softplus(-df))

        @jax.jit
        def jstep(gp_, dp_, real, z):
            dl, dg = jax.value_and_grad(d_loss)(dp_, gp_, real, z)
            dp_ = [a - 0.05 * b for a, b in zip(dp_, dg)]
            gl, gg = jax.value_and_grad(g_loss)(gp_, dp_, z)
            gp_ = [a - 0.05 * b for a, b in zip(gp_, gg)]
            return gp_, dp_, dl + gl

        def step(i):
            nonlocal gp, dp
            real, z = batch_fn(i)
            gp, dp, l = jstep(gp, dp, real, z)
            return float(l)
        return step, batch_fn

    def step(i):
        real, z = batch_fn(i)
        with GradientTape() as tape:
            fake = ops.matmul(ops.relu(ops.matmul(z, gw[0].read())),
                              gw[1].read())
            dr = ops.matmul(ops.relu(ops.matmul(real, dw[0].read())),
                            dw[1].read())
            df = ops.matmul(ops.relu(ops.matmul(fake, dw[0].read())),
                            dw[1].read())
            d_l = ops.add(ops.reduce_mean(ops.log(ops.add(ops.exp(ops.neg(dr)), 1.0))),
                          ops.reduce_mean(ops.log(ops.add(ops.exp(df), 1.0))))
        _sgd(tape, d_l, dw)
        with GradientTape() as tape2:
            fake = ops.matmul(ops.relu(ops.matmul(z, gw[0].read())),
                              gw[1].read())
            df = ops.matmul(ops.relu(ops.matmul(fake, dw[0].read())),
                            dw[1].read())
            g_l = ops.reduce_mean(ops.log(ops.add(ops.exp(ops.neg(df)), 1.0)))
        _sgd(tape2, g_l, gw)
        return ops.add(d_l, g_l)
    return step, batch_fn


NON_CONVERTIBLE = {
    "dropblock": "Python object mutation",
    "musictransformer": "Python object mutation",
    "sdpoint": "Python object mutation",
    "bert_cls": "third-party library call",
    "fasterrcnn": "tensor materialization during conversion",
}
