"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV followed by each table's own
detailed output.  Roofline/dry-run cells are produced separately by
``python -m repro.launch.dryrun`` (they need 512 host devices and must not
contaminate this process's single-device jax state).
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig5_throughput, fig6_breakdown,
                            table1_coverage, table2_lazyeval)

    print("=== Figure 5: training throughput ===")
    rows = fig5_throughput.main()
    print("\n=== name,us_per_call,derived ===")
    for r in rows:
        print(f"fig5/{r[0]},{r[2]:.0f},speedup_vs_imperative={r[4]:.2f}x")

    print("\n=== Table 1: coverage ===")
    t1 = table1_coverage.main()
    for name, terra_ok, fj, reason in t1:
        print(f"table1/{name},0,terra={terra_ok};fulljit={fj}")

    print("\n=== Figure 6: runner breakdown ===")
    fig6_breakdown.main()

    print("\n=== Table 2: lazy evaluation ablation ===")
    table2_lazyeval.main()


if __name__ == "__main__":
    main()
