"""Hot-path benchmark: steady-state per-iteration Python overhead.

Measures, per benchmark program, what the skeleton phase costs the Python
thread each iteration once the engine is in steady-state co-execution:

* ``py_stall_us``    — time blocked at Output Fetching / per-value fences
                       (``engine.stats["py_stall_time"]``),
* ``dispatch_us``    — Python-thread time spent in segment dispatch
                       (``engine.stats["dispatch_time"]``),
* ``py_overhead_us`` — their sum: the interpreter-overhead class the paper's
                       speedup claim depends on keeping off the critical
                       path (ISSUE 2; JANUS / TF-Eager interpreter gap),
* GraphRunner occupancy (``runner_exec_time`` / ``runner_stall_time``) and
  the hot-path counters (``walker_fast_hits``, ``feeds_defaulted``).

Per-iteration samples are collected individually; the headline statistic is
the **median** (steady-state cost — the mean is dominated by GC pauses and
OS scheduling tails on a shared machine, which hit pre- and post-change
code alike).  Each cell runs ``--rounds`` times in-process and keeps the
round with the lowest median overhead.

The ``shape_flip`` section (ISSUE 3) drives serving decode with
alternating batch sizes through the shape-keyed TraceGraph families and
asserts zero ``retraces`` / ``segments_recompiled`` across the flips after
one trace+compile per shape class.

Writes ``BENCH_hotpath.json``.  If a baseline file exists
(``benchmarks/baseline_hotpath.json`` — measured at the pre-change commit
with this same methodology), a per-program and mean reduction is reported;
the ISSUE 2 gate is ``mean_reduction_pct >= 25`` over the fig5 programs.

Usage:
    python -m benchmarks.bench_hotpath [--smoke] [--out BENCH_hotpath.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.programs import NON_CONVERTIBLE, REGISTRY
from repro.core import function as terra_function

DEFAULT_PROGRAMS = ["resnet", "gpt2", "bert_qa", "fasterrcnn"]
BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "baseline_hotpath.json")


def measure_once(name: str, warmup: int, iters: int) -> dict:
    step, _ = REGISTRY[name]("terra")
    tf = terra_function(step)
    for i in range(warmup):
        tf(i)
    tf.wait()
    eng = tf.engine
    stats = eng.stats
    base_counters = {k: stats[k] for k in
                     ("walker_fast_hits", "feeds_defaulted",
                      "segments_dispatched", "replays")}
    base_runner = (stats["runner_exec_time"], stats["runner_stall_time"])
    samples = []
    prev = (stats["py_stall_time"], stats["dispatch_time"])
    for i in range(warmup, warmup + iters):
        t0 = time.perf_counter()
        tf(i)
        wall = time.perf_counter() - t0
        cur = (stats["py_stall_time"], stats["dispatch_time"])
        samples.append((wall, cur[0] - prev[0], cur[1] - prev[1]))
        prev = cur
    tf.wait()
    a = np.asarray(samples) * 1e6
    overhead = a[:, 1] + a[:, 2]
    out = {
        "iters": iters,
        "phase": tf.phase,
        "wall_us_median": float(np.median(a[:, 0])),
        "wall_us_mean": float(a[:, 0].mean()),
        "py_stall_us_median": float(np.median(a[:, 1])),
        "dispatch_us_median": float(np.median(a[:, 2])),
        "py_overhead_us_median": float(np.median(overhead)),
        "py_overhead_us_mean": float(overhead.mean()),
        "runner_exec_us_per_iter":
            (stats["runner_exec_time"] - base_runner[0]) / iters * 1e6,
        "runner_stall_us_per_iter":
            (stats["runner_stall_time"] - base_runner[1]) / iters * 1e6,
    }
    for k, v in base_counters.items():
        out[k] = stats[k] - v
    tf.close()
    return out


def measure(name: str, warmup: int, iters: int, rounds: int) -> dict:
    best = None
    for _ in range(rounds):
        r = measure_once(name, warmup, iters)
        if best is None or (r["py_overhead_us_median"]
                            < best["py_overhead_us_median"]):
            best = r
    return best


def measure_shape_flip(flips: int = 50, sizes=(4, 8)) -> dict:
    """Serving decode with alternating batch sizes (ISSUE 3 acceptance):
    after one trace + compile per shape class, every batch-size flip must
    be a TraceGraph-family lookup — zero retraces, zero segment
    recompiles, zero divergences across ``flips`` flips."""
    import jax
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=48)
    rng = np.random.RandomState(0)

    def run_batch(B):
        reqs = [Request(prompt=rng.randint(0, cfg.vocab, 8).astype(np.int32),
                        max_new_tokens=4) for _ in range(B)]
        t0 = time.perf_counter()
        engine.run_batch(reqs)
        return time.perf_counter() - t0

    for B in sizes:                     # warmup: trace+compile each class
        for _ in range(2):
            run_batch(B)
    st = engine.terra.stats
    eng = engine.terra._tf.engine
    base = (st["retraces"], eng.seg_cache.misses, st["replays"])
    times = [run_batch(sizes[i % len(sizes)]) for i in range(flips)]
    out = {
        "sizes": list(sizes), "flips": flips,
        "retraces": st["retraces"] - base[0],
        "segments_recompiled": eng.seg_cache.misses - base[1],
        "replays": st["replays"] - base[2],
        "families": st["families"],
        "family_switches": st["family_switches"],
        "batch_wall_ms_median": float(np.median(times) * 1e3),
        "phase": engine.terra.phase,
    }
    engine.terra.close()
    assert out["phase"] == "co-execution", "shape-flip never reached skeleton"
    assert out["retraces"] == 0, \
        f"shape flips caused {out['retraces']} retraces (want 0)"
    assert out["segments_recompiled"] == 0, \
        f"shape flips recompiled {out['segments_recompiled']} segments"
    print(f"shape_flip: {flips} flips over batch sizes {list(sizes)}: "
          f"retraces={out['retraces']} segments_recompiled="
          f"{out['segments_recompiled']} replays={out['replays']} "
          f"median batch wall {out['batch_wall_ms_median']:.1f}ms",
          flush=True)
    return out


# ==========================================================================
# --passes ablation (ISSUE 4): symbolic pass pipeline on vs off
# ==========================================================================

def _ablation_workloads():
    """REGISTRY programs plus pass-targeted workloads.  The synthetic ones
    model the async-logging / discarded-metrics patterns the pipeline
    exists for: scalar probes read late (coalescible boundaries), probe
    chains nobody reads (dead ops), repeated subexpressions over variable
    state (CSE) and iteration-constant numpy inputs (feed folding)."""
    import numpy as np
    from repro.core import Variable, ops

    def async_logging(_variant):
        rng = np.random.RandomState(11)
        w1 = Variable((rng.randn(64, 64) * 0.1).astype(np.float32), "al_w1")
        w2 = Variable((rng.randn(64, 64) * 0.1).astype(np.float32), "al_w2")
        norm = np.full((), 1.0 / 64.0, np.float32)   # constant -> folds

        def step(i):
            r = np.random.RandomState(1000 + i)
            x = r.randn(16, 64).astype(np.float32)
            h1 = ops.relu(ops.matmul(x, w1.read()))
            s1 = ops.reduce_sum(ops.mul(ops.reduce_mean(h1), norm))
            h2 = ops.relu(ops.matmul(h1, w2.read()))
            s2 = ops.reduce_sum(ops.mul(ops.reduce_mean(h2), norm))
            out = ops.reduce_sum(h2)
            # telemetry probes read AFTER all graph work is recorded: the
            # boundaries they cut are pure dispatch overhead
            logs = (float(s1), float(s2))
            return float(out) + 0.0 * sum(logs)
        return step, None

    def dead_metrics(_variant):
        rng = np.random.RandomState(12)
        w = Variable((rng.randn(64, 64) * 0.1).astype(np.float32), "dm_w")

        def step(i):
            r = np.random.RandomState(2000 + i)
            x = r.randn(16, 64).astype(np.float32)
            h = ops.relu(ops.matmul(x, w.read()))
            # discarded diagnostics: never fetched, never assigned
            _ = ops.reduce_max(ops.abs_op(ops.mul(h, 3.0)))
            _ = ops.reduce_mean(ops.square(h))
            # duplicate subexpression over variable state (CSE)
            a = ops.mul(w.read(), 2.0)
            b = ops.mul(w.read(), 2.0)
            probe = ops.reduce_sum(ops.sub(a, b))
            out = ops.reduce_sum(h)
            p = float(probe)                   # late read -> coalescible
            return float(out) + 0.0 * p
        return step, None

    wl = {name: REGISTRY[name] for name in DEFAULT_PROGRAMS + ["bert_cls"]}
    wl["async_logging"] = async_logging
    wl["dead_metrics"] = dead_metrics
    return wl


def _measure_passes_mode(make, mode: str, warmup: int, iters: int) -> dict:
    step, _ = make("terra")
    tf = terra_function(step, optimize=mode)
    values = [float(np.asarray(tf(i))) for i in range(warmup)]
    tf.wait()
    stats = tf.engine.stats
    base_seg = stats["segments_dispatched"]
    walls = []
    for i in range(warmup, warmup + iters):
        t0 = time.perf_counter()
        values.append(float(np.asarray(tf(i))))
        walls.append(time.perf_counter() - t0)
    tf.wait()
    assert tf.phase == "co-execution", f"{mode} run never converted"
    result = {
        "segments_per_iter":
            (stats["segments_dispatched"] - base_seg) / iters,
        "iter_wall_us_median": float(np.median(walls) * 1e6),
        "values": values,
        "counters": {k: stats[k] for k in
                     ("nodes_eliminated", "cse_hits", "feeds_folded",
                      "segments_coalesced", "kernels_substituted",
                      "fold_divergences", "replays")},
    }
    tf.close()
    return result


def measure_passes(warmup: int, iters: int, rounds: int = 3) -> dict:
    """Run every ablation workload with the pass pipeline on ("all") and
    off ("none"); emit per-workload segments/iter, pass counters and
    median iteration wall time, and FAIL if any workload's fetched values
    differ between the modes (the pipeline is semantics-preserving by
    contract).  Wall medians keep the best of ``rounds`` alternating
    in-process rounds — the same tail-suppression methodology as the
    headline benchmark (module docstring)."""
    out = {}
    fewer_segments = []
    for name, make in _ablation_workloads().items():
        modes = {}
        for r in range(rounds):
            order = ("all", "none") if r % 2 == 0 else ("none", "all")
            for mode in order:
                m = _measure_passes_mode(make, mode, warmup, iters)
                best = modes.get(mode)
                if best is not None:
                    m["values"] = best["values"]    # deterministic per seed
                    if m["iter_wall_us_median"] > best["iter_wall_us_median"]:
                        m = best
                modes[mode] = m
        va, vn = modes["all"].pop("values"), modes["none"].pop("values")
        if not np.allclose(va, vn, rtol=1e-4, atol=1e-5):
            bad = int(np.argmax(~np.isclose(va, vn, rtol=1e-4, atol=1e-5)))
            raise AssertionError(
                f"--passes ablation: {name} fetched values differ between "
                f"optimize=all and optimize=none at iteration {bad}: "
                f"{va[bad]} vs {vn[bad]}")
        delta = (modes["none"]["segments_per_iter"]
                 - modes["all"]["segments_per_iter"])
        if delta > 0:
            fewer_segments.append(name)
        out[name] = {
            "all": modes["all"], "none": modes["none"],
            "segments_per_iter_delta": delta,
            "wall_reduction_pct": 100.0 * (
                1.0 - modes["all"]["iter_wall_us_median"]
                / max(modes["none"]["iter_wall_us_median"], 1e-9)),
        }
        print(f"passes[{name}]: segments/iter "
              f"{modes['none']['segments_per_iter']:.1f} -> "
              f"{modes['all']['segments_per_iter']:.1f}, "
              f"eliminated={modes['all']['counters']['nodes_eliminated']} "
              f"cse={modes['all']['counters']['cse_hits']} "
              f"folded={modes['all']['counters']['feeds_folded']} "
              f"coalesced={modes['all']['counters']['segments_coalesced']} "
              f"wall {modes['none']['iter_wall_us_median']:.0f} -> "
              f"{modes['all']['iter_wall_us_median']:.0f}us", flush=True)
    assert len(fewer_segments) >= 2, (
        f"expected >=2 workloads with fewer dispatched segments under the "
        f"pass pipeline, got {fewer_segments}")
    # iteration-time gate: no workload may regress beyond scheduler noise.
    # A workload with zero pass activity compiles a bit-identical program
    # — its wall delta is noise by construction (observed swinging ±23%
    # on this shared box even with best-of-rounds medians), so the gate
    # only covers workloads the pipeline actually rewrote, with an
    # allowance wide enough for scheduler jitter but far below the
    # pathological class it exists to catch (interpret-mode kernels, a
    # probe on the hot path, per-iteration replays: 2-100x)
    active_keys = ("nodes_eliminated", "cse_hits", "feeds_folded",
                   "segments_coalesced", "kernels_substituted")
    regressed = {
        n: round(v["wall_reduction_pct"], 1) for n, v in out.items()
        if v["wall_reduction_pct"] < -25.0
        and any(v["all"]["counters"][k] for k in active_keys)}
    assert not regressed, (
        f"pass pipeline regressed median iteration time beyond the noise "
        f"allowance on: {regressed}")
    out["_fewer_segment_workloads"] = fewer_segments
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--programs", nargs="*", default=DEFAULT_PROGRAMS)
    ap.add_argument("--warmup", type=int, default=12)
    ap.add_argument("--iters", type=int, default=80)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: 2 programs, short runs, 1 round")
    ap.add_argument("--flips", type=int, default=50,
                    help="shape-flip scenario: alternating-batch flips "
                         "after warmup (0 disables)")
    ap.add_argument("--passes", action="store_true",
                    help="ISSUE 4 ablation: run every workload with the "
                         "symbolic pass pipeline on vs off; fails on any "
                         "fetched-value mismatch")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)
    if args.smoke:
        args.programs = args.programs[:2]
        args.warmup, args.iters, args.rounds = 6, 20, 1

    results = {}
    for name in args.programs:
        r = measure(name, args.warmup, args.iters, args.rounds)
        results[name] = r
        print(f"{name}: py_overhead={r['py_overhead_us_median']:.1f}us/iter "
              f"(stall {r['py_stall_us_median']:.1f} + dispatch "
              f"{r['dispatch_us_median']:.1f}), wall "
              f"{r['wall_us_median']:.0f}us, fast_hits/iter "
              f"{r['walker_fast_hits'] / r['iters']:.1f}", flush=True)
        assert r["phase"] == "co-execution", f"{name} never reached skeleton"
        if name not in NON_CONVERTIBLE and r["feeds_defaulted"]:
            # zeros substitution is only legitimate for untaken regions of
            # branchy programs — a linear covered program defaulting a feed
            # means the Walker failed to collect a value it validated
            raise AssertionError(
                f"{name}: {r['feeds_defaulted']} Input Feeding values "
                f"silently defaulted to zeros on a covered linear program")

    report = {
        "meta": {
            "metric": "py_stall_time + dispatch_time, median us/iter at "
                      "steady state (see module docstring)",
            "warmup": args.warmup, "iters": args.iters,
            "rounds": args.rounds, "smoke": bool(args.smoke),
        },
        "programs": results,
    }
    if args.flips:
        # ISSUE 3 gate: alternating batch sizes decode through shape-keyed
        # TraceGraph families with zero retraces / recompiles after warmup
        report["shape_flip"] = measure_shape_flip(flips=args.flips)
    if args.passes:
        # ISSUE 4 gate: the pass pipeline preserves every fetched value
        # and at least two workloads dispatch fewer segments per iteration
        report["passes_ablation"] = measure_passes(
            warmup=max(6, args.warmup // 2), iters=args.iters,
            rounds=args.rounds)

    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)
        comparison, reductions = {}, []
        for name, r in results.items():
            b = baseline.get("programs", {}).get(name)
            if not b:
                continue
            red = 100.0 * (1.0 - r["py_overhead_us_median"]
                           / b["py_overhead_us_median"])
            comparison[name] = {
                "baseline_py_overhead_us": b["py_overhead_us_median"],
                "current_py_overhead_us": r["py_overhead_us_median"],
                "reduction_pct": red,
            }
            reductions.append(red)
        report["baseline"] = {"source": baseline.get("meta", {}),
                              "path": args.baseline}
        report["comparison"] = comparison
        if reductions:
            report["mean_reduction_pct"] = float(np.mean(reductions))
            print(f"mean steady-state Python-overhead reduction vs "
                  f"pre-change baseline: {report['mean_reduction_pct']:.1f}%"
                  f" (gate: >= 25%)")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
