"""Hot-path benchmark: steady-state per-iteration Python overhead.

Measures, per benchmark program, what the skeleton phase costs the Python
thread each iteration once the engine is in steady-state co-execution:

* ``py_stall_us``    — time blocked at Output Fetching / per-value fences
                       (``engine.stats["py_stall_time"]``),
* ``dispatch_us``    — Python-thread time spent in segment dispatch
                       (``engine.stats["dispatch_time"]``),
* ``py_overhead_us`` — their sum: the interpreter-overhead class the paper's
                       speedup claim depends on keeping off the critical
                       path (ISSUE 2; JANUS / TF-Eager interpreter gap),
* GraphRunner occupancy (``runner_exec_time`` / ``runner_stall_time``) and
  the hot-path counters (``walker_fast_hits``, ``feeds_defaulted``).

Per-iteration samples are collected individually; the headline statistic is
the **median** (steady-state cost — the mean is dominated by GC pauses and
OS scheduling tails on a shared machine, which hit pre- and post-change
code alike).  Each cell runs ``--rounds`` times in-process and keeps the
round with the lowest median overhead.

The ``shape_flip`` section (ISSUE 3) drives serving decode with
alternating batch sizes through the shape-keyed TraceGraph families and
asserts zero ``retraces`` / ``segments_recompiled`` across the flips after
one trace+compile per shape class.

Writes ``BENCH_hotpath.json``.  If a baseline file exists
(``benchmarks/baseline_hotpath.json`` — measured at the pre-change commit
with this same methodology), a per-program and mean reduction is reported;
the ISSUE 2 gate is ``mean_reduction_pct >= 25`` over the fig5 programs.

Usage:
    python -m benchmarks.bench_hotpath [--smoke] [--out BENCH_hotpath.json]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.programs import NON_CONVERTIBLE, REGISTRY
from repro.core import function as terra_function

DEFAULT_PROGRAMS = ["resnet", "gpt2", "bert_qa", "fasterrcnn"]
BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "baseline_hotpath.json")


def measure_once(name: str, warmup: int, iters: int) -> dict:
    step, _ = REGISTRY[name]("terra")
    tf = terra_function(step)
    for i in range(warmup):
        tf(i)
    tf.wait()
    eng = tf.engine
    stats = eng.stats
    base_counters = {k: stats[k] for k in
                     ("walker_fast_hits", "feeds_defaulted",
                      "segments_dispatched", "replays")}
    base_runner = (stats["runner_exec_time"], stats["runner_stall_time"])
    samples = []
    prev = (stats["py_stall_time"], stats["dispatch_time"])
    for i in range(warmup, warmup + iters):
        t0 = time.perf_counter()
        tf(i)
        wall = time.perf_counter() - t0
        cur = (stats["py_stall_time"], stats["dispatch_time"])
        samples.append((wall, cur[0] - prev[0], cur[1] - prev[1]))
        prev = cur
    tf.wait()
    a = np.asarray(samples) * 1e6
    overhead = a[:, 1] + a[:, 2]
    out = {
        "iters": iters,
        "phase": tf.phase,
        "wall_us_median": float(np.median(a[:, 0])),
        "wall_us_mean": float(a[:, 0].mean()),
        "py_stall_us_median": float(np.median(a[:, 1])),
        "dispatch_us_median": float(np.median(a[:, 2])),
        "py_overhead_us_median": float(np.median(overhead)),
        "py_overhead_us_mean": float(overhead.mean()),
        "runner_exec_us_per_iter":
            (stats["runner_exec_time"] - base_runner[0]) / iters * 1e6,
        "runner_stall_us_per_iter":
            (stats["runner_stall_time"] - base_runner[1]) / iters * 1e6,
    }
    for k, v in base_counters.items():
        out[k] = stats[k] - v
    tf.close()
    return out


def measure(name: str, warmup: int, iters: int, rounds: int) -> dict:
    best = None
    for _ in range(rounds):
        r = measure_once(name, warmup, iters)
        if best is None or (r["py_overhead_us_median"]
                            < best["py_overhead_us_median"]):
            best = r
    return best


def measure_shape_flip(flips: int = 50, sizes=(4, 8)) -> dict:
    """Serving decode with alternating batch sizes (ISSUE 3 acceptance):
    after one trace + compile per shape class, every batch-size flip must
    be a TraceGraph-family lookup — zero retraces, zero segment
    recompiles, zero divergences across ``flips`` flips."""
    import jax
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=48)
    rng = np.random.RandomState(0)

    def run_batch(B):
        reqs = [Request(prompt=rng.randint(0, cfg.vocab, 8).astype(np.int32),
                        max_new_tokens=4) for _ in range(B)]
        t0 = time.perf_counter()
        engine.run_batch(reqs)
        return time.perf_counter() - t0

    for B in sizes:                     # warmup: trace+compile each class
        for _ in range(2):
            run_batch(B)
    st = engine.terra.stats
    eng = engine.terra._tf.engine
    base = (st["retraces"], eng.seg_cache.misses, st["replays"])
    times = [run_batch(sizes[i % len(sizes)]) for i in range(flips)]
    out = {
        "sizes": list(sizes), "flips": flips,
        "retraces": st["retraces"] - base[0],
        "segments_recompiled": eng.seg_cache.misses - base[1],
        "replays": st["replays"] - base[2],
        "families": st["families"],
        "family_switches": st["family_switches"],
        "batch_wall_ms_median": float(np.median(times) * 1e3),
        "phase": engine.terra.phase,
    }
    engine.terra.close()
    assert out["phase"] == "co-execution", "shape-flip never reached skeleton"
    assert out["retraces"] == 0, \
        f"shape flips caused {out['retraces']} retraces (want 0)"
    assert out["segments_recompiled"] == 0, \
        f"shape flips recompiled {out['segments_recompiled']} segments"
    print(f"shape_flip: {flips} flips over batch sizes {list(sizes)}: "
          f"retraces={out['retraces']} segments_recompiled="
          f"{out['segments_recompiled']} replays={out['replays']} "
          f"median batch wall {out['batch_wall_ms_median']:.1f}ms",
          flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--programs", nargs="*", default=DEFAULT_PROGRAMS)
    ap.add_argument("--warmup", type=int, default=12)
    ap.add_argument("--iters", type=int, default=80)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI profile: 2 programs, short runs, 1 round")
    ap.add_argument("--flips", type=int, default=50,
                    help="shape-flip scenario: alternating-batch flips "
                         "after warmup (0 disables)")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)
    if args.smoke:
        args.programs = args.programs[:2]
        args.warmup, args.iters, args.rounds = 6, 20, 1

    results = {}
    for name in args.programs:
        r = measure(name, args.warmup, args.iters, args.rounds)
        results[name] = r
        print(f"{name}: py_overhead={r['py_overhead_us_median']:.1f}us/iter "
              f"(stall {r['py_stall_us_median']:.1f} + dispatch "
              f"{r['dispatch_us_median']:.1f}), wall "
              f"{r['wall_us_median']:.0f}us, fast_hits/iter "
              f"{r['walker_fast_hits'] / r['iters']:.1f}", flush=True)
        assert r["phase"] == "co-execution", f"{name} never reached skeleton"
        if name not in NON_CONVERTIBLE and r["feeds_defaulted"]:
            # zeros substitution is only legitimate for untaken regions of
            # branchy programs — a linear covered program defaulting a feed
            # means the Walker failed to collect a value it validated
            raise AssertionError(
                f"{name}: {r['feeds_defaulted']} Input Feeding values "
                f"silently defaulted to zeros on a covered linear program")

    report = {
        "meta": {
            "metric": "py_stall_time + dispatch_time, median us/iter at "
                      "steady state (see module docstring)",
            "warmup": args.warmup, "iters": args.iters,
            "rounds": args.rounds, "smoke": bool(args.smoke),
        },
        "programs": results,
    }
    if args.flips:
        # ISSUE 3 gate: alternating batch sizes decode through shape-keyed
        # TraceGraph families with zero retraces / recompiles after warmup
        report["shape_flip"] = measure_shape_flip(flips=args.flips)

    if os.path.exists(args.baseline):
        with open(args.baseline) as f:
            baseline = json.load(f)
        comparison, reductions = {}, []
        for name, r in results.items():
            b = baseline.get("programs", {}).get(name)
            if not b:
                continue
            red = 100.0 * (1.0 - r["py_overhead_us_median"]
                           / b["py_overhead_us_median"])
            comparison[name] = {
                "baseline_py_overhead_us": b["py_overhead_us_median"],
                "current_py_overhead_us": r["py_overhead_us_median"],
                "reduction_pct": red,
            }
            reductions.append(red)
        report["baseline"] = {"source": baseline.get("meta", {}),
                              "path": args.baseline}
        report["comparison"] = comparison
        if reductions:
            report["mean_reduction_pct"] = float(np.mean(reductions))
            print(f"mean steady-state Python-overhead reduction vs "
                  f"pre-change baseline: {report['mean_reduction_pct']:.1f}%"
                  f" (gate: >= 25%)")

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    return report


if __name__ == "__main__":
    main()
