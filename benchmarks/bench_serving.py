"""Serving benchmark: continuous batching vs lock-step vs Terra-off.

A mixed-length, Poisson-arrival workload is served three ways:

* ``scheduler_terra``   — serve/scheduler/ continuous batching, decode
                          loop under Terra co-execution (the system);
* ``scheduler_noterra`` — the same scheduler with ``use_terra=False``
                          (plain donated jax.jit steps): what co-execution
                          itself is worth at equal scheduling policy;
* ``lockstep``          — ServingEngine.run_batch, greedy same-length
                          batch formation in arrival order, each batch
                          drained to its slowest request (the pre-ISSUE-5
                          serving shape).

A fourth arm, ``paged_highconc`` (ISSUE 7), serves a burst of short
requests through the paged KV cache with a block arena HALF the size of
the dense pool's memory — concurrency the dense layout cannot reach at
equal memory — and checks exact token equality against a dense run.

Reported per arm: tokens/s, TTFT (time to first token) and per-request
latency p50/p95, the co-execution counters, and a per-step overhead
breakdown (dispatch time, fetch-wait time, runner occupancy, residual
Python) derived from a :class:`TimingProcessor` attached to the
scheduler's EventStream (DESIGN.md §13) during a traced re-run — the
measured trials themselves stay counters-only, the deployment
configuration.  The terra arm's traced re-run also exports the full
event stream as ``trace.jsonl`` (schema-validated, uploaded by CI).
Gates:

* token equality — for an identical fixed request set the scheduler's
  output tokens match lock-step decode exactly (equal quality);
* ``tokens_per_s(scheduler_terra) >= tokens_per_s(scheduler_noterra)``
  — co-execution costs nothing at serving steady state (ISSUE 7; hard
  gate in smoke and full runs);
* the full event stream (timing + request traces + JSONL export)
  costs at most 2 % tokens/s vs counters-only on the terra arm
  (hard gate in smoke and full runs);
* ``tokens_per_s(scheduler_terra) >= 1.5 * tokens_per_s(lockstep)``
  (full-run only);
* after warmup, slot churn causes zero ``retraces`` and the family map
  holds at most 2 shape classes;
* the paged arm's peak concurrency exceeds the dense-equivalent slot
  count for the same memory, with zero post-warmup retraces and tokens
  identical to the dense pool.

Writes ``BENCH_serving.json`` (CI uploads it as an artifact alongside
the hot-path ablation and the event trace).

Usage:
    python -m benchmarks.bench_serving [--smoke] [--out BENCH_serving.json]
                                       [--trace-out trace.jsonl]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.core.events import (JsonlSink, RequestTraceProcessor,
                               TimingProcessor)
from repro.core.events.schema import validate_jsonl
from repro.models import model as M
from repro.obs import Histogram, MetricsProcessor, TraceViewerExporter
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import ContinuousBatchingScheduler

# sampled device-time attribution cadence for the traced re-runs: every
# PROFILE_EVERY-th engine iteration blocks on the segment's outputs on
# the runner thread (DESIGN.md §15); part of the ≥0.98x tracing gate
PROFILE_EVERY = 8


def build_workload(cfg, seed, n, mean_gap_s, lens, max_new_lo, max_new_hi):
    """(arrival_offset, prompt, max_new) triples; Poisson arrivals."""
    rng = np.random.RandomState(seed)
    offsets = np.cumsum(rng.exponential(mean_gap_s, size=n))
    out = []
    for i in range(n):
        L = int(rng.choice(lens))
        out.append((float(offsets[i]),
                    rng.randint(0, cfg.vocab, L).astype(np.int32),
                    int(rng.randint(max_new_lo, max_new_hi + 1))))
    return out


def make_requests(workload, t0):
    return [Request(prompt=p, max_new_tokens=mn, arrival_time=t0 + off)
            for off, p, mn in workload]


def summarize(requests, wall):
    """Latency summary through the same streaming log-bucketed histograms
    a live serving process exposes (repro.obs.metrics; one percentile
    path for benches and production, ±2.5 % bucket error by contract).
    The mean stays exact — histograms track the true sum/count."""
    ttft, lat = Histogram(), Histogram()
    for r in requests:
        ttft.observe((r.first_token_time - r.arrival_time) * 1e3)
        lat.observe((r.finish_time - r.arrival_time) * 1e3)
    toks = sum(len(r.out_tokens) for r in requests)
    return {
        "requests": len(requests),
        "generated_tokens": toks,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(toks / wall, 2),
        "ttft_ms": {"mean": round(ttft.mean, 2),
                    "p50": round(ttft.percentile(50), 2),
                    "p95": round(ttft.percentile(95), 2)},
        "latency_ms": {"p50": round(lat.percentile(50), 2),
                       "p95": round(lat.percentile(95), 2)},
    }


# --------------------------------------------------------------------------
# Arms
# --------------------------------------------------------------------------

def _pow2_sizes(n):
    k, out = 1, []
    while k <= n:
        out.append(k)
        k <<= 1
    return out


def _warm_requests(cfg, bucket, k):
    # max_new=4 gives every warmed shape class >= 3 decode iterations:
    # enough to trace twice, compile, and reach co-execution, so no
    # segment compile can land inside the timed run
    rng = np.random.RandomState(bucket * 131 + k)
    return [Request(prompt=rng.randint(0, cfg.vocab, bucket)
                    .astype(np.int32), max_new_tokens=4, arrival_time=0.0)
            for _ in range(k)]


def make_scheduler(cfg, params, workload, *, max_slots, max_len, use_terra,
                   **sched_kw):
    """Build a scheduler and warm every (group size, length bucket) shape
    the workload can produce — compile caches are engine-lifetime state
    in a real serving deployment, so warmup is not part of the measured
    steady-state cost (same treatment as bench_hotpath)."""
    sch = ContinuousBatchingScheduler(cfg, params, max_slots=max_slots,
                                      max_len=max_len, use_terra=use_terra,
                                      **sched_kw)
    for bucket in sorted({len(p) for _, p, _ in workload}):
        for k in _pow2_sizes(max_slots):
            sch.serve(_warm_requests(cfg, bucket, k))
    return sch


def _one_trial(sch, workload):
    stats0 = dict(sch.stats)
    t0 = time.perf_counter()
    reqs = make_requests(workload, t0)
    sch.serve(reqs)
    return reqs, time.perf_counter() - t0, stats0, dict(sch.stats)


def run_scheduler(sch, workload, trials=5, trace_path=None):
    """Serve the workload both counters-only (the deployment
    configuration) and with the full observability stack attached —
    structured events, request traces, JSONL export, live metrics
    registry, Chrome/Perfetto timeline export, and sampled device-time
    profiling (``PROFILE_EVERY``) — interleaved per round, alternating
    which goes first, so machine drift and any within-round warmth hit
    both configurations equally; report the best-throughput trial of
    each — the steady-state estimator.  The TimingProcessor supplies the
    host-overhead breakdown, and the best-vs-best throughput ratio is
    the ≤2 % profiling/tracing-cost gate (DESIGN.md §15)."""
    timing = TimingProcessor()
    metrics = MetricsProcessor()
    extras = [metrics]
    viewer = None
    if trace_path:
        open(trace_path, "w").close()       # truncate any stale artifact
        viewer = TraceViewerExporter(trace_path + ".trace.json")
        extras += [RequestTraceProcessor(), JsonlSink(trace_path), viewer]
    can_profile = getattr(sch, "use_terra", False)
    best = tbest = None
    for i in range(max(1, trials)):
        for with_events in ((False, True) if i % 2 == 0 else (True, False)):
            if not with_events:
                trial = _one_trial(sch, workload)
                if best is None or trial[1] < best[1]:
                    best = trial
                continue
            timing.reset()                  # breakdown = winning window
            procs = [sch.events.attach(p) for p in [timing] + extras]
            if can_profile:
                sch.set_profile(PROFILE_EVERY)
            try:
                traced = _one_trial(sch, workload)
            finally:
                if can_profile:
                    sch.set_profile(0)
                for p in procs:
                    sch.events.detach(p)
            if tbest is None or traced[1] < tbest[1]:
                tbest = (traced[0], traced[1], timing.summary())
    for p in extras:
        p.close()                   # flushes the JSONL sink + trace export
    reqs, wall, stats0, st = best
    out = summarize(reqs, wall)
    if sch.use_terra:
        out["coexec"] = {
            "phase": st["phase"],
            "retraces_post_warmup": st["retraces"] - stats0["retraces"],
            "families": st["families"],
            "replays": st["replays"],
            "walker_fast_hits": st["walker_fast_hits"],
            "steady_iters": st["steady_iters"] - stats0["steady_iters"],
            "steady_exits": st["steady_exits"] - stats0["steady_exits"],
        }
    out["sched"] = {k: st[k] for k in ("admitted", "retired", "decode_steps",
                                       "prefill_steps", "prefill_tokens",
                                       "peak_resident_tokens")}
    treqs, twall, ov = tbest
    traced = summarize(treqs, twall)
    ov["other_py_ms"] = round(
        (twall - ov.pop("dispatch_s") - ov.pop("fetch_wait_s")) * 1e3, 3)
    out["overhead"] = ov
    snap = metrics.registry.snapshot()
    prof = snap["histograms"].get("segment_device_us", {"count": 0})
    out["tracing"] = {
        "tokens_per_s": traced["tokens_per_s"],
        "ratio_vs_counters_only": round(
            traced["tokens_per_s"] / out["tokens_per_s"], 4),
        "trace": trace_path,
        "perfetto": viewer.path if viewer is not None else None,
        "profile_every": PROFILE_EVERY if can_profile else 0,
        "device_samples": prof["count"],
        "metrics": {k: {kk: round(vv, 3) for kk, vv in h.items()}
                    for k, h in snap["histograms"].items()},
    }
    return out


def run_paged_arm(cfg, params, *, smoke, seed=7):
    """High-concurrency burst through the paged pool: the block arena is
    HALF the dense pool's memory (``capacity_tokens = max_slots*max_len/2``)
    yet the burst runs more requests concurrently than a dense pool of
    that same memory could hold rows for.  Token equality is checked
    against a dense-pool run of the identical request set."""
    max_slots, max_len, page = (8, 64, 16) if smoke else (32, 64, 16)
    num_blocks = (max_slots * max_len // 2) // page + 1
    rng = np.random.RandomState(seed)
    n = max_slots + 4 if smoke else 200     # oversubscribe: most must queue
    lens = ([8] * n if smoke else
            [int(rng.choice((8, 16))) for _ in range(n)])
    mns = ([8] * n if smoke else
            [int(rng.randint(4, 13)) for _ in range(n)])
    workload = [(0.0, p.prompt, mns[i]) for i, p in
                enumerate(make_fixed(cfg, lens, mns, seed))]
    paged = make_scheduler(cfg, params, workload, max_slots=max_slots,
                           max_len=max_len, use_terra=True,
                           page_size=page, num_blocks=num_blocks)
    stats0 = dict(paged.stats)
    peaks = [0]
    reqs = make_fixed(cfg, lens, mns, seed,
                      stream=lambda r, t, i: peaks.append(
                          paged.pool.active_count))
    t0 = time.perf_counter()
    paged.serve(reqs)
    wall = time.perf_counter() - t0
    out = summarize(reqs, wall)
    st = paged.stats
    out["coexec"] = {
        "phase": st["phase"],
        "retraces_post_warmup": st["retraces"] - stats0["retraces"],
        "families": st["families"],
        "steady_iters": st["steady_iters"] - stats0["steady_iters"],
    }
    paged.close()
    dense = ContinuousBatchingScheduler(cfg, params, max_slots=max_slots,
                                        max_len=max_len)
    dref = make_fixed(cfg, lens, mns, seed)
    dense.serve(dref)
    dense.close()
    mism = [i for i, (x, y) in enumerate(zip(reqs, dref))
            if x.out_tokens != y.out_tokens]
    cap_tokens = (num_blocks - 1) * page
    out["paged"] = {
        "page_size": page, "num_blocks": num_blocks,
        "capacity_tokens": cap_tokens,
        "dense_equiv_slots": cap_tokens // max_len,
        "peak_concurrent": int(max(peaks)),
        "peak_resident_tokens": st["peak_resident_tokens"],
        "equal_vs_dense": not mism, "mismatches": mism,
    }
    return out


def make_fixed(cfg, lens, mns, seed, **kw):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, cfg.vocab, L).astype(np.int32),
                    max_new_tokens=mn, arrival_time=0.0, **kw)
            for L, mn in zip(lens, mns)]


def make_lockstep(cfg, params, workload, *, max_slots, max_len):
    """Lock-step baseline engine, batch shapes pre-warmed.  Batches are
    padded to power-of-two sizes (bucket_batches) so the greedy batch
    former's shape space is as small as the scheduler's."""
    eng = ServingEngine(cfg, params, max_len=max_len, bucket_batches=True)
    for L in sorted({len(p) for _, p, _ in workload}):
        for k in _pow2_sizes(max_slots):
            eng.run_batch(_warm_requests(cfg, L, k))
    return eng


def run_lockstep(eng, workload, *, max_slots):
    t0 = time.perf_counter()
    reqs = make_requests(workload, t0)
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    while pending:
        now = time.perf_counter()
        ready = [r for r in pending if r.arrival_time <= now]
        if not ready:
            time.sleep(max(0.0, pending[0].arrival_time - now))
            continue
        # greedy same-length batch in arrival order (run_batch rejects
        # ragged prompts); the batch then drains to its slowest member
        L = len(ready[0].prompt)
        batch = [r for r in ready if len(r.prompt) == L][:max_slots]
        taken = {id(r) for r in batch}
        pending = [r for r in pending if id(r) not in taken]
        eng.run_batch(batch)
    wall = time.perf_counter() - t0
    out = summarize(reqs, wall)
    out["engine_stats"] = {k: round(v, 4) if isinstance(v, float) else v
                           for k, v in eng.stats.items()}
    return out


def check_equality(sch, eng, workload, *, max_slots):
    """Equal quality: identical fixed request set (all arrived at t=0),
    scheduler tokens == lock-step tokens, request by request."""
    fixed = [(0.0, p, mn) for _, p, mn in workload]
    a = make_requests(fixed, 0.0)
    sch.serve(a)
    b = make_requests(fixed, 0.0)
    by_len = {}
    for r in b:
        by_len.setdefault(len(r.prompt), []).append(r)
    for group in by_len.values():
        for i in range(0, len(group), max_slots):
            eng.run_batch(group[i:i + max_slots])
    mism = [i for i, (x, y) in enumerate(zip(a, b))
            if x.out_tokens != y.out_tokens]
    return {"checked": len(a), "mismatches": mism, "equal": not mism}


# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; the equality and "
                         "shape-stability gates still hard-fail, only "
                         "the 1.5x speedup gate is full-run-only")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--trace-out", default="trace.jsonl",
                    help="JSONL event-trace artifact from the terra arm's "
                         "traced re-run (schema-validated; '' disables)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.smoke:
        # decode-heavy even in smoke: the terra-vs-noterra gate measures
        # steady-state decode overhead, which a prefill-dominated burst
        # would bury in compile-adjacent noise
        knobs = dict(max_slots=4, max_len=64)
        mean_gap = 0.005
        workload = build_workload(cfg, args.seed, n=12, mean_gap_s=mean_gap,
                                  lens=(8, 16), max_new_lo=8, max_new_hi=24)
    else:
        # heavy mixed traffic: high decode-budget variance is exactly what
        # lock-step batching is worst at (every batch drains to its
        # slowest member while finished rows burn decode steps)
        knobs = dict(max_slots=8, max_len=128)
        mean_gap = 0.003
        workload = build_workload(cfg, args.seed, n=40, mean_gap_s=mean_gap,
                                  lens=(8, 16, 32), max_new_lo=4,
                                  max_new_hi=80)

    arms = {}
    sch = make_scheduler(cfg, params, workload, use_terra=True, **knobs)
    arms["scheduler_terra"] = run_scheduler(sch, workload,
                                            trace_path=args.trace_out or None)
    sch2 = make_scheduler(cfg, params, workload, use_terra=False, **knobs)
    arms["scheduler_noterra"] = run_scheduler(sch2, workload)
    sch2.close()
    eng = make_lockstep(cfg, params, workload, **knobs)
    arms["lockstep"] = run_lockstep(eng, workload,
                                    max_slots=knobs["max_slots"])
    equality = check_equality(sch, eng, workload,
                              max_slots=knobs["max_slots"])
    sch.close()
    if eng.terra is not None:
        eng.terra.close()
    arms["paged_highconc"] = run_paged_arm(cfg, params, smoke=args.smoke)

    speedup = (arms["scheduler_terra"]["tokens_per_s"]
               / arms["lockstep"]["tokens_per_s"])
    vs_noterra = (arms["scheduler_terra"]["tokens_per_s"]
                  / arms["scheduler_noterra"]["tokens_per_s"])
    coexec = arms["scheduler_terra"]["coexec"]
    paged = arms["paged_highconc"]["paged"]
    tracing = arms["scheduler_terra"]["tracing"]
    trace_counts = (validate_jsonl(args.trace_out) if args.trace_out
                    else {})
    gates = {
        "token_equality": equality["equal"],
        "speedup_vs_lockstep": round(speedup, 3),
        "speedup_gate_1.5x": speedup >= 1.5,
        "terra_vs_noterra": round(vs_noterra, 3),
        "terra_ge_noterra": vs_noterra >= 1.0,
        "tracing_ratio": tracing["ratio_vs_counters_only"],
        "tracing_cost_le_2pct": tracing["ratio_vs_counters_only"] >= 0.98,
        "retraces_post_warmup": coexec["retraces_post_warmup"],
        "families": coexec["families"],
        "shape_stable": (coexec["retraces_post_warmup"] == 0
                         and coexec["families"] <= 2),
        "paged_equal_vs_dense": paged["equal_vs_dense"],
        "paged_beyond_dense_capacity": (
            paged["peak_concurrent"] > paged["dense_equiv_slots"]),
        "paged_retraces_post_warmup":
            arms["paged_highconc"]["coexec"]["retraces_post_warmup"],
    }
    report = {
        "arch": cfg.name, "smoke": args.smoke, "knobs": knobs,
        "workload": {"requests": len(workload),
                     "mean_gap_s": mean_gap,
                     "prompt_lens": sorted({len(p) for _, p, _ in workload}),
                     "total_budget_tokens": sum(mn for _, _, mn in workload)},
        "arms": arms, "equality": equality, "gates": gates,
        "trace": {"path": args.trace_out or None,
                  "events": sum(trace_counts.values()),
                  "by_type": trace_counts},
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    failures = []
    if not equality["equal"]:
        failures.append(f"token mismatch at requests {equality['mismatches']}")
    if not gates["shape_stable"]:
        failures.append(f"slot churn not shape-stable: {coexec}")
    if not gates["terra_ge_noterra"]:
        failures.append(f"co-execution overhead visible: terra is "
                        f"{vs_noterra:.3f}x of noterra (< 1.0)")
    if not gates["tracing_cost_le_2pct"]:
        failures.append(
            f"full event stream costs more than 2% tokens/s: traced run "
            f"is {tracing['ratio_vs_counters_only']:.4f}x of counters-only")
    if not gates["paged_equal_vs_dense"]:
        failures.append(f"paged tokens diverge from dense at requests "
                        f"{paged['mismatches']}")
    if not gates["paged_beyond_dense_capacity"]:
        failures.append(
            f"paged peak concurrency {paged['peak_concurrent']} did not "
            f"exceed dense-equivalent {paged['dense_equiv_slots']} slots")
    if gates["paged_retraces_post_warmup"] != 0:
        failures.append("paged arm retraced after warmup")
    if not args.smoke and not gates["speedup_gate_1.5x"]:
        failures.append(f"speedup {speedup:.2f}x < 1.5x")
    if failures:
        raise SystemExit("bench_serving FAILED: " + "; ".join(failures))
    print(f"bench_serving OK: {speedup:.2f}x vs lockstep, "
          f"{vs_noterra:.2f}x vs noterra, tracing "
          f"{tracing['ratio_vs_counters_only']:.3f}x, "
          f"retraces={coexec['retraces_post_warmup']}, "
          f"families={coexec['families']}, paged peak "
          f"{paged['peak_concurrent']}/{paged['dense_equiv_slots']} "
          f"dense-equiv slots")


if __name__ == "__main__":
    main()
