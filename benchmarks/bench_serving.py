"""Serving benchmark: continuous batching vs lock-step vs Terra-off.

A mixed-length, Poisson-arrival workload is served three ways:

* ``scheduler_terra``   — serve/scheduler/ continuous batching, decode
                          loop under Terra co-execution (the system);
* ``scheduler_noterra`` — the same scheduler with ``use_terra=False``
                          (plain donated jax.jit steps): what co-execution
                          itself is worth at equal scheduling policy;
* ``lockstep``          — ServingEngine.run_batch, greedy same-length
                          batch formation in arrival order, each batch
                          drained to its slowest request (the pre-ISSUE-5
                          serving shape).

Reported per arm: tokens/s, TTFT (time to first token) and per-request
latency p50/p95, plus the co-execution counters.  Gates (non-smoke,
ISSUE 5 acceptance):

* token equality — for an identical fixed request set the scheduler's
  output tokens match lock-step decode exactly (equal quality);
* ``tokens_per_s(scheduler_terra) >= 1.5 * tokens_per_s(lockstep)``;
* after warmup, slot churn causes zero ``retraces`` and the family map
  holds at most 2 shape classes.

Writes ``BENCH_serving.json`` (CI uploads it as an artifact alongside
the hot-path ablation).

Usage:
    python -m benchmarks.bench_serving [--smoke] [--out BENCH_serving.json]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import ContinuousBatchingScheduler


def build_workload(cfg, seed, n, mean_gap_s, lens, max_new_lo, max_new_hi):
    """(arrival_offset, prompt, max_new) triples; Poisson arrivals."""
    rng = np.random.RandomState(seed)
    offsets = np.cumsum(rng.exponential(mean_gap_s, size=n))
    out = []
    for i in range(n):
        L = int(rng.choice(lens))
        out.append((float(offsets[i]),
                    rng.randint(0, cfg.vocab, L).astype(np.int32),
                    int(rng.randint(max_new_lo, max_new_hi + 1))))
    return out


def make_requests(workload, t0):
    return [Request(prompt=p, max_new_tokens=mn, arrival_time=t0 + off)
            for off, p, mn in workload]


def summarize(requests, wall):
    ttft = np.asarray([r.first_token_time - r.arrival_time
                       for r in requests])
    lat = np.asarray([r.finish_time - r.arrival_time for r in requests])
    toks = sum(len(r.out_tokens) for r in requests)
    return {
        "requests": len(requests),
        "generated_tokens": toks,
        "wall_s": round(wall, 4),
        "tokens_per_s": round(toks / wall, 2),
        "ttft_ms": {"mean": round(float(ttft.mean() * 1e3), 2),
                    "p50": round(float(np.percentile(ttft, 50) * 1e3), 2),
                    "p95": round(float(np.percentile(ttft, 95) * 1e3), 2)},
        "latency_ms": {"p50": round(float(np.percentile(lat, 50) * 1e3), 2),
                       "p95": round(float(np.percentile(lat, 95) * 1e3), 2)},
    }


# --------------------------------------------------------------------------
# Arms
# --------------------------------------------------------------------------

def _pow2_sizes(n):
    k, out = 1, []
    while k <= n:
        out.append(k)
        k <<= 1
    return out


def _warm_requests(cfg, bucket, k):
    # max_new=4 gives every warmed shape class >= 3 decode iterations:
    # enough to trace twice, compile, and reach co-execution, so no
    # segment compile can land inside the timed run
    rng = np.random.RandomState(bucket * 131 + k)
    return [Request(prompt=rng.randint(0, cfg.vocab, bucket)
                    .astype(np.int32), max_new_tokens=4, arrival_time=0.0)
            for _ in range(k)]


def make_scheduler(cfg, params, workload, *, max_slots, max_len, use_terra):
    """Build a scheduler and warm every (group size, length bucket) shape
    the workload can produce — compile caches are engine-lifetime state
    in a real serving deployment, so warmup is not part of the measured
    steady-state cost (same treatment as bench_hotpath)."""
    sch = ContinuousBatchingScheduler(cfg, params, max_slots=max_slots,
                                      max_len=max_len, use_terra=use_terra)
    for bucket in sorted({len(p) for _, p, _ in workload}):
        for k in _pow2_sizes(max_slots):
            sch.serve(_warm_requests(cfg, bucket, k))
    return sch


def run_scheduler(sch, workload, stats0):
    t0 = time.perf_counter()
    reqs = make_requests(workload, t0)
    sch.serve(reqs)
    wall = time.perf_counter() - t0
    out = summarize(reqs, wall)
    st = sch.stats
    if sch.use_terra:
        out["coexec"] = {
            "phase": st["phase"],
            "retraces_post_warmup": st["retraces"] - stats0["retraces"],
            "families": st["families"],
            "replays": st["replays"],
            "walker_fast_hits": st["walker_fast_hits"],
        }
    out["sched"] = {k: st[k] for k in ("admitted", "retired", "decode_steps",
                                       "prefill_steps", "prefill_tokens")}
    return out


def make_lockstep(cfg, params, workload, *, max_slots, max_len):
    """Lock-step baseline engine, batch shapes pre-warmed.  Batches are
    padded to power-of-two sizes (bucket_batches) so the greedy batch
    former's shape space is as small as the scheduler's."""
    eng = ServingEngine(cfg, params, max_len=max_len, bucket_batches=True)
    for L in sorted({len(p) for _, p, _ in workload}):
        for k in _pow2_sizes(max_slots):
            eng.run_batch(_warm_requests(cfg, L, k))
    return eng


def run_lockstep(eng, workload, *, max_slots):
    t0 = time.perf_counter()
    reqs = make_requests(workload, t0)
    pending = sorted(reqs, key=lambda r: r.arrival_time)
    while pending:
        now = time.perf_counter()
        ready = [r for r in pending if r.arrival_time <= now]
        if not ready:
            time.sleep(max(0.0, pending[0].arrival_time - now))
            continue
        # greedy same-length batch in arrival order (run_batch rejects
        # ragged prompts); the batch then drains to its slowest member
        L = len(ready[0].prompt)
        batch = [r for r in ready if len(r.prompt) == L][:max_slots]
        taken = {id(r) for r in batch}
        pending = [r for r in pending if id(r) not in taken]
        eng.run_batch(batch)
    wall = time.perf_counter() - t0
    out = summarize(reqs, wall)
    out["engine_stats"] = {k: round(v, 4) if isinstance(v, float) else v
                           for k, v in eng.stats.items()}
    return out


def check_equality(sch, eng, workload, *, max_slots):
    """Equal quality: identical fixed request set (all arrived at t=0),
    scheduler tokens == lock-step tokens, request by request."""
    fixed = [(0.0, p, mn) for _, p, mn in workload]
    a = make_requests(fixed, 0.0)
    sch.serve(a)
    b = make_requests(fixed, 0.0)
    by_len = {}
    for r in b:
        by_len.setdefault(len(r.prompt), []).append(r)
    for group in by_len.values():
        for i in range(0, len(group), max_slots):
            eng.run_batch(group[i:i + max_slots])
    mism = [i for i, (x, y) in enumerate(zip(a, b))
            if x.out_tokens != y.out_tokens]
    return {"checked": len(a), "mismatches": mism, "equal": not mism}


# --------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny workload for CI; the equality and "
                         "shape-stability gates still hard-fail, only "
                         "the 1.5x speedup gate is full-run-only")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.smoke:
        knobs = dict(max_slots=4, max_len=64)
        mean_gap = 0.005
        workload = build_workload(cfg, args.seed, n=10, mean_gap_s=mean_gap,
                                  lens=(8, 16), max_new_lo=2, max_new_hi=16)
    else:
        # heavy mixed traffic: high decode-budget variance is exactly what
        # lock-step batching is worst at (every batch drains to its
        # slowest member while finished rows burn decode steps)
        knobs = dict(max_slots=8, max_len=128)
        mean_gap = 0.003
        workload = build_workload(cfg, args.seed, n=40, mean_gap_s=mean_gap,
                                  lens=(8, 16, 32), max_new_lo=4,
                                  max_new_hi=80)

    arms = {}
    sch = make_scheduler(cfg, params, workload, use_terra=True, **knobs)
    arms["scheduler_terra"] = run_scheduler(sch, workload, dict(sch.stats))
    sch2 = make_scheduler(cfg, params, workload, use_terra=False, **knobs)
    arms["scheduler_noterra"] = run_scheduler(sch2, workload,
                                              dict(sch2.stats))
    sch2.close()
    eng = make_lockstep(cfg, params, workload, **knobs)
    arms["lockstep"] = run_lockstep(eng, workload,
                                    max_slots=knobs["max_slots"])
    equality = check_equality(sch, eng, workload,
                              max_slots=knobs["max_slots"])
    sch.close()
    if eng.terra is not None:
        eng.terra.close()

    speedup = (arms["scheduler_terra"]["tokens_per_s"]
               / arms["lockstep"]["tokens_per_s"])
    coexec = arms["scheduler_terra"]["coexec"]
    gates = {
        "token_equality": equality["equal"],
        "speedup_vs_lockstep": round(speedup, 3),
        "speedup_gate_1.5x": speedup >= 1.5,
        "retraces_post_warmup": coexec["retraces_post_warmup"],
        "families": coexec["families"],
        "shape_stable": (coexec["retraces_post_warmup"] == 0
                         and coexec["families"] <= 2),
    }
    report = {
        "arch": cfg.name, "smoke": args.smoke, "knobs": knobs,
        "workload": {"requests": len(workload),
                     "mean_gap_s": mean_gap,
                     "prompt_lens": sorted({len(p) for _, p, _ in workload}),
                     "total_budget_tokens": sum(mn for _, _, mn in workload)},
        "arms": arms, "equality": equality, "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report, indent=2))

    failures = []
    if not equality["equal"]:
        failures.append(f"token mismatch at requests {equality['mismatches']}")
    if not gates["shape_stable"]:
        failures.append(f"slot churn not shape-stable: {coexec}")
    if not args.smoke and not gates["speedup_gate_1.5x"]:
        failures.append(f"speedup {speedup:.2f}x < 1.5x")
    if failures:
        raise SystemExit("bench_serving FAILED: " + "; ".join(failures))
    print(f"bench_serving OK: {speedup:.2f}x vs lockstep, "
          f"retraces={coexec['retraces_post_warmup']}, "
          f"families={coexec['families']}")


if __name__ == "__main__":
    main()
