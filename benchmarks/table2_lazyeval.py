"""Table 2: co-execution vs lazy-evaluation (LazyTensor-style serialized)
execution, relative to imperative — on the same three programs the paper
uses (ResNet, BERT Q&A, DCGAN).

Methodology note: on this container there is no accelerator, so graph
execution competes with Python for the single CPU core and the paper's
overlap cannot manifest from compute alone.  Each step therefore includes
an I/O-bound Python stage (2 ms, emulating the data-pipeline wait that
dominates real imperative programs' Python time); the co-execution engine
overlaps it with the GraphRunner exactly as Terra overlaps Python with
device execution, while lazy evaluation serializes the two — reproducing
the paper's Table-2 effect (lazy can even drop below imperative)."""

from __future__ import annotations

import time

from benchmarks.programs import REGISTRY
from repro.core import function as terra_function, imperative

PROGRAMS = ["resnet", "bert_qa", "dcgan"]
IO_S = 0.010                      # simulated data-pipeline wait per step
BATCH = 256                       # paper-scale step times (graph >> handoff)


def _with_io(step):
    def wrapped(i):
        time.sleep(IO_S)          # imperative Python the runtime cannot see
        return step(i)
    return wrapped


def timed(name, lazy: bool, warmup=12, measure=40):
    step, _ = REGISTRY[name]("terra", batch=BATCH)
    tf = terra_function(_with_io(step), lazy=lazy)
    for i in range(warmup):
        tf(i)
    tf.wait()
    t0 = time.perf_counter()
    for i in range(warmup, warmup + measure):
        tf(i)
    tf.wait()
    dt = (time.perf_counter() - t0) / measure
    tf.close()
    return dt


def timed_imperative(name, warmup=12, measure=40):
    step, _ = REGISTRY[name]("terra", batch=BATCH)
    wrapped = _with_io(step)
    with imperative() as imp:
        for i in range(warmup):
            wrapped(i)
            imp.step()
        t0 = time.perf_counter()
        for i in range(warmup, warmup + measure):
            wrapped(i)
            imp.step()
        return (time.perf_counter() - t0) / measure


def main():
    print("program,terra_speedup,terra_lazyeval_speedup")
    for name in PROGRAMS:
        imp = timed_imperative(name)
        co = timed(name, lazy=False)
        lz = timed(name, lazy=True)
        print(f"{name},x{imp / co:.2f},x{imp / lz:.2f}")
    print("# paper: co-execution beats lazy evaluation (e.g. ResNet50 "
          "x1.25 vs x1.13); lazy can drop below imperative")


if __name__ == "__main__":
    main()
