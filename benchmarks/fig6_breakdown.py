"""Figure 6: runner-level time breakdown within training — PythonRunner
exec / stall and GraphRunner exec / stall per program, plus the executor
counters (segment cache hits / recompiles, donated variable bytes).

Every number read from ``eng.stats`` here is event-derived: the dict is
the engine EventStream's counter tier (core/events/, DESIGN.md §13),
updated through ``inc``/``add``/``put`` at the same sites that emit the
structured lifecycle events — the breakdown therefore agrees with what a
TimingProcessor attached to the same stream would report.  Output goes
through the metrics-registry JSON snapshot (repro.obs, DESIGN.md §15):
the same formatting path the serving metrics endpoint and the obs report
CLI use, instead of a third hand-built printer."""

from __future__ import annotations

import json
import time

from benchmarks.programs import REGISTRY
from repro.core import function as terra_function
from repro.obs import MetricsRegistry, counters_table

COUNTER_KEYS = ("segment_cache_hits", "segments_recompiled",
                "donated_bytes", "graph_versions", "replays",
                "walker_fast_hits", "feeds_defaulted",
                "nodes_eliminated", "cse_hits", "segments_coalesced",
                "kernels_substituted", "feeds_folded",
                "artifact_hits", "warm_families", "aot_loads")


def breakdown(name: str, warmup: int = 12, measure: int = 40):
    """Per-iteration time split + executor counters for one program, as a
    MetricsRegistry: times as gauges (µs/iteration), counters attached."""
    step, _ = REGISTRY[name]("terra")
    tf = terra_function(step)
    for i in range(warmup):
        tf(i)
    tf.wait()                        # sync() mirrors runner times into stats
    eng = tf.engine
    base = {"py_stall": eng.stats["py_stall_time"],
            "dispatch": eng.stats["dispatch_time"],
            "g_exec": eng.stats["runner_exec_time"],
            "g_stall": eng.stats["runner_stall_time"]}
    t0 = time.perf_counter()
    for i in range(warmup, warmup + measure):
        tf(i)
    tf.wait()
    wall = time.perf_counter() - t0
    py_stall = eng.stats["py_stall_time"] - base["py_stall"]
    dispatch = eng.stats["dispatch_time"] - base["dispatch"]
    g_exec = eng.stats["runner_exec_time"] - base["g_exec"]
    g_stall = eng.stats["runner_stall_time"] - base["g_stall"]
    py_exec = max(wall - py_stall, 0.0)
    reg = MetricsRegistry()
    for k, v in dict(wall=wall, py_exec=py_exec, py_stall=py_stall,
                     dispatch=dispatch, g_exec=g_exec,
                     g_stall=g_stall).items():
        reg.set_gauge(f"{k}_us_per_iter", round(v / measure * 1e6, 1))
    reg.attach_counters({k: eng.stats[k] for k in COUNTER_KEYS})
    tf.close()
    return reg


def main():
    report = {}
    for name in sorted(REGISTRY):
        reg = breakdown(name)
        snap = reg.snapshot()
        report[name] = snap
        print(f"== {name} ==")
        print(counters_table(snap["gauges"]))
        print(counters_table(snap["counters"], list(COUNTER_KEYS)))
    print(json.dumps(report, indent=2))
    print("# paper finding: GraphRunner rarely stalls; PythonRunner exec is"
          " hidden behind graph execution")
    print("# executor counters: cache hits mean a TraceGraph version bump"
          " reused compiled segments; donated_bytes counts var_in buffers"
          " offered to XLA for in-place reuse; walker_fast_hits counts ops"
          " validated by the stamp fast path; feeds_defaulted counts Input"
          " Feeding slots filled with zeros (untaken regions only)")
    print("# pass-pipeline counters (DESIGN.md §10): nodes_eliminated (DCE),"
          " cse_hits (duplicate subexpressions merged), segments_coalesced"
          " (gating boundaries removed), kernels_substituted (subgraphs"
          " fused to Pallas kernels), feeds_folded (Input Feeds demoted to"
          " baked constants)")
    print("# warm-boot counters (DESIGN.md §14): artifact_hits (records/"
          "executables loaded from $TERRA_CACHE_DIR), warm_families"
          " (families hydrated instead of traced), aot_loads (segments"
          " deserialized instead of recompiled)")


if __name__ == "__main__":
    main()
