"""Co-execution showcase: every failure class of static converters
(paper Figure 1 + §2.2) running in ONE imperative program under Terra.

    PYTHONPATH=src python examples/coexec_showcase.py
"""

import numpy as np

from repro.core import GradientTape, Variable, function, ops


class Augment:                         # Fig 1c: mutated Python object
    noise = 0.0


aug = Augment()
W = Variable(np.random.RandomState(0).randn(8, 8).astype(np.float32) * 0.3)


def feature_gen(x, k):                 # Fig 1b: Python generator
    for i in range(k):
        yield ops.mul(x, float(i + 1))


@function(optimize="all")          # full symbolic pass pipeline (§10)
def step(x, n_feats):
    try:                               # try/except (AutoGraph-unsupported)
        acc = ops.zeros_like(x)
        for f in feature_gen(x, n_feats):          # generator + dyn loop
            acc = ops.add(acc, f)
        h = ops.matmul(acc, W.read())
        if float(ops.reduce_sum(h)) > 1e4:         # materialization gating
            raise OverflowError
    except OverflowError:
        h = ops.mul(ops.matmul(x, W.read()), 0.1)

    h = ops.add(h, ops.mul(ops.random_normal(h.shape), aug.noise))
    hs = np.sort(h.numpy(), axis=1)                # Fig 1a: third-party call
    # third-party results flow back as Input Feeding points (np arrays /
    # np scalars are feeds; a bare Python float would be a baked constant)
    loss = ops.reduce_mean(ops.square(ops.sub(h, np.float32(hs.mean()))))
    with GradientTape() as tape:
        out = ops.matmul(x, W.read())
        l2 = ops.reduce_mean(ops.square(out))
    g, = tape.gradient(l2, [W])
    W.assign_sub(ops.mul(g, 0.01))                 # in-graph state update
    return loss


def main():
    rng = np.random.RandomState(1)
    for i in range(16):
        if i == 8:
            aug.noise = 0.05           # mutation mid-run
        x = rng.randn(4, 8).astype(np.float32) * (10.0 if i == 12 else 1.0)
        loss = step(x, 2 + i % 3)
        print(f"iter {i:2d}  n_feats={2 + i % 3}  loss={float(loss):9.4f}  "
              f"phase={step.phase}")
    print("stats:", {k: v for k, v in step.stats.items()
                     if isinstance(v, int)})
    step.close()


if __name__ == "__main__":
    main()
