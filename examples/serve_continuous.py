"""Continuous-batching serving demo: mixed-length prompts, Poisson
arrivals, mid-decode admission and per-token streaming — the serving
main loop running as an imperative program under Terra co-execution
(serve/scheduler/, DESIGN.md §11).

    PYTHONPATH=src python examples/serve_continuous.py --arch llama3-8b
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import smoke_config
from repro.models import model as M
from repro.serve.engine import Request
from repro.serve.scheduler import ContinuousBatchingScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--mean-gap-ms", type=float, default=5.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    sch = ContinuousBatchingScheduler(cfg, params,
                                      max_slots=args.max_slots,
                                      max_len=args.max_len)

    rng = np.random.RandomState(args.seed)
    streamed = []
    t0 = time.perf_counter()
    offsets = np.cumsum(rng.exponential(args.mean_gap_ms / 1e3,
                                        args.requests))
    reqs = []
    for i in range(args.requests):
        L = int(rng.choice([8, 16, 32]))
        reqs.append(Request(
            prompt=rng.randint(0, cfg.vocab, L).astype(np.int32),
            max_new_tokens=int(rng.randint(4, 33)),
            arrival_time=t0 + float(offsets[i]),
            stream=lambda r, tok, idx: streamed.append((tok, idx))))
    sch.serve(reqs)
    wall = time.perf_counter() - t0

    total = sum(len(r.out_tokens) for r in reqs)
    ttft = [r.first_token_time - r.arrival_time for r in reqs]
    print(f"arch={cfg.name}  requests={args.requests}  "
          f"slots={args.max_slots}  generated={total} tokens in "
          f"{wall:.2f}s  ({total / wall:.1f} tok/s)  "
          f"ttft_p50={np.percentile(ttft, 50) * 1e3:.1f}ms")
    st = sch.stats
    print(f"sched: admitted={st['admitted']} retired={st['retired']} "
          f"decode_steps={st['decode_steps']} "
          f"prefill_steps={st['prefill_steps']} "
          f"streamed={len(streamed)}")
    print(f"coexec: phase={st['phase']} retraces={st['retraces']} "
          f"families={st['families']} replays={st['replays']} "
          f"walker_fast_hits={st['walker_fast_hits']}")
    print(f"first sequence: {reqs[0].out_tokens[:16]}")
    sch.close()


if __name__ == "__main__":
    main()
