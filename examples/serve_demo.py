"""Batched serving demo: prefill + lock-step decode with a KV cache,
through ServingEngine.run_batch — one batch of same-length prompts,
decoded in lock-step and drained to its slowest request.  For true
continuous batching (mid-decode admission, slot-pooled cache,
mixed-length prompts) see examples/serve_continuous.py and
serve/scheduler/.

    PYTHONPATH=src python examples/serve_demo.py --arch llama3-8b
(the arch's reduced smoke config is served — full configs are exercised
via the multi-pod dry-run)
"""

import argparse
import time

import numpy as np
import jax

from repro.configs import smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=args.prompt_len
                           + args.max_new + 8)

    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, args.prompt_len)
                    .astype(np.int32), max_new_tokens=args.max_new)
            for _ in range(args.batch)]

    extras = {}
    if cfg.family == "vlm":
        extras["cross_states"] = jax.numpy.asarray(
            rng.randn(args.batch, cfg.frontend_tokens, cfg.d_model),
            jax.numpy.bfloat16)

    t0 = time.perf_counter()
    out = engine.run_batch(reqs, **extras)
    dt = time.perf_counter() - t0

    total_new = sum(len(r.out_tokens) for r in out)
    print(f"arch={cfg.name}  batch={args.batch}  "
          f"prompt={args.prompt_len}  generated={total_new} tokens "
          f"in {dt:.2f}s  ({total_new / dt:.1f} tok/s)")
    print(f"stats: {engine.stats}")
    if engine.terra is not None:
        coexec = {k: v for k, v in engine.terra.stats.items()
                  if isinstance(v, int)}
        print(f"decode phase: {engine.terra.phase}  coexec stats: {coexec}")
    print(f"first sequence: {out[0].out_tokens[:16]}")


if __name__ == "__main__":
    main()
