"""Quickstart: Terra imperative-symbolic co-execution in 40 lines.

Write any imperative program against repro.core.ops — dynamic control
flow, Python mutation, numpy calls included — wrap it with terra.function,
and the runtime traces, builds a symbolic graph, and co-executes.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import GradientTape, Variable, function, ops

# a 2-layer network as ordinary mutable Python state
W1 = Variable(np.random.RandomState(0).randn(16, 32).astype(np.float32) * 0.2)
W2 = Variable(np.random.RandomState(1).randn(32, 4).astype(np.float32) * 0.2)


class Schedule:                      # Python object mutated mid-training
    lr = 0.1


sched = Schedule()


@function(optimize="all")          # full symbolic pass pipeline (§10)
def train_step(x, y):
    with GradientTape() as tape:
        h = ops.relu(ops.matmul(x, W1.read()))
        logits = ops.matmul(h, W2.read())
        loss = ops.softmax_xent(logits, y)
    g1, g2 = tape.gradient(loss, [W1, W2])
    W1.assign_sub(ops.mul(g1, sched.lr))      # captured mutation
    W2.assign_sub(ops.mul(g2, sched.lr))
    return loss


def main():
    rng = np.random.RandomState(42)
    for step in range(60):
        x = rng.randn(64, 16).astype(np.float32)
        y = (x.sum(axis=1) > 0).astype(np.int32)
        loss = train_step(x, y)
        if step == 30:
            sched.lr = 0.02           # Terra re-traces transparently
        if step % 10 == 0:
            print(f"step {step:3d}  loss {float(loss):.4f}  "
                  f"phase={train_step.phase}")
    print("stats:", {k: v for k, v in train_step.stats.items()
                     if isinstance(v, int)})
    train_step.close()


if __name__ == "__main__":
    main()
