"""End-to-end driver: train a language model through the full framework
stack — synthetic data pipeline, AdamW, remat/scan transformer, Terra
co-execution, checkpointing with auto-resume, straggler watchdog.

    # ~100M-parameter model, a few hundred steps (accelerator-scale run):
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

    # CPU-friendly smoke preset:
    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 60
"""

import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer

PRESETS = {
    # ~130M params: GPT-2-small-class decoder-only LM
    "100m": dict(cfg=ModelConfig(
        name="lm-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=10, d_ff=2560, vocab=50304, head_dim=64,
        rope_theta=10000.0, block_pattern=("attn",), remat=True,
        q_block=128, kv_block=256),
        batch=4, seq_len=256),
    "tiny": dict(cfg=ModelConfig(
        name="lm-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab=2048, head_dim=32,
        rope_theta=10000.0, block_pattern=("attn",), remat=False,
        q_block=64, kv_block=64),
        batch=8, seq_len=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--no-terra", action="store_true",
                    help="bypass co-execution (debug)")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    from repro.models.model import param_count
    print(f"model: {p['cfg'].name}  params={param_count(p['cfg']) / 1e6:.1f}M")

    trainer = Trainer(
        p["cfg"],
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                  total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, batch=p["batch"], seq_len=p["seq_len"],
        log_every=10, ckpt_every=max(args.steps // 4, 20),
        use_terra=not args.no_terra)
    if trainer.start_step:
        print(f"resumed from checkpoint at step {trainer.start_step}")
    hist = trainer.train(args.steps)
    print(f"final loss {hist[-1][1]:.4f} "
          f"(from {hist[0][1]:.4f} at step {hist[0][0]})")
    if trainer.straggler_events:
        print(f"straggler watchdog flagged {len(trainer.straggler_events)} "
              f"slow steps")
    if trainer.use_terra:
        print("terra stats:", {k: v for k, v in trainer._iteration.stats.items()
                               if isinstance(v, int)})
        trainer._iteration.close()


if __name__ == "__main__":
    main()
