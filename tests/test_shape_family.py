"""Shape-keyed TraceGraph families (ISSUE 3, DESIGN.md §8): lifecycle,
LRU eviction, cross-family segment-cache sharing, serving batch flips —
plus the divergence-rollback / GraphRunner.cancel / strict-feeds
correctness fixes that ride along."""

import os
import re

import numpy as np
import pytest

from repro.core import Variable, function, ops


# ==========================================================================
# family lifecycle
# ==========================================================================

def test_shape_flip_zero_retrace_zero_recompile():
    """Trace shape A, trace shape B, then flip between them: every flip is
    a dictionary lookup — no retrace, no segment recompile, and the Walker
    stamp fast path resumes on the revisited family."""
    @function
    def step(x):
        y = ops.mul(x, 2.0)
        s = float(ops.reduce_sum(y))           # gating fetch -> 2 segments
        z = ops.add(y, 1.0)
        return float(ops.reduce_sum(z)) + 0.0 * s

    for i in range(3):
        step(np.full(4, i + 1.0, np.float32))
    for i in range(3):
        step(np.full(8, i + 1.0, np.float32))
    assert step.phase == "co-execution"
    st = step.stats
    eng = step.engine
    assert st["families"] == 2
    assert st["retraces"] == 1                 # shape B's first trace
    base = (st["retraces"], eng.seg_cache.misses, st["replays"],
            st["walker_fast_hits"])

    for i in range(10):
        n = 4 if i % 2 == 0 else 8
        out = step(np.full(n, 9.0, np.float32))
        assert out == pytest.approx(9 * 2 * n + n), n
    step.wait()
    assert step.phase == "co-execution"
    assert st["retraces"] == base[0]           # zero retraces across flips
    assert eng.seg_cache.misses == base[1]     # zero recompiles
    assert st["segments_recompiled"] == eng.seg_cache.misses
    assert st["replays"] == base[2]            # flips are not divergences
    assert st["walker_fast_hits"] > base[3]    # stamp fast path resumed
    assert st["family_switches"] >= 10
    step.close()


def test_family_provenance_keys():
    """Each family's TraceGraph and GraphProgram record the shape-class
    key they were generated under."""
    @function
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, 3.0)))

    for n in (4, 4, 8, 8):
        step(np.full(n, 1.0, np.float32))
    eng = step.engine
    assert len(eng.fm.families) == 2
    for key, fam in eng.fm.families.items():
        assert fam.tg.family_key == key
        assert fam.gp is not None and fam.gp.family_key == key
    assert eng.gp.family_key == eng.family.key
    step.close()


def test_family_lru_eviction_and_retrace():
    """Past ``max_families`` the least recently used family is evicted;
    revisiting an evicted shape class re-traces (counted in retraces)."""
    @function(max_families=2)
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, 2.0)))

    for n in (4, 4, 8, 8, 16, 16):             # 16 evicts the LRU family (4)
        out = step(np.full(n, 1.0, np.float32))
        assert out == pytest.approx(2.0 * n)
    st = step.stats
    assert st["families"] == 2
    assert st["families_evicted"] == 1
    base = st["retraces"]
    step(np.full(4, 1.0, np.float32))          # evicted shape: traces again
    assert st["retraces"] == base + 1
    step(np.full(4, 1.0, np.float32))
    assert step.phase == "co-execution"
    step.close()


def test_cross_family_segment_cache_sharing():
    """A shape-invariant segment (fixed-shape variable work before the
    boundary) is shared across family members through the SegmentCache;
    only the shape-variant segment recompiles for the sibling shape."""
    w = Variable(np.ones(16, np.float32), "xf_w")

    @function
    def step(x):
        w.assign(ops.mul(w.read(), 1.5))       # shape-invariant segment
        s = float(ops.reduce_sum(w.read()))    # gating fetch -> boundary
        return float(ops.reduce_sum(ops.mul(x, 2.0))) + 0.0 * s

    for i in range(3):
        step(np.full(4, 1.0, np.float32))
    eng = step.engine
    hits, misses = eng.seg_cache.hits, eng.seg_cache.misses
    for i in range(3):
        step(np.full(8, 1.0, np.float32))
    assert step.phase == "co-execution"
    assert step.stats["families"] == 2
    # sibling family reused the invariant segment's compiled callable ...
    assert eng.seg_cache.hits > hits
    # ... and recompiled strictly fewer segments than the whole program
    assert eng.seg_cache.misses - misses < len(eng.gp.seg_progs)
    step.wait()
    step.close()


def test_divergence_stays_within_family():
    """A real control-flow divergence re-traces only its own family; the
    sibling family's graph survives untouched."""
    class Cfg:
        k = 1.0
    cfg = Cfg()

    @function
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, cfg.k)))

    for n in (4, 4, 8, 8):
        step(np.full(n, 1.0, np.float32))
    st = step.stats
    eng = step.engine
    fam8 = eng.family
    assert st["families"] == 2
    cfg.k = 2.0                                # diverges the active family
    out = step(np.full(8, 1.0, np.float32))
    assert out == pytest.approx(16.0)
    assert st["replays"] == 1
    assert st["families"] == 2                 # no family created/destroyed
    assert eng.family is fam8
    step.close()


def test_serving_decode_alternating_batch_sizes():
    """Serving decode with alternating batch sizes reaches steady state
    with exactly one trace+compile per shape class: after warmup, flips
    cause zero retraces, zero recompiles and zero divergences."""
    import jax
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=48)
    rng = np.random.RandomState(0)

    def run(B):
        reqs = [Request(prompt=rng.randint(0, cfg.vocab, 8).astype(np.int32),
                        max_new_tokens=4) for _ in range(B)]
        for r in engine.run_batch(reqs):
            assert len(r.out_tokens) == 4

    for B in (2, 2, 4, 4):                     # warmup: both shape classes
        run(B)
    st = engine.terra.stats
    eng = engine.terra._tf.engine
    assert st["families"] == 2
    base = (st["retraces"], eng.seg_cache.misses, st["replays"])
    for i in range(6):                         # alternating batch sizes
        run(2 if i % 2 == 0 else 4)
    assert engine.terra.phase == "co-execution"
    assert st["retraces"] == base[0]
    assert eng.seg_cache.misses == base[1]
    assert st["replays"] == base[2]
    engine.terra.close()


def test_bucket_pow2_bounds_family_cardinality():
    from repro.core.executor.families import bucket_pow2
    assert [bucket_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    assert bucket_pow2(3, floor=4) == 4


# ==========================================================================
# divergence-rollback correctness (satellite bugfixes)
# ==========================================================================

def test_first_iteration_divergence_with_fresh_variable_rolls_back():
    """Divergence on an iteration whose snapshot is the empty store must
    still roll back: a Variable first registered (and buffer-seeded) during
    the diverging iteration must NOT survive in the store — the
    pre-iteration state had no buffers at all."""
    holder = {}

    @function
    def step(x):
        y = ops.mul(x, 2.0)
        s = float(ops.reduce_sum(y))           # boundary; snapshot is {}
        if holder:
            z = ops.add(holder["w"].read(), y)     # fresh var -> diverges
            return float(ops.reduce_sum(z)) + 0.0 * s
        return s

    for i in range(3):
        step(np.full(4, 1.0, np.float32))
    eng = step.engine
    assert step.phase == "co-execution"
    assert not eng.store.buffers               # empty pre-iteration state

    holder["w"] = Variable(np.full(4, 5.0, np.float32), "fresh_w")
    out = step(np.full(4, 1.0, np.float32))
    assert step.stats["replays"] == 1
    assert out == pytest.approx(4 * (5.0 + 2.0))
    # VariableStore is exactly at its pre-iteration state: the fresh
    # variable's seed buffer did not survive the rollback
    assert holder["w"].var_id not in eng.store
    # and the engine keeps working (re-seeds on the next registration)
    for i in range(2):
        out = step(np.full(4, 1.0, np.float32))
        assert out == pytest.approx(4 * (5.0 + 2.0))
    assert step.phase == "co-execution"
    step.close()


def test_graphrunner_cancel_is_public_and_clears_error():
    """GraphRunner.cancel() drains, closes the iteration window and clears
    the stashed error in one call — no attribute pokes required."""
    @function
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, 2.0)))

    for i in range(3):
        step(np.full(4, 1.0, np.float32))
    eng = step.engine

    def boom():
        raise RuntimeError("boom")

    eng.runner.submit(boom)
    eng.runner.cancel()                        # drains + clears the stash
    step.wait()                                # must NOT re-raise "boom"
    out = step(np.full(4, 3.0, np.float32))    # runner still alive
    assert out == pytest.approx(4 * 6.0)
    step.close()


def test_lazy_mode_closure_error_surfaces_at_fetch():
    """Lazy mode (serialized evaluation, no runner thread) must surface a
    queued closure's error on the calling thread at the fetch/fence point
    — not stash it silently and hand back stale buffers."""
    w = Variable(np.ones(4, np.float32), "lz_err_w")

    @function(lazy=True)
    def step(x):
        w.assign(ops.mul(w.read(), x))
        return ops.reduce_sum(w.read())

    for i in range(3):
        step(np.full(4, 2.0, np.float32))
    eng = step.engine

    def boom():
        raise RuntimeError("lazy boom")

    eng.runner.submit(boom)
    with pytest.raises(RuntimeError, match="lazy boom"):
        eng.variable_value(w)                  # fence wait drains -> raises
    # error consumed; the engine keeps working afterwards
    val = np.asarray(eng.variable_value(w))
    np.testing.assert_allclose(val, np.full(4, 2.0 ** 3))
    step.close()


def test_no_private_graphrunner_access_in_sources():
    """The divergence handler (and everything else) goes through the
    public GraphRunner API: no ``runner._x`` attribute pokes and no
    external assignment to ``pending_error`` anywhere in the source tree
    outside graph_runner.py itself."""
    import repro
    root = os.path.dirname(repro.__file__)
    poke = re.compile(r"runner\._[a-z]|\.pending_error\s*=")
    offenders = []
    for dirpath, _, names in os.walk(root):
        for name in names:
            if not name.endswith(".py") or name == "graph_runner.py":
                continue
            path = os.path.join(dirpath, name)
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    if poke.search(line):
                        offenders.append(f"{path}:{lineno}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_stamp_fast_path_rejects_ambiguous_siblings():
    """After a branch re-merge (DESIGN.md §7.1), the two per-path sibling
    nodes carry *identical* entry stamps — the stamp hashes the raw trace
    entry, and resolved srcs are the only thing telling the siblings
    apart.  The Walker fast path must fall back to the structural scan on
    an ambiguous stamp: blindly accepting the first match records the
    wrong Case Select, and the switch phi silently commits the OTHER
    branch's value into the variable (no divergence, no replay)."""
    v = Variable(np.zeros(4, np.float32), "amb_v")

    @function
    def step(x, flag):
        if flag:
            y = ops.mul(x, 2.0)
        else:
            y = ops.add(x, 3.0)
        h = ops.relu(x)                        # path-independent: re-merges
        z = ops.add(y, h)                      # per-path siblings, equal stamps
        v.assign(z)                            # switch phi output
        return float(ops.reduce_sum(h))        # path-independent fetch

    x = np.full(4, 1.0, np.float32)
    for flag in (True, False, True, False, True, False):
        step(x, flag)
    assert step.phase == "co-execution"
    for flag, want in ((False, 5.0), (True, 3.0), (False, 5.0)):
        step(x, flag)
        step.wait()
        np.testing.assert_allclose(
            np.asarray(step.engine.variable_value(v)), np.full(4, want),
            err_msg=f"flag={flag}: wrong branch committed into the phi")
    assert step.stats["replays"] == 0          # resolved without divergence
    step.close()


# ==========================================================================
# strict feeds (zeros substitution on a taken path is an error)
# ==========================================================================

def _feed_drop_program(**kw):
    hook = [None]

    @function(**kw)
    def step(x):
        y = ops.mul(x, 2.0)                    # x is an Input Feeding value
        if hook[0]:
            hook[0]()
        return float(ops.reduce_sum(y))        # fetch -> dispatch

    return step, hook


def test_strict_feeds_raises_on_taken_path_default():
    step, hook = _feed_drop_program()
    # vary the fed value so constant-feed folding never demotes the slot
    # (a folded feed has no collection to lose — it is a baked constant)
    for i in range(3):
        step(np.full(4, float(i + 1), np.float32))
    assert step.phase == "co-execution"
    eng = step.engine
    hook[0] = lambda: eng.walker.feed_vals.clear()   # lose a collected feed
    with pytest.raises(RuntimeError, match="never collected on the taken"):
        step(np.full(4, 1.0, np.float32))
    # the escaped error aborted the iteration cleanly: the engine is not
    # stuck half-open and the next calls re-trace and co-execute again
    hook[0] = None
    for i in range(2):
        out = step(np.full(4, 1.0, np.float32))
        assert out == pytest.approx(8.0)
    assert step.phase == "co-execution"
    step.wait()
    step.close()


def test_strict_feeds_opt_out_warns_per_engine_and_counts():
    # the warn-once latch is engine-lifetime, not process-global: a second
    # engine with the same defect must warn again
    for _ in range(2):
        step, hook = _feed_drop_program(strict_feeds=False)
        for i in range(3):
            step(np.full(4, float(i + 1), np.float32))   # no feed folding
        eng = step.engine
        base = step.stats["feeds_defaulted"]
        hook[0] = lambda: eng.walker.feed_vals.clear()
        with pytest.warns(RuntimeWarning, match="strict_feeds disabled"):
            step(np.full(4, 1.0, np.float32))
        assert step.stats["feeds_defaulted"] > base
        step.close()


def test_untaken_branch_feed_defaults_do_not_raise():
    """Zeros substitution stays legitimate (and silent) for feed slots of
    the branch NOT taken this iteration, also under strict feeds."""
    w = Variable(np.ones(4, np.float32), "sf_w")

    @function
    def step(x, big):
        s = float(ops.reduce_sum(ops.mul(x, 2.0)))
        if s > 10.0:
            z = ops.add(ops.mul(x, 3.0), big)  # feed only on this path
        else:
            z = ops.mul(x, 1.5)
        w.assign(z)
        return s

    big = np.full(4, 100.0, np.float32)
    for v in (0.5, 3.0, 0.5, 3.0, 0.5, 3.0):
        step(np.full(4, v, np.float32), big)
    assert step.phase == "co-execution"
    base = step.stats["feeds_defaulted"]
    step(np.full(4, 0.5, np.float32), big)     # small branch: big untaken
    step.wait()
    assert step.stats["feeds_defaulted"] > base
    step.close()
