"""Event-stream tests (core/events/, DESIGN.md §13): counter
bit-compatibility with and without structured processors, causal
completeness of divergence → rollback → replay chains, per-request
serving traces (mid-decode admission and early-EOS retirement) with
monotone timestamps, steady-state lifecycle events, and the strict
JSONL schema roundtrip."""

import json

import numpy as np
import pytest

import jax

from repro.configs import smoke_config
from repro.core import Variable, function, ops
from repro.core.events import (EventStream, JsonlSink, ListProcessor,
                               RequestTraceProcessor, dict_to_event,
                               event_to_dict, load_jsonl, types,
                               validate_jsonl)
from repro.models import model as M
from repro.serve.engine import Request
from repro.serve.scheduler import ContinuousBatchingScheduler

MAX_LEN = 64


@pytest.fixture(scope="module")
def llama():
    cfg = smoke_config("llama3-8b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def make_requests(cfg, lens, max_news, seed=1, **kw):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, cfg.vocab, L).astype(np.int32),
                    max_new_tokens=mn, arrival_time=0.0, **kw)
            for L, mn in zip(lens, max_news)]


# ==========================================================================
# counter tier: bit-compatible with the pre-event-layer stats dicts
# ==========================================================================

def _counting_run(attach_list):
    v = Variable(np.ones(4, np.float32))

    @function
    def step(x):
        y = ops.mul(x, 2.0)
        v.assign(ops.add(v.read(), y))
        return float(ops.reduce_sum(y))

    lp = ListProcessor()
    if attach_list:
        step.engine.events.attach(lp)
    for i in range(6):
        step(np.full(4, i + 1.0, np.float32))
    step.wait()
    st = dict(step.stats)
    step.close()
    return st, lp


def test_counters_identical_with_and_without_processors():
    """Attaching a structured processor must not change a single counter:
    the counter tier and the event tier are independent by construction."""
    plain, _ = _counting_run(attach_list=False)
    traced, lp = _counting_run(attach_list=True)
    ints = {k for k, x in plain.items() if isinstance(x, (int, np.integer))}
    assert {k: plain[k] for k in ints} == {k: traced[k] for k in ints}
    # and the event tier saw the same lifecycle the counters recorded
    assert len(lp.of_type(types.IterationStart)) == plain["iterations"]
    assert plain["iterations"] == 6


def test_no_events_constructed_when_off():
    """Hot-path discipline: with no structured processor, ``on`` is False
    and emit sites never build an event object."""
    es = EventStream(counters={"n": 0})
    assert es.on is False
    es.inc("n")
    lp = es.attach(ListProcessor())
    assert es.on is True
    es.emit(types.Transition(0))
    es.detach(lp)
    assert es.on is False and len(lp.events) == 1
    assert es.counters["n"] == 1


# ==========================================================================
# causal completeness: divergence -> rollback -> replay, one iter_id
# ==========================================================================

def test_divergence_chain_causally_complete():
    """Every Divergence is followed by exactly one Rollback and exactly
    one Replay-or-Retrace carrying the same iteration id, in that order."""
    class Cfg:
        scale = 1.0
    cfg = Cfg()

    @function
    def step(x):
        y = ops.mul(x, 2.0)
        z = ops.mul(y, cfg.scale)      # baked const -> diverges on change
        return float(ops.reduce_sum(z))

    lp = step.engine.events.attach(ListProcessor())
    for i in range(4):                 # trace, cover, enter co-execution
        step(np.full(4, i + 1.0, np.float32))
    cfg.scale = 3.0                    # walker mismatch mid-iteration
    out = step(np.full(4, 9.0, np.float32))
    assert out == pytest.approx(4 * 9.0 * 2.0 * 3.0)
    step.wait()

    divs = lp.of_type(types.Divergence)
    assert len(divs) >= 1
    for d in divs:
        chain = [e for e in lp.of_type(types.Rollback, types.Replay,
                                       types.Retrace)
                 if e.iter_id == d.iter_id]
        rbs = [e for e in chain if isinstance(e, types.Rollback)]
        rps = [e for e in chain if isinstance(e, (types.Replay,
                                                  types.Retrace))]
        assert len(rbs) == 1, f"iter {d.iter_id}: {len(rbs)} rollbacks"
        assert len(rps) == 1, f"iter {d.iter_id}: {len(rps)} replays"
        order = lp.events.index
        assert order(d) < order(rbs[0]) < order(rps[0])
    # the chain is causally attributed: the divergence iteration retraced
    assert step.stats["retraces"] >= len(divs)
    step.close()


# ==========================================================================
# request traces: admit -> prefill -> token* -> retire, monotone ts
# ==========================================================================

def _assert_complete_trace(rec, req):
    kinds = [r["type"] for r in rec]
    assert kinds[0] == "RequestSubmit"
    assert kinds[1] == "RequestAdmit"
    assert kinds[2] == "RequestPrefill"
    assert kinds[-1] == "RequestRetire"
    toks = [r for r in rec if r["type"] == "RequestToken"]
    assert len(toks) == len(req.out_tokens)
    assert [t["token"] for t in toks] == list(req.out_tokens)
    assert [t["index"] for t in toks] == list(range(len(toks)))
    ts = [r["ts"] for r in rec]
    assert all(a <= b for a, b in zip(ts, ts[1:])), "timestamps regress"
    return rec[-1]


def test_request_traces_mid_decode_admission(llama):
    """Oversubscribed workload (6 requests, 3 slots): late requests are
    admitted mid-decode, and every admitted request's trace is complete
    — admit, prefill at its bucket, one token event per generated token,
    retire — with monotone timestamps."""
    cfg, params = llama
    sch = ContinuousBatchingScheduler(cfg, params, max_slots=3,
                                      max_len=MAX_LEN)
    tracer = sch.events.attach(RequestTraceProcessor())
    lens = [5, 8, 13, 8, 5, 16]
    mns = [4, 9, 3, 5, 7, 4]
    reqs = sch.serve(make_requests(cfg, lens, mns))
    assert sch.stats["retired"] == len(reqs)
    assert len(tracer.traces) == len(reqs)
    for req in reqs:
        retire = _assert_complete_trace(tracer.trace(req.rid), req)
        assert retire["reason"] == "budget"
        assert retire["tokens"] == len(req.out_tokens)
    sch.close()


def test_request_trace_eos_retirement(llama):
    """A request that hits EOS mid-budget retires with reason 'eos' and a
    trace that ends at the EOS token (no post-retirement token events)."""
    cfg, params = llama
    sch = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                      max_len=MAX_LEN)
    [probe] = sch.serve(make_requests(cfg, [6], [8]))
    # greedy decode is deterministic: the first token value NOT already
    # generated earlier marks a mid-budget EOS point when replayed
    idx, eos = next((i, t) for i, t in enumerate(probe.out_tokens)
                    if i > 0 and t not in probe.out_tokens[:i])
    tracer = sch.events.attach(RequestTraceProcessor())
    [req] = sch.serve(make_requests(cfg, [6], [8], eos_id=eos))
    assert req.done and len(req.out_tokens) == idx + 1
    retire = _assert_complete_trace(tracer.trace(req.rid), req)
    assert retire["reason"] == "eos" and retire["tokens"] == idx + 1
    sch.close()


# ==========================================================================
# steady-state lifecycle events
# ==========================================================================

def test_steady_state_events():
    """Zero-walker steady state announces itself: SteadyEnter on entry,
    'steady'-kind SegmentDispatch per plan dispatch, SteadyProbe on the
    forced walker iterations."""
    v = Variable(np.zeros(4, np.float32))

    @function(optimize="safe", steady_state=2, steady_probe=4)
    def step(x):
        y = ops.mul(x, 2.0)
        v.assign(ops.add(v.read(), y))
        return y

    lp = step.engine.events.attach(ListProcessor())
    for i in range(16):
        # materialized output: steady eligibility needs the fetch pattern
        np.asarray(step(np.full(4, float(i + 1), np.float32)))
    step.wait()
    assert step.stats["steady_iters"] > 0
    assert len(lp.of_type(types.SteadyEnter)) == step.stats["steady_entries"]
    steady_dispatch = [e for e in lp.of_type(types.SegmentDispatch)
                       if e.kind == "steady"]
    assert len(steady_dispatch) == step.stats["steady_iters"]
    # every steady_probe-th call is forced through the full walker path
    assert len(lp.of_type(types.SteadyProbe)) >= 1
    step.close()


# ==========================================================================
# strict JSONL schema
# ==========================================================================

def test_event_dict_roundtrip():
    for e in (types.IterationStart(3, "skeleton", "a1b2c3d4"),
              types.Divergence(7, "const mismatch"),
              types.RequestToken(2, 991, 0),
              types.PassPipelineRun(4, "f" * 8, ("cse", "dce"),
                                    {"cse": {"cse_hits": 2}})):
        d = json.loads(json.dumps(event_to_dict(e)))
        e2 = dict_to_event(d)
        assert type(e2) is type(e)
        assert event_to_dict(e2) == event_to_dict(e)


def test_schema_rejects_malformed():
    with pytest.raises(ValueError, match="unknown event type"):
        dict_to_event({"type": "NoSuchEvent"})
    with pytest.raises(ValueError):                      # extra field
        dict_to_event({"type": "Transition", "iter_id": 1, "bogus": 2})
    with pytest.raises(ValueError):                      # missing field
        dict_to_event({"type": "RequestToken", "rid": 1})


def test_jsonl_sink_and_validation(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    es = EventStream()
    sink = es.attach(JsonlSink(path))
    es.emit(types.IterationStart(0, "tracing", "00000000"))
    es.emit(types.RequestSubmit(1, 8, 4))
    es.emit(types.RequestRetire(1, "eos", 3))
    es.close()                          # close flushes the sink
    events = load_jsonl(path)
    assert [type(e).__name__ for e in events] == \
        ["IterationStart", "RequestSubmit", "RequestRetire"]
    assert events[0].ts is not None
    counts = validate_jsonl(path)
    assert counts == {"IterationStart": 1, "RequestSubmit": 1,
                      "RequestRetire": 1}
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"type": "NoSuchEvent"}\n')
    with pytest.raises(ValueError, match="bad.jsonl:1"):
        validate_jsonl(str(bad))
