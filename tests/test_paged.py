"""Paged KV cache + zero-walker steady state (ISSUE 7).

Covers the paged arena data layer (PagedLayout / BlockAllocator /
SlotPool block tables), admission backpressure through the block-budget
checker, paged-vs-dense exact greedy equality under the serving
scheduler, high-concurrency admission beyond dense-equivalent capacity,
the Pallas paged-attention decode kernel against its dense oracle, and
the zero-walker steady-state dispatch path (executor/steady.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core import Variable, function, ops
from repro.models import model as M
from repro.serve.engine import Request
from repro.serve.scheduler import (ArrivalQueue, BlockAllocator,
                                   ContinuousBatchingScheduler, PagedLayout,
                                   SlotPool)


@pytest.fixture(scope="module")
def llama():
    cfg = smoke_config("llama3-8b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def make_requests(cfg, lens, max_news, seed=1, **kw):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, cfg.vocab, L).astype(np.int32),
                    max_new_tokens=mn, arrival_time=0.0, **kw)
            for L, mn in zip(lens, max_news)]


# ==========================================================================
# layout + allocator
# ==========================================================================

def test_paged_layout_geometry_and_validation():
    lay = PagedLayout(block_size=16, num_blocks=9, max_len=64)
    assert lay.nbps == 4
    # prompt + budget + 1 post-EOS garbage position, ceil to blocks
    assert lay.blocks_needed(1, 0) == 1
    assert lay.blocks_needed(15, 0) == 1
    assert lay.blocks_needed(15, 1) == 2
    assert lay.blocks_needed(8, 23) == 2
    with pytest.raises(ValueError):
        PagedLayout(block_size=10, num_blocks=4, max_len=64)   # not divisor
    with pytest.raises(ValueError):
        PagedLayout(block_size=16, num_blocks=1, max_len=64)   # no trash


def test_block_allocator_lifecycle_and_guards():
    al = BlockAllocator(6)                  # capacity 5: blocks 1..5
    assert al.capacity == 5 and al.free_count == 5
    a = al.alloc(2)
    b = al.alloc(3)
    assert a == [1, 2] and b == [3, 4, 5] and al.free_count == 0
    assert al.alloc(1) is None              # all-or-nothing: no partials
    al.free(a)
    # fragmentation after early retirement: freed ids are reused lowest-
    # first, so a later alloc lands back in the gap deterministically
    assert al.alloc(2) == [1, 2]
    al.free(b)
    with pytest.raises(RuntimeError):
        al.free([3])                        # double free
    with pytest.raises(ValueError):
        al.free([0])                        # the trash block never moves


def test_slotpool_block_table_churn():
    lay = PagedLayout(block_size=8, num_blocks=9, max_len=32)   # cap 8
    pool = SlotPool(3, lay)
    r0 = Request(prompt=np.arange(7, dtype=np.int32), max_new_tokens=8)
    r1 = Request(prompt=np.arange(7, dtype=np.int32), max_new_tokens=8)
    s0 = pool.alloc(r0, 7)                  # needs ceil(16/8) = 2 blocks
    s1 = pool.alloc(r1, 7)
    assert list(pool.block_table[s0][:2]) == [1, 2]
    assert list(pool.block_table[s1][:2]) == [3, 4]
    assert pool.block_table[s0][2:].tolist() == [0, 0]   # tail -> trash
    assert pool.resident_tokens == 32
    pool.release(s0)
    # the released row is zeroed so an in-flight decode for the retired
    # slot scatters into the trash block, never another request's block
    assert pool.block_table[s0].tolist() == [0, 0, 0, 0]
    assert pool.resident_tokens == 16
    r2 = Request(prompt=np.arange(20, dtype=np.int32), max_new_tokens=3)
    s2 = pool.alloc(r2, 20)                 # ceil(24/8) = 3: reuse + fresh
    assert list(pool.block_table[s2][:3]) == [1, 2, 5]
    assert pool.peak_resident_tokens == 40
    # 3 blocks free (s1 holds 2, s2 holds 3): a 4-block head is refused
    big = Request(prompt=np.arange(9, dtype=np.int32), max_new_tokens=21)
    fits = pool.admit_checker()
    assert fits(big) is False
    small = Request(prompt=np.arange(3, dtype=np.int32), max_new_tokens=3)
    assert fits(small) is True              # 1 block: fits the remainder


def test_admission_backpressure_queues_not_crashes():
    cfg = smoke_config("llama3-8b")
    q = ArrivalQueue(clock=lambda: 0.0)
    head = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=8,
                   arrival_time=0.0)
    tail = Request(prompt=np.arange(8, dtype=np.int32), max_new_tokens=8,
                   arrival_time=1.0)
    q.submit(head), q.submit(tail)
    # head-of-line does not fit -> no admission at all (FIFO preserved)
    got = q.pop_admission(2.0, 2, cfg, 64, 2, fits=lambda r: False)
    assert got is None and len(q) == 2
    # head fits, tail does not -> tail is skipped but stays queued
    seen = []
    got = q.pop_admission(2.0, 2, cfg, 64, 2,
                          fits=lambda r: seen.append(r) or len(seen) == 1)
    assert got is not None and got[1] == [head]
    assert len(q) == 1


# ==========================================================================
# scheduler: paged vs dense
# ==========================================================================

def test_paged_equals_dense_greedy(llama):
    """Exact token equality between the paged and dense pools over a
    churn-heavy mix (admissions between decodes, early retirements)."""
    cfg, params = llama
    lens = [5, 8, 13, 8, 5, 16]
    mns = [4, 9, 3, 5, 7, 4]
    dense = ContinuousBatchingScheduler(cfg, params, max_slots=3,
                                        max_len=64)
    a = make_requests(cfg, lens, mns)
    dense.serve(a)
    paged = ContinuousBatchingScheduler(cfg, params, max_slots=3,
                                        max_len=64, page_size=16)
    b = make_requests(cfg, lens, mns)
    paged.serve(b)
    for i, (x, y) in enumerate(zip(a, b)):
        assert x.out_tokens == y.out_tokens, f"request {i}"
    st = paged.stats
    assert st["phase"] == "co-execution"
    assert st["retraces"] == 0 and st["replays"] == 0
    assert st["families"] == 1
    assert st["peak_resident_tokens"] > 0


def test_paged_high_concurrency_beyond_dense_capacity(llama):
    """With blocks sized to HALF the dense arena (8 slots x 64 tokens),
    the paged pool still runs 16 requests concurrently — admission is
    bounded by tokens resident, not by worst-case rows."""
    cfg, params = llama
    n, L, mn = 16, 8, 8                     # 2 blocks each at bs=16
    paged = ContinuousBatchingScheduler(
        cfg, params, max_slots=n, max_len=64, page_size=16,
        num_blocks=33)                      # capacity 32 blocks = 512 tok
    peaks = []
    reqs = make_requests(
        cfg, [L] * n, [mn] * n,
        stream=lambda r, t, i: peaks.append(paged.pool.active_count))
    paged.serve(reqs)
    assert all(len(r.out_tokens) == mn for r in reqs)
    st = paged.stats
    assert st["retired"] == n and st["retraces"] == 0
    dense_equiv_slots = (33 - 1) * 16 // 64     # same memory, dense rows
    assert max(peaks) > dense_equiv_slots       # ran past dense capacity
    assert st["peak_resident_tokens"] <= 512


def test_paged_arena_exhaustion_backpressure(llama):
    """A tiny arena (2 concurrent requests max) forces the rest of the
    queue to wait for retirements; everything completes with tokens
    identical to an uncontended paged run."""
    cfg, params = llama
    lens, mns = [8, 8, 8, 8, 8], [6, 6, 6, 6, 6]
    wide = ContinuousBatchingScheduler(cfg, params, max_slots=5,
                                       max_len=32, page_size=8)
    a = make_requests(cfg, lens, mns)
    wide.serve(a)
    tight = ContinuousBatchingScheduler(
        cfg, params, max_slots=5, max_len=32, page_size=8,
        num_blocks=4)                       # capacity 3: one 2-block req
    b = make_requests(cfg, lens, mns)
    tight.serve(b)
    for i, (x, y) in enumerate(zip(a, b)):
        assert x.out_tokens == y.out_tokens, f"request {i}"
    assert tight.stats["retired"] == len(lens)
    # a request that can never fit is rejected up front, not deadlocked
    with pytest.raises(ValueError):
        tight.submit(Request(prompt=np.arange(9, dtype=np.int32),
                             max_new_tokens=15))


# ==========================================================================
# Pallas paged-attention kernel
# ==========================================================================

def test_paged_attention_kernel_matches_oracle():
    from repro.kernels import ops as kops
    rng = np.random.RandomState(0)
    B, Hq, Hkv, D, bs, nbps, nblocks = 3, 4, 2, 16, 8, 4, 9
    q = jnp.asarray(rng.randn(B, 1, Hq, D).astype(np.float32))
    kp = jnp.asarray(rng.randn(nblocks, bs, Hkv, D).astype(np.float32))
    vp = jnp.asarray(rng.randn(nblocks, bs, Hkv, D).astype(np.float32))
    bt = np.zeros((B, nbps), np.int32)
    bt[:, :2] = rng.permutation(np.arange(1, nblocks))[:B * 2].reshape(B, 2)
    bt = jnp.asarray(bt)
    valid = jnp.asarray(np.array([5, 9, 16], np.int32))
    out = kops.paged_attention(q, kp, vp, bt, valid)
    ref = kops.paged_attention(q, kp, vp, bt, valid, use_ref=True)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    outw = kops.paged_attention(q, kp, vp, bt, valid, window=6)
    refw = kops.paged_attention(q, kp, vp, bt, valid, window=6, use_ref=True)
    np.testing.assert_allclose(outw, refw, rtol=2e-5, atol=2e-5)


def test_paged_kernel_substitution_in_scheduler(llama):
    """With the ``kernels`` pass named explicitly, the pass pipeline
    rewrites the paged ``serve.slot_decode`` node to the Pallas kernel op
    (interpret-mode off-TPU) and tokens stay identical to the dense run."""
    cfg, params = llama
    lens, mns = [5, 9], [4, 3]
    dense = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                        max_len=32)
    a = make_requests(cfg, lens, mns)
    dense.serve(a)
    paged = ContinuousBatchingScheduler(
        cfg, params, max_slots=2, max_len=32, page_size=8,
        optimize=("cse", "kernels", "dce", "coalesce"))
    b = make_requests(cfg, lens, mns)
    paged.serve(b)
    assert paged.stats["kernels_substituted"] >= 1
    for i, (x, y) in enumerate(zip(a, b)):
        assert x.out_tokens == y.out_tokens, f"request {i}"


# ==========================================================================
# zero-walker steady state
# ==========================================================================

def test_steady_state_entry_and_exact_values():
    v = Variable(np.zeros(4, np.float32), "steady_v")

    @function(optimize="safe", steady_state=3, steady_probe=5)
    def step(x):
        y = ops.mul(x, 2.0)
        v.assign(ops.add(v.read(), y))
        return y

    outs = []
    for i in range(20):
        outs.append(np.asarray(step(np.full(4, float(i + 1), np.float32))))
    st = step.stats
    assert st["steady_entries"] == 1 and st["steady_exits"] == 0
    assert st["steady_iters"] > 0
    # every steady_probe-th call revalidates through the full walker path
    assert st["steady_iters"] < st["iterations"]
    for i, o in enumerate(outs):
        np.testing.assert_allclose(o, np.full(4, 2.0 * (i + 1)))
    total = sum(2.0 * (i + 1) for i in range(20))
    np.testing.assert_allclose(
        np.asarray(step.engine.variable_value(v)), np.full(4, total))
    step.close()


def test_steady_state_exit_on_control_flow_change():
    """A Python-value-driven branch change misses the steady plan's baked
    constant, runs the walker, diverges, and drops the plan — slower
    never wrong: the new branch's value is exact."""
    v = Variable(np.zeros(4, np.float32), "steady_w")

    @function(optimize="safe", steady_state=3, steady_probe=100)
    def step(x, flag):
        y = ops.mul(x, 2.0) if flag else ops.add(x, 10.0)
        v.assign(ops.add(v.read(), y))
        return y

    one = np.full(4, 1.0, np.float32)
    for _ in range(8):
        np.testing.assert_allclose(np.asarray(step(one, 1)), np.full(4, 2.0))
    st = step.stats
    assert st["steady_entries"] == 1 and st["steady_iters"] > 0
    np.testing.assert_allclose(np.asarray(step(one, 0)), np.full(4, 11.0))
    st = step.stats
    assert st["steady_exits"] >= 1          # plan dropped, not reused
    np.testing.assert_allclose(
        np.asarray(step.engine.variable_value(v)), np.full(4, 8 * 2.0 + 11.0))
    step.close()


def test_steady_state_python_observation_poisons_entry():
    """An iteration whose skeleton reads device state through Python
    (variable_value) is never counted toward the steady streak — Python
    visibility means the fn cannot be skipped."""
    v = Variable(np.zeros(2, np.float32), "steady_p")

    @function(optimize="safe", steady_state=2, steady_probe=100)
    def step(x):
        v.assign(ops.add(v.read(), x))
        float(np.asarray(step.engine.variable_value(v))[0])  # Python sees
        return ops.mul(x, 1.0)

    for i in range(8):
        step(np.full(2, 1.0, np.float32))
    st = step.stats
    assert st["steady_entries"] == 0 and st["steady_iters"] == 0
    step.close()
