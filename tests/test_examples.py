"""Smoke coverage for the runnable examples: the serving demos'
main() paths execute end-to-end on a tiny config — API drift in the
engine/scheduler surface breaks here instead of on users."""

import sys

import pytest


def _run_main(module, argv, monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", argv)
    module.main()
    return capsys.readouterr().out


def test_serve_demo_main_path(monkeypatch, capsys):
    from examples import serve_demo
    out = _run_main(serve_demo,
                    ["serve_demo", "--arch", "llama3-8b", "--batch", "2",
                     "--prompt-len", "8", "--max-new", "4"],
                    monkeypatch, capsys)
    assert "generated=8 tokens" in out
    assert "decode phase: co-execution" in out


def test_serve_continuous_main_path(monkeypatch, capsys):
    from examples import serve_continuous
    out = _run_main(serve_continuous,
                    ["serve_continuous", "--arch", "llama3-8b",
                     "--requests", "4", "--max-slots", "2",
                     "--max-len", "64", "--mean-gap-ms", "1"],
                    monkeypatch, capsys)
    assert "retired=4" in out
    assert "phase=co-execution" in out
    assert "retraces=0" in out


@pytest.fixture(autouse=True)
def _examples_importable(monkeypatch):
    """examples/ is not a package dir on sys.path by default."""
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    monkeypatch.syspath_prepend(root)
