"""Behaviour tests for the executor package: compatibility shim, the
cross-version compiled-segment cache, donated variable buffers, and the
divergence fallback's replay contract."""

import numpy as np
import pytest

from repro.core import Variable, function, ops


def test_runner_shim_reexports():
    """Historical import paths keep working after the decomposition."""
    from repro.core.runner import (SKELETON, TRACING, DivergenceError,
                                   GraphRunner, TerraEngine, Walker)
    from repro.core.executor import TerraEngine as NewEngine
    assert TerraEngine is NewEngine
    assert isinstance(TRACING, str) and isinstance(SKELETON, str)
    assert DivergenceError is not None and Walker is not None
    assert GraphRunner is not None


def test_executor_modules_stay_small():
    """The decomposition contract: no executor (or passes, serving
    scheduler, events, or kernels) module regrows past ~350 lines, and
    the shim stays under 50."""
    import os
    import repro.core.events as events
    import repro.core.executor as ex
    import repro.core.passes as passes
    import repro.core.persist as persist
    import repro.kernels as kern
    import repro.obs as obs
    import repro.serve.scheduler as sched
    for pkg in (ex, passes, sched, kern, events, persist, obs):
        pkg_dir = os.path.dirname(pkg.__file__)
        pkg_name = os.path.basename(pkg_dir)
        for name in os.listdir(pkg_dir):
            if not name.endswith(".py"):
                continue
            with open(os.path.join(pkg_dir, name)) as f:
                n = sum(1 for _ in f)
            assert n <= 360, f"{pkg_name}/{name} has {n} lines"
    import repro.core.runner as shim
    with open(shim.__file__.replace(".pyc", ".py")) as f:
        assert sum(1 for _ in f) < 50, "runner.py shim regrew"


def test_segment_cache_hit_after_divergence():
    """A TraceGraph version bump that leaves a segment structurally
    unchanged must reuse its jitted fn (observable as a cache hit)."""
    class Cfg:
        scale = 1.0
    cfg = Cfg()

    @function
    def step(x):
        y = ops.mul(x, 2.0)
        s = float(ops.reduce_sum(y))       # gating fetch: segment boundary
        z = ops.mul(y, cfg.scale)          # baked const -> diverges on change
        return float(ops.reduce_sum(z)) + 0.0 * s

    for i in range(4):
        step(np.full(4, i + 1.0, np.float32))
    assert step.phase == "co-execution"
    base_hits = step.stats["segment_cache_hits"]
    base_recompiled = step.stats["segments_recompiled"]

    cfg.scale = 3.0                        # forced divergence (Fig. 1c class)
    for i in range(4, 9):
        x = np.full(4, i + 1.0, np.float32)
        got = step(x)
        assert got == pytest.approx(float((x * 2 * 3.0).sum())), f"iter {i}"
    assert step.phase == "co-execution"
    assert step.stats["replays"] >= 1
    # the pre-fetch segment did not change: its compiled fn was reused ...
    assert step.stats["segment_cache_hits"] >= base_hits + 1
    # ... and only the changed region recompiled (not the whole program)
    assert step.stats["segments_recompiled"] == base_recompiled + 1
    step.close()


def test_segment_cache_reuses_fn_object():
    """Same-structure regeneration returns the identical compiled callable."""
    from repro.core.graphgen import GraphProgram

    @function
    def step(x):
        y = ops.mul(x, 2.0)
        s = float(ops.reduce_sum(y))
        z = ops.add(y, 1.0)
        return float(ops.reduce_sum(z)) + 0.0 * s

    for i in range(3):
        step(np.full(4, i + 1.0, np.float32))
    eng = step.engine
    old_fns = [sp.fn for sp in eng.gp.seg_progs]
    # regeneration carries the pass results (opt) of the live program:
    # same optimized structure -> identical cached callables
    regen = GraphProgram(eng.tg, {vid: v.aval for vid, v in eng.vars.items()},
                         seg_cache=eng.seg_cache, opt=eng.gp.opt)
    assert [sp.fn for sp in regen.seg_progs] == old_fns
    step.close()


def test_coalesced_segments_not_recompiled_on_regeneration():
    """Segment signatures are computed strictly POST-pass: regenerating a
    program whose optimized (coalesced) form is unchanged must be a pure
    cache hit — the pre-pass layout never reaches the cache key, so the
    coalesced segment cannot be spuriously recompiled."""
    from repro.core.graphgen import GraphProgram

    @function(optimize="all")
    def step(x):
        a = ops.mul(x, 2.0)
        sa = ops.reduce_sum(a)
        b = ops.mul(a, 3.0)
        sb = ops.reduce_sum(b)
        return float(sa) + float(sb)       # late reads -> boundary coalesces

    r = np.random.RandomState(0)
    for _ in range(6):
        step(r.randn(4).astype(np.float32))
    eng = step.engine
    assert step.phase == "co-execution"
    assert step.stats["segments_coalesced"] >= 1
    base_misses = eng.seg_cache.misses
    regen = GraphProgram(eng.tg, {vid: v.aval for vid, v in eng.vars.items()},
                         seg_cache=eng.seg_cache, opt=eng.gp.opt)
    assert eng.seg_cache.misses == base_misses, \
        "identical optimized segments recompiled on regeneration"
    assert [sp.fn for sp in regen.seg_progs] == \
        [sp.fn for sp in eng.gp.seg_progs]
    step.close()


def test_divergence_replays_validated_prefix_exactly_once():
    class Cfg:
        k = 1.0
    cfg = Cfg()

    @function
    def step(x):
        a = ops.mul(x, 2.0)
        b = ops.add(a, 1.0)
        c = ops.mul(b, cfg.k)              # divergence point when k changes
        return ops.reduce_sum(c)

    for i in range(3):
        step(np.full(4, 1.0, np.float32))
    assert step.phase == "co-execution"
    assert step.stats["replays"] == 0

    cfg.k = 2.0
    x = np.full(4, 1.0, np.float32)
    got = float(step(x))
    assert got == pytest.approx(float(((x * 2 + 1) * 2).sum()))
    # exactly one fallback, replaying exactly the 2-entry validated prefix
    assert step.stats["replays"] == 1
    assert step.stats["replayed_entries"] == 2
    step.close()


def test_donated_variable_buffers_fire_and_stay_correct():
    """A segment that rewrites a variable first written by an earlier
    segment of the same iteration donates the intermediate buffer."""
    w = Variable(np.ones(1024, np.float32))

    @function
    def step(x):
        w.assign(ops.mul(w.read(), 2.0))
        s = float(ops.reduce_sum(w.read()))  # boundary between the writes
        w.assign(ops.mul(x, 3.0))
        return s

    eng = step.engine
    for i in range(6):
        x = np.full(1024, float(i + 1), np.float32)
        s = step(x)
        # s fetches w*2 where w committed as 3*i at the previous iteration
        want = (1.0 if i == 0 else 3.0 * i) * 2 * 1024
        assert s == pytest.approx(want), f"iter {i}"
        # the committed store value stays correct after donation
        np.testing.assert_allclose(np.asarray(eng.variable_value(w)),
                                   np.full(1024, 3.0 * (i + 1)))
    step.wait()
    assert step.stats["donated_bytes"] > 0
    # iteration-start buffers are snapshot-protected: only the intermediate
    # (first-write) buffer is donated, once per co-executed iteration
    assert step.stats["donated_bytes"] % 4096 == 0
    step.close()


def test_donation_never_marks_snapshot_buffers():
    """Static eligibility: a variable whose only write in the program is
    its first write must never be marked donatable (the snapshot owns the
    iteration-start buffer)."""
    w = Variable(np.ones(8, np.float32))

    @function
    def step(x):
        y = ops.mul(w.read(), x)
        w.assign(ops.add(w.read(), 1.0))
        return ops.reduce_sum(y)

    for i in range(4):
        step(np.full(8, 1.0, np.float32))
    assert step.phase == "co-execution"
    assert step.engine.gp.donatable_var_ids == set()
    assert step.stats["donated_bytes"] == 0
    step.close()


def test_divergence_after_donating_segments_rolls_back():
    """Divergence cancellation must survive donation: the snapshot holds
    the iteration-start buffers, which are never donated."""
    class Cfg:
        flip = False
    cfg = Cfg()
    w = Variable(np.full(256, 2.0, np.float32))

    @function
    def step(x):
        w.assign(ops.mul(w.read(), 2.0))
        s = float(ops.reduce_sum(w.read()))
        w.assign(ops.mul(x, 3.0))
        if cfg.flip:                      # Python-level change -> divergence
            w.assign(ops.add(w.read(), 1.0))
        return s

    for i in range(4):
        step(np.full(256, float(i + 1), np.float32))
    assert step.stats["donated_bytes"] > 0
    cfg.flip = True
    x = np.full(256, 9.0, np.float32)
    step(x)
    assert step.stats["replays"] == 1
    np.testing.assert_allclose(
        np.asarray(step.engine.variable_value(w)), np.full(256, 28.0))
    step.close()


def test_serving_decode_coexecutes():
    """The serving engine's decode loop runs under Terra co-execution and
    its TraceGraph (and compiled segments) survive batch boundaries."""
    import jax
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=48)
    rng = np.random.RandomState(0)
    for _ in range(2):
        reqs = [Request(prompt=rng.randint(0, cfg.vocab, 8).astype(np.int32),
                        max_new_tokens=6) for _ in range(2)]
        out = engine.run_batch(reqs)
        for r in out:
            assert len(r.out_tokens) == 6
    assert engine.terra.phase == "co-execution"
    stats = engine.terra.stats
    assert stats["replays"] == 0
    assert stats["graph_versions"] == 1       # one graph serves both batches
    engine.terra.close()
