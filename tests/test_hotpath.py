"""Hot-path behaviour tests (ISSUE 2): precomputed dispatch plans,
per-value synchronization, the Walker stamp fast path, runner error
containment, and the feeds_defaulted / runner-time stat exports."""

import threading

import numpy as np
import pytest

from repro.core import Variable, function, ops


# ==========================================================================
# per-value synchronization
# ==========================================================================

def test_early_fetch_does_not_block_on_trailing_segments():
    """Reading a variable written by an early segment must not wait for a
    trailing segment of the same iteration: the GraphRunner queue is gated
    behind an Event after the early segment, and the read must return while
    the trailing writer is still pending."""
    a = Variable(np.ones(8, np.float32), "pv_a")
    b = Variable(np.ones(8, np.float32), "pv_b")
    gate = threading.Event()
    hook = [None]

    @function
    def step(x):
        a.assign(ops.mul(x, 2.0))
        s = float(ops.reduce_sum(a.read()))    # gating fetch -> boundary
        if hook[0]:
            hook[0]()                          # wedge the runner queue
        b.assign(ops.mul(x, 5.0))              # trailing segment writes b
        return s

    for i in range(3):
        step(np.full(8, float(i + 1), np.float32))
    eng = step.engine
    assert step.phase == "co-execution"

    # watchdog: a regression that reintroduces a full drain would deadlock
    # on the gate — release it after 20s so the test fails instead of hangs
    watchdog = threading.Timer(20.0, gate.set)
    watchdog.start()
    try:
        hook[0] = lambda: eng.runner.submit(gate.wait)
        x = np.full(8, 7.0, np.float32)
        s = step(x)
        assert s == pytest.approx(8 * 14.0)
        # reading a blocks only on a's writer (already done), never on the
        # whole queue: b's writer must still be pending when this returns
        val = np.asarray(eng.variable_value(a))
        fence_b = eng.store.write_fence(b.var_id)
        assert fence_b is not None and not eng.runner.done(fence_b), \
            "trailing segment already ran — variable_value drained the queue"
        assert not gate.is_set(), "watchdog fired: variable_value blocked"
        np.testing.assert_allclose(val, np.full(8, 14.0))
    finally:
        gate.set()
        watchdog.cancel()
    step.wait()
    np.testing.assert_allclose(np.asarray(eng.variable_value(b)),
                               np.full(8, 35.0))
    step.close()


def test_variable_value_mid_iteration_under_donation():
    """A mid-iteration variable read of a donatable buffer returns a
    private copy of the intermediate value, and the copy survives the later
    segment donating the buffer."""
    w = Variable(np.ones(64, np.float32), "don_w")
    probe = [False]
    seen = []

    @function
    def step(x):
        w.assign(ops.mul(w.read(), 2.0))
        s = float(ops.reduce_sum(w.read()))    # boundary between the writes
        if probe[0]:
            seen.append(np.asarray(step.engine.variable_value(w)).copy())
        w.assign(ops.mul(x, 3.0))              # donates the intermediate
        return s

    for i in range(4):
        step(np.full(64, float(i + 1), np.float32))
    assert step.phase == "co-execution"
    assert step.engine.gp.donatable_var_ids == {w.var_id}

    probe[0] = True
    for i in range(4, 7):
        x = np.full(64, float(i + 1), np.float32)
        step(x)
        # mid-iteration value: committed w (= 3*x_prev) doubled by seg 0
        np.testing.assert_allclose(seen[-1], np.full(64, 3.0 * i * 2.0))
    step.wait()
    assert step.stats["donated_bytes"] > 0
    # the private copies were not clobbered by the donation
    for j, i in enumerate(range(4, 7)):
        np.testing.assert_allclose(seen[j], np.full(64, 6.0 * i))
    step.close()


def test_variable_value_after_divergence_rollback():
    """After divergence cancellation the store is rolled back and finished
    imperatively; variable_value (mid-iteration and after) must reflect the
    imperative values, not the cancelled symbolic ones."""
    class Cfg:
        k = 1.0
    cfg = Cfg()
    w = Variable(np.full(16, 2.0, np.float32), "rb_w")
    probe = [False]
    seen = []

    @function
    def step(x):
        w.assign(ops.mul(w.read(), 2.0))
        s = float(ops.reduce_sum(w.read()))
        w.assign(ops.mul(x, cfg.k))            # baked const: diverges on k
        if probe[0]:
            seen.append(np.asarray(step.engine.variable_value(w)).copy())
        return s

    for i in range(3):
        step(np.full(16, float(i + 1), np.float32))
    assert step.phase == "co-execution"

    probe[0] = True
    cfg.k = 4.0
    x = np.full(16, 9.0, np.float32)
    step(x)
    assert step.stats["replays"] == 1
    # post-divergence the iteration finished imperatively: the mid-iteration
    # read and the committed value both see the eager x*k binding
    np.testing.assert_allclose(seen[-1], x * 4.0)
    np.testing.assert_allclose(np.asarray(step.engine.variable_value(w)),
                               x * 4.0)
    step.close()


# ==========================================================================
# dispatch plans + feeds_defaulted
# ==========================================================================

def test_dispatch_plans_are_precomputed():
    """Every compiled segment carries a DispatchPlan whose tuples mirror
    the segment IO analysis and the global selector/trip slot orders."""
    w = Variable(np.ones(4, np.float32), "plan_w")

    @function
    def step(x):
        y = ops.mul(w.read(), x)
        s = float(ops.reduce_sum(y))           # boundary -> two segments
        w.assign(ops.add(w.read(), 1.0))
        return s

    for i in range(3):
        step(np.full(4, 1.0, np.float32))
    gp = step.engine.gp
    assert gp is not None and len(gp.seg_progs) >= 2
    for sp in gp.seg_progs:
        plan = sp.plan
        assert plan is not None
        assert plan.don_var_ids == tuple(sp.don_var_ids)
        assert plan.keep_var_ids == tuple(sp.keep_var_ids)
        assert plan.var_writes == tuple(sp.var_writes)
        assert plan.feed_keys == tuple(sp.feed_keys)
        assert plan.fetch_keys == tuple(sp.fetch_keys)
        # slot orders: position in the tuple == globally assigned slot
        assert [gp.selector_slot[u] for u in plan.sel_uids] == \
            list(range(len(plan.sel_uids)))
        assert [gp.trip_slot[u] for u in plan.trip_uids] == \
            list(range(len(plan.trip_uids)))
    step.close()


def test_feeds_defaulted_stays_zero_on_covered_linear_program():
    """A linear covered program must never silently substitute zeros for a
    missing Input Feeding value (the defaulting path is only legitimate for
    feed slots inside untaken branch regions)."""
    w = Variable(np.ones(8, np.float32), "fd_w")

    @function
    def step(x, y):
        h = ops.add(ops.mul(w.read(), x), y)   # x, y are Input Feeding
        s = float(ops.reduce_sum(h))
        w.assign(ops.mul(w.read(), 0.5))
        return s

    for i in range(6):
        step(np.full(8, float(i + 1), np.float32),
             np.full(8, 0.5, np.float32))
    assert step.phase == "co-execution"
    assert step.stats["feeds_defaulted"] == 0
    step.close()


def test_feeds_defaulted_counts_untaken_branch_slots():
    """Feed slots inside the branch NOT taken this iteration are filled
    with zeros when the enclosing switch region dispatches — that is the
    one legitimate defaulting case, and it is counted."""
    w = Variable(np.ones(4, np.float32), "br_w")

    @function
    def step(x, big):
        s = float(ops.reduce_sum(ops.mul(x, 2.0)))   # boundary -> seg 0
        if s > 10.0:
            z = ops.add(ops.mul(x, 3.0), big)        # feed only on this path
        else:
            z = ops.mul(x, 1.5)
        w.assign(z)                                  # phi output of the switch
        return s

    big = np.full(4, 100.0, np.float32)
    vals = [0.5, 3.0, 0.5, 3.0, 0.5, 3.0]
    for v in vals:
        step(np.full(4, v, np.float32), big)
    assert step.phase == "co-execution"
    base = step.stats["feeds_defaulted"]
    step(np.full(4, 0.5, np.float32), big)   # small branch: big not collected
    step.wait()
    assert step.stats["feeds_defaulted"] > base
    np.testing.assert_allclose(np.asarray(step.engine.variable_value(w)),
                               np.full(4, 0.75))
    step.close()


# ==========================================================================
# Walker fast path + stat exports
# ==========================================================================

def test_walker_fast_path_validates_steady_state():
    @function
    def step(x):
        return ops.reduce_sum(ops.add(ops.mul(x, 2.0), 1.0))

    for i in range(6):
        step(np.full(4, float(i + 1), np.float32))
    assert step.phase == "co-execution"
    # steady-state iterations validate every op via the stamp comparison
    assert step.stats["walker_fast_hits"] >= 6
    step.close()


def test_fast_path_falls_back_structurally_not_to_divergence():
    """Clearing every node stamp disables the fast path; validation must
    still succeed through the full structural comparison (a stamp mismatch
    is never treated as divergence)."""
    @function
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, 3.0)))

    for i in range(4):
        step(np.full(4, float(i + 1), np.float32))
    assert step.phase == "co-execution"
    eng = step.engine
    for n in eng.tg.nodes.values():
        n.entry_stamp = None                   # kill all stamps
    base_replays = step.stats["replays"]
    out = step(np.full(4, 5.0, np.float32))
    assert out == pytest.approx(4 * 15.0)
    assert step.stats["replays"] == base_replays    # no divergence
    assert step.phase == "co-execution"
    step.close()


def test_runner_time_stats_exported():
    @function
    def step(x):
        w = ops.mul(x, 2.0)
        return ops.reduce_sum(w)

    for i in range(5):
        step(np.full(4, 1.0, np.float32))
    step.wait()                                # sync mirrors runner times
    assert step.stats["runner_exec_time"] == pytest.approx(
        step.engine.runner.exec_time)
    assert step.stats["runner_stall_time"] == pytest.approx(
        step.engine.runner.stall_time)
    assert step.stats["runner_exec_time"] > 0.0
    step.close()


def test_runner_survives_closure_exception():
    """A raising closure must not kill the runner thread (a dead worker
    would hang every later fence wait): its sequence still completes,
    sync() re-raises the stashed error once, and the engine keeps working."""
    @function
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, 2.0)))

    for i in range(4):
        step(np.full(4, 1.0, np.float32))
    eng = step.engine

    def boom():
        raise RuntimeError("boom")

    seq = eng.runner.submit(boom)
    eng.runner.wait_for(seq)                   # fence releases despite raise
    with pytest.raises(RuntimeError, match="boom"):
        step.wait()                            # sync surfaces the error once
    out = step(np.full(4, 3.0, np.float32))    # worker thread still alive
    assert out == pytest.approx(4 * 6.0)
    step.wait()
    step.close()


def test_lazy_mode_per_value_fences():
    """Lazy mode (no runner thread) must still resolve per-value fences by
    executing queued work on the calling thread."""
    w = Variable(np.ones(4, np.float32), "lz_w")

    @function(lazy=True)
    def step(x):
        w.assign(ops.mul(w.read(), x))
        return ops.reduce_sum(w.read())

    for i in range(4):
        step(np.full(4, 2.0, np.float32))
    val = np.asarray(step.engine.variable_value(w))
    np.testing.assert_allclose(val, np.full(4, 2.0 ** 4))
    step.close()
