"""Unit + property tests for TraceGraph merging, loop rolling and the case
assignment structure (hypothesis drives randomized trace families)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.ops import Const
from repro.core.trace import Aval, Ref, Trace, TraceEntry
from repro.core.tracegraph import LoopEntry, TraceGraph, roll_loops
from repro.core.casing import NodeItem, Structure, SwitchItem

AV = (Aval((2, 2), "float32"),)


def entry(name, loc, refs=(), attrs=()):
    return TraceEntry(op_name=name, attrs=tuple(attrs),
                      location=("prog.py", loc), input_refs=tuple(refs),
                      out_avals=AV)


def make_trace(specs):
    """specs: list of (name, loc, input_entry_indices)."""
    t = Trace()
    for name, loc, ins in specs:
        e = entry(name, loc, refs=[Ref(i, 0) for i in ins])
        t.add_entry(e)
    return t


def merge_all(tg, traces):
    results = []
    for t in traces:
        results.append(tg.merge_trace(t, roll_loops(t)))
    return results


def test_identical_traces_covered_after_first():
    tg = TraceGraph()
    specs = [("a", 1, []), ("b", 2, [0]), ("c", 3, [1])]
    r = merge_all(tg, [make_trace(specs), make_trace(specs)])
    assert r == [False, True]
    assert tg.n_ops() == 3


def test_branching_creates_fork_and_merges_back():
    tg = TraceGraph()
    t1 = make_trace([("a", 1, []), ("b", 2, [0])])
    t2 = make_trace([("a", 1, []), ("c", 5, [0])])
    merge_all(tg, [t1, t2])
    assert len(tg.forks()) == 1
    # both traces now covered
    assert tg.merge_trace(make_trace([("a", 1, []), ("b", 2, [0])]),
                          roll_loops(make_trace([("a", 1, []),
                                                 ("b", 2, [0])])))


def test_same_op_different_location_does_not_merge():
    tg = TraceGraph()
    t1 = make_trace([("a", 1, []), ("b", 2, [0])])
    t2 = make_trace([("a", 1, []), ("b", 9, [0])])
    merge_all(tg, [t1, t2])
    assert tg.n_ops() == 3          # two distinct 'b' nodes (paper App. A)


def test_loop_rolling_detects_tandem_repeat():
    # x = a(); then 5x: x = f(x) at the same location
    specs = [("a", 1, [])] + [("f", 2, [i]) for i in range(0, 5)]
    t = make_trace(specs)
    rolled = roll_loops(t)
    loops = [ev for ev in rolled if isinstance(ev, LoopEntry)]
    assert len(loops) == 1
    assert loops[0].trips == 5
    assert len(loops[0].body.entries) == 1


def test_loop_trip_variation_goes_dynamic():
    tg = TraceGraph()
    for n in (3, 5):
        specs = [("a", 1, [])] + [("f", 2, [i]) for i in range(0, n)]
        t = make_trace(specs)
        tg.merge_trace(t, roll_loops(t))
    loop_nodes = [x for x in tg.nodes.values() if x.kind == "loop"]
    assert len(loop_nodes) == 1
    assert loop_nodes[0].trips == {3, 5}


def test_structure_is_exhaustive_and_non_duplicating():
    tg = TraceGraph()
    t1 = make_trace([("a", 1, []), ("b", 2, [0]), ("d", 4, [1])])
    t2 = make_trace([("a", 1, []), ("c", 3, [0]), ("d", 8, [1])])
    merge_all(tg, [t1, t2])
    s = Structure(tg)
    uids = s.uids_in(s.program)
    op_uids = [u for u, n in tg.nodes.items() if n.kind in ("op", "loop")]
    assert sorted(uids) == sorted(op_uids)


# --------------------------------------------------------------------------
# hypothesis: random branching programs
# --------------------------------------------------------------------------

@st.composite
def branching_program(draw):
    """A random program: chain of ops where some steps branch on a coin."""
    n = draw(st.integers(2, 6))
    branch_at = draw(st.sets(st.integers(0, n - 1), max_size=2))
    return n, branch_at


@settings(max_examples=30, deadline=None)
@given(branching_program(), st.lists(st.booleans(), min_size=1, max_size=6))
def test_random_traces_always_covered_eventually(prog, coins):
    n, branch_at = prog
    tg = TraceGraph()

    def trace_for(coin):
        specs = []
        prev = None
        for i in range(n):
            loc = 10 * i + (1 if (i in branch_at and coin) else 0)
            specs.append((f"op{i}", loc, [] if prev is None else [prev]))
            prev = i
        return make_trace(specs)

    for c in coins:
        t = trace_for(c)
        tg.merge_trace(t, roll_loops(t))
    # replaying any already-seen coin must be covered
    for c in {c for c in coins}:
        t = trace_for(c)
        assert tg.merge_trace(t, roll_loops(t)), "seen trace not covered"
    # the DAG must remain structurable (case assignment total)
    Structure(tg)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(2, 7), min_size=1, max_size=4))
def test_dynamic_loops_cover_all_trip_counts(trip_counts):
    tg = TraceGraph()

    def trace_for(k):
        specs = [("a", 1, [])] + [("f", 2, [i]) for i in range(0, k)]
        return make_trace(specs)

    for k in trip_counts:
        tg.merge_trace(trace_for(k), roll_loops(trace_for(k)))
    for k in set(trip_counts):
        assert tg.merge_trace(trace_for(k), roll_loops(trace_for(k)))
    if len(set(trip_counts)) > 1:
        ln = [x for x in tg.nodes.values() if x.kind == "loop"]
        assert ln and len(ln[0].trips) == len(set(trip_counts))
