"""Pallas kernel validation: interpret-mode execution vs pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as K
from repro.kernels import ref as R


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


ATTN_SWEEP = [
    # (B, H, Hkv, Sq, Skv, D, causal, window)
    (1, 4, 4, 128, 128, 64, True, 0),
    (2, 8, 2, 256, 256, 64, True, 0),          # GQA
    (1, 4, 1, 128, 128, 128, True, 0),         # MQA
    (2, 4, 4, 128, 128, 64, False, 0),         # bidirectional
    (1, 4, 2, 256, 256, 64, True, 64),         # sliding window
    (1, 2, 2, 64, 256, 64, False, 0),          # cross-shape (Sq != Skv)
]


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("case", ATTN_SWEEP)
def test_flash_attention_matches_ref(case, dtype):
    B, H, Hkv, Sq, Skv, D, causal, window = case
    if causal and Sq != Skv:
        pytest.skip("causal requires square for this sweep")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    dt = jnp.dtype(dtype)
    q = _rand(ks[0], (B, H, Sq, D), dt)
    k = _rand(ks[1], (B, Hkv, Skv, D), dt)
    v = _rand(ks[2], (B, Hkv, Skv, D), dt)
    got = K.flash_attention(q, k, v, causal=causal, window=window,
                            q_block=64, kv_block=64)
    want = R.ref_attention(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == "bfloat16" else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


SSD_SWEEP = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 32, 32, 32),
    (1, 128, 2, 64, 16, 64),
    (1, 96, 2, 16, 32, 32),    # S not a multiple of chunk -> chunk shrinks
]


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("case", SSD_SWEEP)
def test_ssd_scan_matches_sequential_ref(case, dtype):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    dt_ = jnp.dtype(dtype)
    x = _rand(ks[0], (B, S, H, P), dt_)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32)) * 0.1
    A = -jnp.exp(_rand(ks[2], (H,), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (B, S, N), dt_)
    Cm = _rand(ks[0], (B, S, N), dt_)
    got = K.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    want = R.ref_ssd(x, dt, A, Bm, Cm)
    tol = 5e-2 if dtype == "bfloat16" else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(4, 128), (2, 16, 256), (64, 512)])
def test_rmsnorm_matches_ref(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    dt = jnp.dtype(dtype)
    x = _rand(ks[0], shape, dt)
    g = _rand(ks[1], (shape[-1],), dt) * 0.1
    got = K.rmsnorm(x, g, row_block=16)
    want = R.ref_rmsnorm(x, g)
    tol = 2e-2 if dtype == "bfloat16" else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_ssd_kernel_agrees_with_model_path():
    """The chunked XLA implementation (models/ssm.ssd_chunked) and the
    Pallas kernel must agree — the kernel is a drop-in replacement."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, N = 2, 128, 4, 32, 16
    x = _rand(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (B, S, H), jnp.float32)) * 0.1
    A = -jnp.exp(_rand(ks[2], (H,), jnp.float32) * 0.3)
    Bm = _rand(ks[3], (B, S, N), jnp.float32)
    Cm = _rand(ks[4], (B, S, N), jnp.float32)
    a = K.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    b = ssd_chunked(x, dt, A, Bm, Cm, 32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)
