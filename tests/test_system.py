"""End-to-end behaviour tests for the full system: the imperative Trainer
driven through Terra co-execution (checkpoint/resume included) and the
batched serving engine."""

import tempfile

import numpy as np
import jax
import pytest

pytestmark = pytest.mark.slow       # multi-minute suite; see pytest.ini

from repro.configs import smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def test_trainer_coexec_converges_and_resumes():
    cfg = smoke_config("granite-3-2b")
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=5,
                                    total_steps=100),
                     ckpt_dir=d, batch=4, seq_len=32, log_every=5,
                     ckpt_every=10)
        hist = tr.train(20, verbose=False)
        assert tr._iteration.phase == "co-execution"
        assert hist[-1][1] < hist[0][1]
        tr._iteration.close()

        tr2 = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=5,
                                     total_steps=100),
                      ckpt_dir=d, batch=4, seq_len=32, log_every=5,
                      ckpt_every=100)
        assert tr2.start_step == 20        # auto-resume (fault tolerance)
        h2 = tr2.train(10, verbose=False)
        assert np.isfinite(h2[-1][1])
        tr2._iteration.close()


def test_trainer_straggler_watchdog_fields():
    cfg = smoke_config("mamba2-130m")
    tr = Trainer(cfg, OptConfig(lr=1e-3, warmup_steps=2, total_steps=50),
                 batch=2, seq_len=32, log_every=50)
    tr.train(12, verbose=False)
    assert isinstance(tr.straggler_events, list)   # watchdog active
    tr._iteration.close()


@pytest.mark.parametrize("arch", ["llama3-8b", "recurrentgemma-2b",
                                  "mixtral-8x22b"])
def test_serving_engine_generates(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=64)
    rng = np.random.RandomState(0)
    reqs = [Request(prompt=rng.randint(0, cfg.vocab, 16).astype(np.int32),
                    max_new_tokens=8) for _ in range(4)]
    out = engine.run_batch(reqs)
    for r in out:
        assert len(r.out_tokens) == 8
        assert all(0 <= t < cfg.vocab for t in r.out_tokens)
    assert engine.stats["decode_steps"] >= 7


def test_serving_matches_forward_greedy():
    """Greedy decode through the engine must equal argmax over the full
    forward logits recomputed offline (system-level KV-cache check)."""
    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=32)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab, 8).astype(np.int32)
    out = engine.run_batch([Request(prompt=prompt, max_new_tokens=4)])
    seq = list(prompt)
    import jax.numpy as jnp
    for t in range(4):
        logits = M.forward(cfg, params, np.asarray([seq], np.int32))
        nxt = int(jnp.argmax(logits[0, -1]))
        assert nxt == out[0].out_tokens[t], f"mismatch at step {t}"
        seq.append(nxt)
