"""Substrate tests: optimizer, checkpoint, data pipeline, sharding specs,
pipeline parallelism, gradient compression."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need hypothesis "
                           "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_decreases_quadratic():
    oc = opt.OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                       weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}        # d/dw ||w||^2
        params, state, m = opt.apply(oc, state, grads, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_bf16_params_keep_f32_master():
    oc = opt.OptConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    params2, state2, _ = opt.apply(oc, state, {"w": jnp.ones(4, jnp.bfloat16)},
                                   params)
    assert params2["w"].dtype == jnp.bfloat16
    assert state2["master"]["w"].dtype == jnp.float32


def test_grad_clipping_bounds_update():
    oc = opt.OptConfig(lr=1.0, warmup_steps=0, total_steps=10,
                       clip_norm=1e-3, weight_decay=0.0)
    params = {"w": jnp.zeros((2,))}
    state = opt.init(params)
    _, _, m = opt.apply(oc, state, {"w": jnp.full((2,), 1e6)}, params)
    assert float(m["grad_norm"]) > 1e5       # raw norm reported


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_schedule_monotone_warmup_and_bounded(step):
    oc = opt.OptConfig(lr=3e-4, warmup_steps=100, total_steps=1000)
    lr = float(opt.schedule(oc, jnp.asarray(step, jnp.float32)))
    assert 0.0 <= lr <= oc.lr + 1e-9


# --------------------------------------------------------------------------
# checkpoint
# --------------------------------------------------------------------------

def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((3,), jnp.float32),
                  "d": jnp.zeros((), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree)
        assert ckpt.latest_step(d) == 7
        out = ckpt.restore(d, 7, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_crash_safety_keeps_previous():
    tree = {"a": jnp.ones((2,))}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, tree)
        ckpt.save(d, 2, jax.tree.map(lambda x: x * 2, tree))
        assert ckpt.latest_step(d) == 2
        # step_1 still restorable (atomic commits never corrupt old state)
        out = ckpt.restore(d, 1, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), [1.0, 1.0])


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    ds = data_mod.SyntheticLMDataset(vocab=100, seq_len=8, batch=2, seed=3)
    a = ds.batch_at(5)
    b = ds.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    it = data_mod.PrefetchIterator(ds, start_step=0)
    first = next(it)
    it.seek(5)
    resumed = next(it)
    np.testing.assert_array_equal(resumed["tokens"], a["tokens"])
    it.close()


def test_data_shards_differ():
    d0 = data_mod.SyntheticLMDataset(100, 8, 2, seed=3, shard=0, n_shards=2)
    d1 = data_mod.SyntheticLMDataset(100, 8, 2, seed=3, shard=1, n_shards=2)
    assert not np.array_equal(d0.batch_at(0)["tokens"],
                              d1.batch_at(0)["tokens"])


# --------------------------------------------------------------------------
# sharding specs
# --------------------------------------------------------------------------

def test_param_specs_divisible_everywhere():
    from repro.configs import smoke_config, get_config
    from repro.models import model as M
    from repro.parallel import specs as S

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("llama3-8b")
    aparams = M.abstract_params(cfg)
    spec_tree = S.tree_param_specs(mesh, aparams)
    # every spec must be applicable (no divisibility violations)
    for (path, leaf), spec in zip(
            jax.tree_util.tree_flatten_with_path(aparams)[0],
            jax.tree.leaves(spec_tree,
                            is_leaf=lambda x: hasattr(x, "_normalized_spec")
                            or x.__class__.__name__ == "PartitionSpec")):
        assert len(spec) <= len(leaf.shape)


# --------------------------------------------------------------------------
# pipeline parallelism (on a host-device mesh)
# --------------------------------------------------------------------------

def test_gpipe_pipeline_matches_sequential():
    if jax.device_count() < 2:
        pytest.skip("needs >=2 devices (CI: multidevice job forces 2)")
    from repro.parallel.pipeline import make_pipelined_apply

    n_stages = jax.device_count()
    mesh = jax.make_mesh((n_stages,), ("stage",))
    mb, d = 4, 16
    rng = np.random.RandomState(0)
    ws = jnp.asarray(rng.randn(n_stages, d, d).astype(np.float32) * 0.2)
    xs = jnp.asarray(rng.randn(2 * n_stages, mb, d).astype(np.float32))

    pipe = make_pipelined_apply(mesh, "stage",
                                lambda p, x: jnp.tanh(x @ p["w"]))
    with mesh:
        got = pipe({"w": ws}, xs)

    ref = xs
    for s in range(n_stages):
        ref = jnp.tanh(ref @ ws[s])
    assert float(jnp.abs(got - ref).max()) < 1e-5


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

def test_int8_codec_roundtrip_error_small():
    from repro.parallel import compression as C
    rng = np.random.RandomState(0)
    g = jnp.asarray(rng.randn(1000).astype(np.float32))
    packed = C.compress_int8(g)
    back = C.decompress_int8(packed)
    err = float(jnp.abs(back - g).max() / jnp.abs(g).max())
    assert err < 0.02


def test_bf16_error_feedback_unbiased():
    """With error feedback, repeated compression accumulates no bias: the
    sum of compressed updates converges to the sum of true gradients."""
    from repro.parallel import compression as C
    rng = np.random.RandomState(1)
    g_true = jnp.asarray(rng.randn(64).astype(np.float32)) * 1e-3
    r = jnp.zeros_like(g_true)
    sent = jnp.zeros_like(g_true)
    for _ in range(200):
        g = g_true + r
        c = C.compress_bf16(g)
        r = g - C.decompress_bf16(c)
        sent = sent + C.decompress_bf16(c)
    np.testing.assert_allclose(np.asarray(sent),
                               np.asarray(g_true) * 200, rtol=1e-3,
                               atol=1e-5)


def test_wire_bytes_accounting():
    from repro.parallel import compression as C
    grads = {"w": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
    un, comp = C.wire_bytes_saved(grads, "bf16")
    assert un == 4096 and comp == 2048
