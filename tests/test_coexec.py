"""End-to-end behaviour tests for Terra's imperative-symbolic co-execution."""

import numpy as np
import pytest

from repro.core import (GradientTape, Variable, function, imperative, ops)


def test_imperative_engine_matches_numpy():
    with imperative():
        x = np.arange(6, dtype=np.float32).reshape(2, 3)
        y = ops.add(ops.mul(x, 2.0), 1.0)
        np.testing.assert_allclose(y.numpy(), x * 2 + 1)


def test_gradient_tape_matches_jax():
    import jax
    import jax.numpy as jnp
    w0 = np.random.RandomState(0).randn(3, 3).astype(np.float32)
    x0 = np.random.RandomState(1).randn(3, 3).astype(np.float32)

    with imperative():
        w = Variable(w0, "w")
        with GradientTape() as tape:
            y = ops.matmul(w.read(), x0)
            loss = ops.reduce_sum(ops.square(y))
        g, = tape.gradient(loss, [w])
        got = g.numpy()

    want = jax.grad(lambda w: jnp.sum(jnp.square(w @ x0)))(w0)
    np.testing.assert_allclose(got, np.asarray(want), rtol=1e-5)


def test_coexec_switches_after_coverage():
    w = Variable(np.ones(4, np.float32))

    @function
    def step(x):
        return ops.reduce_sum(ops.mul(w, x))

    outs = [float(step(np.full(4, i + 1.0, np.float32))) for i in range(5)]
    np.testing.assert_allclose(outs, [4.0, 8.0, 12.0, 16.0, 20.0])
    assert step.phase == "co-execution"
    assert step.stats["traced_iterations"] == 2
    step.close()


def test_coexec_correct_across_many_iterations():
    w = Variable(np.full(3, 2.0, np.float32))

    @function
    def step(x):
        y = ops.mul(w, x)
        w.assign_add(ops.mul(ops.ones_like(w.read()), 0.5))
        return ops.reduce_sum(y)

    wv = np.full(3, 2.0)
    for i in range(10):
        x = np.full(3, float(i + 1), np.float32)
        got = float(step(x))
        want = float((wv * x).sum())
        wv = wv + 0.5
        assert got == pytest.approx(want), f"iter {i}"
    step.close()


def test_data_dependent_branch():
    @function
    def step(x):
        y = ops.mul(x, 2.0)
        if float(ops.reduce_sum(y)) > 10.0:      # gating fetch -> branch
            y = ops.mul(y, 10.0)
        else:
            y = ops.add(y, 1.0)
        return ops.reduce_sum(y)

    def ref(x):
        y = x * 2.0
        y = y * 10.0 if y.sum() > 10 else y + 1.0
        return y.sum()

    xs = [np.full(4, v, np.float32)
          for v in (0.5, 0.5, 3.0, 0.5, 3.0, 4.0, 0.1, 5.0)]
    for i, x in enumerate(xs):
        assert float(step(x)) == pytest.approx(float(ref(x))), f"iter {i}"
    assert step.phase == "co-execution"
    step.close()


def test_python_object_mutation_fig1c():
    """The Figure-1c failure class: a Python attribute baked into an op
    changes mid-training.  Terra re-traces and stays correct; a static
    converter would silently reuse the stale constant."""
    class Cfg:
        scale = 1.0
    cfg = Cfg()

    @function
    def step(x):
        return ops.reduce_sum(ops.mul(x, cfg.scale))

    for i in range(8):
        if i == 5:
            cfg.scale = 3.0
        got = float(step(np.ones(4, np.float32)))
        want = 4.0 * (3.0 if i >= 5 else 1.0)
        assert got == pytest.approx(want), f"iter {i}"
    assert step.stats["replays"] >= 1
    step.close()


def test_third_party_library_call():
    """numpy (third-party) transforms a materialized tensor mid-program —
    the Figure-1a failure class for static converters."""
    @function
    def step(x):
        y = ops.mul(x, 2.0)
        z = np.sort(y.numpy())[::-1].copy()     # arbitrary third-party code
        return ops.reduce_sum(ops.mul(y, z))

    for i in range(6):
        x = np.arange(4, dtype=np.float32) + i
        y = x * 2.0
        z = np.sort(y)[::-1]
        want = float((y * z).sum())
        assert float(step(x)) == pytest.approx(want), f"iter {i}"
    step.close()


def test_dynamic_python_loop_rolls():
    @function
    def step(x, n):
        y = x
        for _ in range(n):
            y = ops.add(y, y)
        return ops.reduce_sum(y)

    # varying trip counts: after rolling, any n co-executes
    for i, n in enumerate([3, 4, 3, 5, 8, 2, 6]):
        got = float(step(np.ones(2, np.float32), n))
        assert got == pytest.approx(2.0 * 2 ** n), f"iter {i} n={n}"
    assert step.phase == "co-execution"
    step.close()


def test_generator_program():
    """Python generators (Figure 1b) — unsupported by AutoGraph-style
    conversion, transparent to Terra."""
    def gen(x, k):
        for i in range(k):
            yield ops.mul(x, float(i + 1))

    @function
    def step(x):
        acc = ops.zeros_like(x)
        for t in gen(x, 3):
            acc = ops.add(acc, t)
        return ops.reduce_sum(acc)

    for i in range(5):
        x = np.full(3, i + 1.0, np.float32)
        assert float(step(x)) == pytest.approx(float((x * 6).sum()))
    assert step.phase == "co-execution"
    step.close()


def test_try_except_program():
    @function
    def step(x):
        try:
            y = ops.mul(x, 2.0)
            if float(ops.reduce_sum(y)) > 1e6:
                raise ValueError("overflow")
        except ValueError:
            y = ops.zeros_like(x)
        return ops.reduce_sum(y)

    for i in range(5):
        v = 1e6 if i == 3 else 1.0
        x = np.full(2, v, np.float32)
        want = 0.0 if i == 3 else 4.0
        assert float(step(x)) == pytest.approx(want)
    step.close()


def test_training_convergence_coexec():
    rng = np.random.RandomState(0)
    W = Variable(rng.randn(4, 1).astype(np.float32) * 0.1)
    target = np.array([[1.0], [-2.0], [0.5], [3.0]], np.float32)

    @function
    def train(x):
        with GradientTape() as tape:
            pred = ops.matmul(x, W.read())
            loss = ops.reduce_mean(ops.square(ops.sub(pred, ops.matmul(x, target))))
        g, = tape.gradient(loss, [W])
        W.assign_sub(ops.mul(g, 0.1))
        return loss

    losses = [float(train(rng.randn(16, 4).astype(np.float32)))
              for _ in range(30)]
    assert train.phase == "co-execution"
    assert losses[-1] < losses[0] * 0.1
    train.close()


def test_lazy_mode_matches():
    """Table-2 ablation plumbing: lazy (serialized) evaluation gives the
    same results as the overlapped co-execution."""
    w = Variable(np.full(3, 1.5, np.float32))

    @function(lazy=True)
    def step(x):
        y = ops.mul(w, x)
        return ops.reduce_sum(y)

    for i in range(5):
        x = np.full(3, i + 1.0, np.float32)
        assert float(step(x)) == pytest.approx(float((x * 1.5).sum()))
    assert step.phase == "co-execution"
    step.close()


def test_multiple_fetches_and_mid_iteration_print():
    @function
    def step(x):
        y = ops.mul(x, 2.0)
        s1 = ops.reduce_sum(y)
        _ = float(s1)                    # mid-iteration gating fetch
        z = ops.add(y, 1.0)
        return ops.reduce_sum(z)

    for i in range(5):
        x = np.full(4, i + 1.0, np.float32)
        assert float(step(x)) == pytest.approx(float((x * 2 + 1).sum()))
    assert step.phase == "co-execution"
    # the mid-iteration fetch produces a 2-segment graph, not replays
    assert step.stats["replays"] == 0
    step.close()


def test_rng_ops_are_iteration_stable():
    @function
    def step(x):
        noise = ops.random_normal((4,))
        return ops.reduce_sum(ops.add(x, noise))

    outs = [float(step(np.zeros(4, np.float32))) for _ in range(6)]
    assert step.phase == "co-execution"
    # different keys per iteration -> different values
    assert len({round(o, 6) for o in outs}) > 1
    step.close()
