"""Continuous-batching scheduler tests (serve/scheduler/, DESIGN.md §11):
slot alloc/free across retire-and-admit, mid-decode admission token
correctness, variable-length bucketed prefill, streaming callback
ordering, Terra-vs-baseline equality, and the lock-step run_batch
satellite fixes (ragged rejection, latency fields, live-row budget)."""

import numpy as np
import pytest

import jax

from repro.configs import smoke_config
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import (ContinuousBatchingScheduler, SlotPool,
                                   bucket_len)

MAX_LEN = 64


@pytest.fixture(scope="module")
def llama():
    cfg = smoke_config("llama3-8b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def make_requests(cfg, lens, max_news, seed=1, **kw):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, cfg.vocab, L).astype(np.int32),
                    max_new_tokens=mn, arrival_time=0.0, **kw)
            for L, mn in zip(lens, max_news)]


def lockstep_reference(cfg, params, lens, max_news, seed=1):
    """Per-request lock-step decode: the exact-token oracle."""
    eng = ServingEngine(cfg, params, max_len=MAX_LEN)
    reqs = make_requests(cfg, lens, max_news, seed)
    for r in reqs:
        eng.run_batch([r])
    eng.terra.close()
    return reqs


# ==========================================================================
# SlotPool unit behaviour
# ==========================================================================

def test_slot_pool_alloc_free_across_retire_and_admit():
    pool = SlotPool(3)
    a = pool.alloc("r0", 4)
    b = pool.alloc("r1", 5)
    c = pool.alloc("r2", 6)
    assert (a, b, c) == (0, 1, 2) and pool.free_count == 0
    with pytest.raises(RuntimeError):
        pool.alloc("r3", 1)
    pool.release(b)
    assert pool.free_count == 1 and pool.requests[1] is None
    # retire-and-admit reuses the freed slot, lowest-index-first
    assert pool.alloc("r3", 7) == 1
    with pytest.raises(RuntimeError):            # double free
        pool.release(0)
        pool.release(0)
    assert pool.active_mask().tolist() == [False, True, True]
    pool.advance_active()
    assert pool.pos.tolist() == [4, 8, 7]        # only active rows advance


def test_admission_anchors_on_earliest_arrival():
    """The admission bucket follows arrival order, not submission order —
    a later-submitted-but-earlier-arrived request must not be starved by
    a stream of other-bucket requests."""
    from repro.serve.scheduler import ArrivalQueue
    cfg = smoke_config("llama3-8b")
    q = ArrivalQueue(clock=lambda: 0.0)
    late = Request(prompt=np.zeros(16, np.int32), arrival_time=1.0)
    early = Request(prompt=np.zeros(8, np.int32), arrival_time=0.5)
    q.submit(late)
    q.submit(early)
    bucket, group = q.pop_admission(2.0, free_slots=1, cfg=cfg,
                                    max_len=64, batch_cap=1)
    assert bucket == 8 and group == [early]


def test_callback_queue_raise_preserves_remainder():
    """One raising callback loses only its own delivery; other queued
    callbacks survive the exception and deliver on the next flush."""
    from repro.serve.scheduler import CallbackQueue

    delivered = []

    def boom(req, tok, idx):
        raise RuntimeError("third-party failure")

    r1 = Request(prompt=np.zeros(1, np.int32), stream=boom,
                 out_tokens=[7])
    r2 = Request(prompt=np.zeros(1, np.int32),
                 stream=lambda req, tok, idx: delivered.append(tok),
                 out_tokens=[9])
    q = CallbackQueue()
    q.push(r1, 7)
    q.push(r2, 9)
    with pytest.raises(RuntimeError):
        q.flush()
    q.flush()
    assert delivered == [9] and q.delivered == 1


def test_bucket_len_policy():
    attn = smoke_config("llama3-8b")
    rec = smoke_config("mamba2-130m")
    assert bucket_len(attn, 5, 64) == 8          # pow2 cell (floor 8)
    assert bucket_len(attn, 13, 64) == 16
    assert bucket_len(attn, 60, 64) == 64        # capped at max_len
    assert bucket_len(rec, 13, 64) == 13         # recurrent: exact length


# ==========================================================================
# Scheduler end-to-end: token equality under churn
# ==========================================================================

def test_mid_decode_admission_and_varlen_bucketed_prefill(llama):
    """Six mixed-length requests through three slots: admissions land
    between decode steps of older requests, prompts bucket to 8/16 with
    right padding, and every request's tokens equal its solo lock-step
    decode — old and new requests alike."""
    cfg, params = llama
    lens = [5, 8, 13, 8, 5, 16]
    mns = [4, 9, 3, 5, 7, 4]
    ref = lockstep_reference(cfg, params, lens, mns)

    sch = ContinuousBatchingScheduler(cfg, params, max_slots=3,
                                      max_len=MAX_LEN)
    got = make_requests(cfg, lens, mns)
    sch.serve(got)
    for i, (a, b) in enumerate(zip(ref, got)):
        assert a.out_tokens == b.out_tokens, f"request {i}"
    st = sch.stats
    # slot churn is shape-stable: one family, no retraces, no divergence
    assert st["phase"] == "co-execution"
    assert st["retraces"] == 0 and st["replays"] == 0
    assert st["families"] == 1
    assert st["prefill_steps"] >= 2              # mid-decode admissions
    assert st["retired"] == len(lens)
    # latency fields recorded on every request
    for r in got:
        assert r.first_token_time is not None
        assert r.finish_time is not None
        assert r.arrival_time <= r.first_token_time <= r.finish_time
    sch.close()


def test_terra_vs_baseline_token_equality(llama):
    """use_terra=True and use_terra=False run the identical step math."""
    cfg, params = llama
    lens, mns = [8, 5, 13, 8], [6, 8, 4, 3]
    a = make_requests(cfg, lens, mns)
    b = make_requests(cfg, lens, mns)
    s1 = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                     max_len=MAX_LEN)
    s2 = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                     max_len=MAX_LEN, use_terra=False)
    s1.serve(a)
    s2.serve(b)
    assert [r.out_tokens for r in a] == [r.out_tokens for r in b]
    assert s1.stats["phase"] == "co-execution"
    s1.close()
    s2.close()


def test_eos_retirement_frees_slot_for_queued_request(llama):
    """EOS mid-stream retires the request immediately and the freed slot
    admits the next queued request (retire-and-admit through the device
    pool, not just the host free list)."""
    cfg, params = llama
    probe = lockstep_reference(cfg, params, [8], [8])[0]
    eos = probe.out_tokens[2]                    # will hit at index 2

    sch = ContinuousBatchingScheduler(cfg, params, max_slots=1,
                                      max_len=MAX_LEN)
    first = make_requests(cfg, [8], [8])[0]
    first.eos_id = eos
    second = make_requests(cfg, [8], [6], seed=3)[0]
    sch.serve([first, second])
    assert first.out_tokens == probe.out_tokens[:3]
    assert first.done
    ref2 = lockstep_reference(cfg, params, [8], [6], seed=3)[0]
    assert second.out_tokens == ref2.out_tokens
    assert sch.stats["retired"] == 2 and sch.stats["retraces"] == 0
    sch.close()


def test_recurrent_arch_exact_length_admission():
    """Recurrent stacks (no pad-safe cache) admit at exact prompt length
    and still match their lock-step decode."""
    cfg = smoke_config("mamba2-130m")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    lens, mns = [8, 8, 11], [5, 3, 6]
    ref = lockstep_reference(cfg, params, lens, mns, seed=2)
    sch = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                      max_len=MAX_LEN)
    got = make_requests(cfg, lens, mns, seed=2)
    sch.serve(got)
    assert [r.out_tokens for r in ref] == [r.out_tokens for r in got]
    assert sch.stats["families"] == 1
    sch.close()


def test_streaming_callback_ordering(llama):
    """Per-token streaming callbacks: every token delivered exactly once,
    per-request indices strictly sequential, token values matching the
    request's final out_tokens — even though delivery is deferred past
    the next step's dispatch (the overlap window)."""
    cfg, params = llama
    events = []

    def stream(req, tok, idx):
        events.append((id(req), tok, idx))

    sch = ContinuousBatchingScheduler(cfg, params, max_slots=2,
                                      max_len=MAX_LEN)
    reqs = make_requests(cfg, [8, 8, 5], [5, 3, 4], stream=stream)
    sch.serve(reqs)
    assert sch.stats["callbacks_delivered"] == \
        sum(len(r.out_tokens) for r in reqs)
    for r in reqs:
        mine = [(tok, idx) for rid, tok, idx in events if rid == id(r)]
        assert [idx for _, idx in mine] == list(range(len(r.out_tokens)))
        assert [tok for tok, _ in mine] == r.out_tokens
    sch.close()


def test_submit_validation(llama):
    cfg, params = llama
    sch = ContinuousBatchingScheduler(cfg, params, max_slots=1,
                                      max_len=32)
    with pytest.raises(ValueError):
        sch.submit(Request(prompt=np.arange(20, dtype=np.int32),
                           max_new_tokens=20))
    with pytest.raises(ValueError):
        sch.submit(Request(prompt=np.zeros(0, np.int32)))
    sch.close()


def test_unsupported_family_raises():
    cfg = smoke_config("whisper-small")          # encoder/cross family
    with pytest.raises(NotImplementedError):
        ContinuousBatchingScheduler(cfg, params=None, max_len=32)


# ==========================================================================
# Lock-step run_batch satellites
# ==========================================================================

def test_run_batch_rejects_ragged_prompts(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, use_terra=False)
    reqs = make_requests(cfg, [8, 5], [4, 4])
    with pytest.raises(ValueError, match="same-length"):
        eng.run_batch(reqs)


def test_run_batch_budget_tracks_live_rows_and_records_latency(llama):
    """A short request retiring early must not stretch the decode loop
    past the longest *live* request, pad rows never extend it, and the
    latency fields come back filled."""
    cfg, params = llama
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, use_terra=False,
                        bucket_batches=True)
    reqs = make_requests(cfg, [8, 8, 8], [2, 6, 6])   # pads batch to 4
    eng.run_batch(reqs)
    assert [len(r.out_tokens) for r in reqs] == [2, 6, 6]
    # prefill (1 token) + 5 decode steps serve the longest request; the
    # retired row and the pad row add nothing
    assert eng.stats["decode_steps"] == 5
    assert eng.stats["prefill_tokens"] == 24          # real rows only
    for r in reqs:
        assert r.arrival_time <= r.first_token_time <= r.finish_time
    # finish stamped at the retiring step, not at batch drain: the
    # early-EOS row's latency excludes the steps it merely rode along
    assert reqs[0].finish_time < reqs[1].finish_time


def test_run_batch_streaming_callbacks(llama):
    cfg, params = llama
    got = []
    eng = ServingEngine(cfg, params, max_len=MAX_LEN, use_terra=False)
    reqs = make_requests(cfg, [8, 8], [3, 4],
                         stream=lambda r, t, i: got.append((id(r), t, i)))
    eng.run_batch(reqs)
    for r in reqs:
        mine = [(t, i) for rid, t, i in got if rid == id(r)]
        assert mine == list(zip(r.out_tokens, range(len(r.out_tokens))))
