"""Per-architecture smoke tests: reduced same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs.  The FULL
configs are exercised only via the dry-run (launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_config
from repro.models import model as M

pytestmark = pytest.mark.slow       # multi-minute suite; see pytest.ini

ARCH_IDS = sorted(ARCHS.keys())


def _inputs(cfg, B=2, S=32):
    rng = np.random.RandomState(0)
    tokens = rng.randint(0, cfg.vocab, (B, S)).astype(np.int32)
    kw = {}
    if cfg.family == "vlm":
        kw["cross_states"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        kw["frontend_embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_tokens, cfg.d_model), jnp.float32)
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg)
    logits = M.forward(cfg, params, tokens, **kw)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), \
        f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step_no_nans(arch):
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg)
    labels = np.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits = M.forward(cfg, p, tokens, **kw).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits)
        ll = jnp.take_along_axis(lp, labels[..., None], axis=-1)
        return -ll.mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss)), f"{arch}: NaN loss"
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)), f"{arch}: NaN grads"
    assert float(gnorm) > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """Prefill+decode must agree with the full forward pass on the next-token
    logits (KV-cache correctness)."""
    cfg = smoke_config(arch)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg, B=2, S=16)

    full = M.forward(cfg, params, tokens, **kw)
    # serve path: prefill on the first 15, then decode token 15
    pre_logits, cache = M.prefill(cfg, params, tokens[:, :15], max_len=32,
                                  **kw)
    dec_kw = {k: v for k, v in kw.items() if k != "frontend_embeds"}
    if cfg.family == "audio":
        dec_kw["cross_states"] = None  # recomputed below
        from repro.models import transformer as T
        dec_kw["cross_states"] = T.encode(cfg, params, kw["frontend_embeds"])
    dec_logits, cache = M.decode_step(cfg, params, cache,
                                      tokens[:, 15:16], **dec_kw)

    want = full[:, 15].astype(jnp.float32)
    got = dec_logits.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.15, atol=0.15)
    # ranking agreement on the argmax
    assert bool((jnp.argmax(got, -1) == jnp.argmax(want, -1)).mean() >= 0.5)
