"""Multi-device tests (pipeline parallelism, compressed DP all-reduce,
sharded train step) — run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device jax state."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow       # multi-minute suite; see pytest.ini

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str) -> dict:
    prog = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "(os.environ.get('XLA_FLAGS','') + "
            "' --xla_force_host_platform_device_count=8')\n"
            + textwrap.dedent(code))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_pipeline_matches_sequential():
    res = run_sub("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.parallel.pipeline import make_pipelined_apply

    mesh = jax.make_mesh((8,), ("stage",))
    S, M, mb, d = 8, 16, 4, 32
    rng = np.random.RandomState(0)
    ws = jnp.asarray(rng.randn(S, d, d).astype(np.float32) * 0.2)
    xs = jnp.asarray(rng.randn(M, mb, d).astype(np.float32))

    pipe = make_pipelined_apply(mesh, "stage",
                                lambda p, x: jnp.tanh(x @ p["w"]))
    with mesh:
        got = pipe({"w": ws}, xs)

    # sequential reference
    ref = xs
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s])
    err = float(jnp.abs(got - ref).max())
    print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-5


def test_shardmap_ep_moe_matches_pjit_path():
    """The explicit all_to_all expert-parallel MoE (models/moe_ep.py) must
    agree exactly with the pjit capacity-scatter path."""
    res = run_sub("""
    import json, dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.parallel.sharding import ShardingPolicy, use_policy

    cfg = smoke_config("deepseek-moe-16b")
    cfg = dataclasses.replace(cfg, n_experts=8, top_k=2,
                              capacity_factor=8.0)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    tokens = rng.randint(0, cfg.vocab, (4, 32)).astype(np.int32)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh, use_policy(ShardingPolicy(mesh)):
        ref = M.forward(cfg, params, tokens)
        cfg2 = dataclasses.replace(cfg, moe_impl="shard_map")
        got = jax.jit(lambda p, t: M.forward(cfg2, p, t))(params, tokens)
    err = float(jnp.abs(got.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    print(json.dumps({"err": err}))
    """)
    assert res["err"] == 0.0


def test_compressed_dp_allreduce_matches_mean():
    res = run_sub("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.parallel.compression import dp_allreduce, zero_residuals

    mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.RandomState(1)
    g = jnp.asarray(rng.randn(8, 64).astype(np.float32))
    grads = {"w": g}
    red = dp_allreduce(mesh, "data", compression="bf16")
    with mesh:
        out, resid = red(grads, zero_residuals(grads))
    want = jnp.broadcast_to(g.mean(0, keepdims=True), g.shape)
    err = float(jnp.abs(out["w"] - want).max() / jnp.abs(want).max())
    print(json.dumps({"err": err}))
    """)
    assert res["err"] < 1e-2        # bf16 quantization noise only


def test_sharded_train_step_runs_on_8_devices():
    res = run_sub("""
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.parallel import specs as S
    from repro.parallel.sharding import ShardingPolicy, use_policy
    from repro.train import optimizer as opt
    from repro.train.train_step import build_train_step

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    ost = opt.init(params)
    pspecs = S.tree_param_specs(mesh, params)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    osh = {"step": NamedSharding(mesh, P()),
           "m": psh, "v": psh, "master": psh}
    params = jax.device_put(params, psh)
    ost = jax.device_put(ost, osh)
    step = build_train_step(cfg, opt.OptConfig(lr=1e-3, warmup_steps=2,
                                               total_steps=10),
                            microbatches=2)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab, (8, 32)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab, (8, 32)),
                                   jnp.int32)}
    with mesh, use_policy(ShardingPolicy(mesh)):
        jstep = jax.jit(step)
        losses = []
        for i in range(4):
            params, ost, m = jstep(params, ost, batch)
            losses.append(float(m["loss"]))
    print(json.dumps({"losses": losses}))
    """)
    import numpy as np
    losses = res["losses"]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]
