"""Observability-layer tests (repro.obs, DESIGN.md §15): timeline trace
validity (JSON, per-track monotone timestamps, request flow completeness)
for a mid-decode-admission serving run under sampled device profiling,
streaming-histogram accuracy against exact rank statistics, Prometheus
exposition wellformedness, profiling-mode token equality + steady entry,
fork-observation distributions, and the perf-regression guard's
injected-regression failure mode."""

import json
import math
import os
import re
import sys

import numpy as np
import pytest

import jax

from repro.configs import smoke_config
from repro.core import function, ops
from repro.core.events import types as T
from repro.core.events.processors import ListProcessor
from repro.models import model as M
from repro.obs import (GROWTH, Histogram, MetricsProcessor, MetricsRegistry,
                       TraceViewerExporter, chrome_trace, counters_table)
from repro.serve.engine import Request, ServingEngine
from repro.serve.scheduler import ContinuousBatchingScheduler, SlotPool

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MAX_LEN = 64


@pytest.fixture(scope="module")
def llama():
    cfg = smoke_config("llama3-8b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def make_requests(cfg, lens, max_news, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(prompt=rng.randint(0, cfg.vocab, L).astype(np.int32),
                    max_new_tokens=mn, arrival_time=0.0)
            for L, mn in zip(lens, max_news)]


@pytest.fixture(scope="module")
def served(llama):
    """One mid-decode-admission serving run with sampled profiling,
    metrics, and the trace buffer attached — shared by the timeline,
    metrics, and equality tests below."""
    cfg, params = llama
    lens = [5, 8, 13, 8, 5, 16]
    mns = [4, 9, 3, 5, 7, 4]
    eng = ServingEngine(cfg, params, max_len=MAX_LEN)
    ref = make_requests(cfg, lens, mns)
    for r in ref:
        eng.run_batch([r])
    eng.terra.close()

    sch = ContinuousBatchingScheduler(cfg, params, max_slots=3,
                                      max_len=MAX_LEN, steady_state=4,
                                      profile=3)
    registry = sch.enable_metrics()
    lp = ListProcessor()
    sch.events.attach(lp)
    got = make_requests(cfg, lens, mns)
    sch.serve(got)
    stats = sch.stats
    sch.close()
    return dict(ref=ref, got=got, events=lp.events, registry=registry,
                stats=stats)


# ==========================================================================
# sampled profiling: correctness must be untouched
# ==========================================================================

def test_profiling_preserves_token_equality_and_steady_entry(served):
    """profile=3 blocks on device outputs on the GraphRunner thread only:
    every request still matches its solo lock-step decode, and the engine
    still reaches zero-walker steady state."""
    for i, (a, b) in enumerate(zip(served["ref"], served["got"])):
        assert a.out_tokens == b.out_tokens, f"request {i}"
    st = served["stats"]
    assert st["phase"] == "co-execution"
    assert st["retraces"] == 0 and st["replays"] == 0
    assert st["steady_iters"] > 0                  # steady entry happened
    profs = [e for e in served["events"] if isinstance(e, T.SegmentProfile)]
    assert profs, "profile=3 emitted no SegmentProfile events"
    for e in profs:
        assert e.kind in ("segment", "chain", "steady")
        assert 0.0 < e.dispatch <= e.device        # host slice of the wall
    assert any(e.kind == "steady" for e in profs)  # sampling survives steady


def test_dense_pool_counts_resident_tokens(served):
    """The dense layout reserves a full max_len row per active slot, so
    resident/peak accounting must be non-zero (satellite: the serving
    bench reported peak_resident_tokens: 0 on dense)."""
    st = served["stats"]
    assert st["peak_resident_tokens"] == 3 * MAX_LEN
    pool = SlotPool(2, row_tokens=16)
    pool.alloc("r0", 5)
    assert pool.resident_tokens == 16
    pool.alloc("r1", 7)
    assert (pool.resident_tokens, pool.peak_resident_tokens) == (32, 32)
    pool.release(0)
    assert (pool.resident_tokens, pool.peak_resident_tokens) == (16, 32)


# ==========================================================================
# timeline export
# ==========================================================================

def test_trace_json_valid_and_tracks_monotone(served, tmp_path):
    trace = chrome_trace(served["events"])
    # must round-trip as strict JSON
    blob = json.dumps(trace)
    doc = json.loads(blob)
    evs = doc["traceEvents"]
    assert len(evs) > 50
    by_track = {}
    for e in evs:
        assert {"ph", "pid", "tid"} <= set(e)
        if e["ph"] == "M":
            continue
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
        by_track.setdefault((e["pid"], e["tid"]), []).append(e["ts"])
    for track, tss in by_track.items():
        assert tss == sorted(tss), f"track {track} timestamps not monotone"
    # the exporter writes the same document
    exp = TraceViewerExporter(str(tmp_path / "t.trace.json"))
    for e in served["events"]:
        exp.process(e)
    exp.close()
    with open(exp.path) as f:
        assert json.load(f)["traceEvents"] == evs


def test_trace_request_flows_complete(served):
    """Every retired request's lifecycle flow has a start (submit), at
    least one step (admit/prefill/token), and a finish (retire) — no
    dangling arrows even with mid-decode admissions."""
    evs = chrome_trace(served["events"])["traceEvents"]
    flows = {}
    for e in evs:
        if e.get("cat") == "flow" and str(e["id"]).startswith("req:"):
            flows.setdefault(e["id"], []).append(e["ph"])
    retired = {f"req:{e.rid}" for e in served["events"]
               if isinstance(e, T.RequestRetire)}
    assert retired and set(flows) == retired
    for fid, phs in flows.items():
        assert phs[0] == "s" and phs[-1] == "f", fid
        assert phs.count("s") == 1 and phs.count("f") == 1, fid
        assert "t" in phs, fid
    # finish arrows bind to the enclosing request span
    assert all(e.get("bp") == "e" for e in evs
               if e.get("cat") == "flow" and e["ph"] == "f")


# ==========================================================================
# streaming histograms + registry
# ==========================================================================

def test_histogram_matches_exact_rank_statistics():
    rng = np.random.RandomState(7)
    samples = rng.lognormal(mean=1.0, sigma=1.2, size=5000)
    h = Histogram()
    for v in samples:
        h.observe(float(v))
    srt = np.sort(samples)
    tol = math.sqrt(GROWTH) - 1.0 + 1e-9           # bucket guarantee
    for q in (50.0, 90.0, 95.0, 99.0):
        exact = srt[max(1, math.ceil(q / 100.0 * len(srt))) - 1]
        got = h.percentile(q)
        assert abs(got - exact) / exact <= tol, (q, got, exact)
    assert h.mean == pytest.approx(samples.mean())  # mean is exact
    assert h.count == 5000
    assert h.percentile(0.0) == pytest.approx(srt[0], rel=tol)
    assert h.percentile(100.0) == pytest.approx(srt[-1], rel=tol)


def test_histogram_zeros_and_empty():
    h = Histogram()
    assert h.percentile(50.0) == 0.0 and h.mean == 0.0
    for v in (0.0, -1.0, 2.0, 4.0):
        h.observe(v)
    assert h.percentile(25.0) == 0.0               # underflow bucket
    assert h.count == 4 and h.mean == pytest.approx(1.25)


_PROM_LINE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_]+=\"[^\"]*\"(,[a-zA-Z_]+="
    r"\"[^\"]*\")*\})? [-+0-9.eEnaif]+$")


def test_prometheus_exposition_parses(served):
    reg = served["registry"]
    assert reg.histograms["ttft_ms"].count == len(served["got"])
    text = reg.prometheus_text()
    names = set()
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("#"):
            assert line.startswith(("# HELP", "# TYPE"))
            continue
        assert _PROM_LINE.match(line), line
        names.add(line.split("{")[0].split(" ")[0])
    assert "terra_ttft_ms_count" in names
    assert "terra_ttft_ms_bucket" in names
    # cumulative buckets are monotone and +Inf equals the count
    for name, h in reg.histograms.items():
        if not h.count:
            continue
        pat = re.compile(rf'^terra_{name}_bucket{{le="([^"]+)"}} (\d+)$',
                         re.M)
        counts = [int(m.group(2)) for m in pat.finditer(text)]
        assert counts == sorted(counts)
        assert counts[-1] == h.count               # le="+Inf"


def test_metrics_processor_replay_and_counters_table(served):
    """Replaying the captured event list through a fresh processor gives
    the same histogram counts as the live run — the report CLI relies on
    this — and counters_table renders numeric entries only."""
    mp = MetricsProcessor()
    for e in served["events"]:
        mp.process(e)
    live = served["registry"]
    for name in ("ttft_ms", "token_latency_ms", "dispatch_us",
                 "segment_device_us"):
        assert mp.registry.histograms[name].count == \
            live.histograms[name].count, name
    table = counters_table({"b_num": 3, "a_str": "x", "c_f": 1.5})
    assert "b_num" in table and "c_f" in table and "a_str" not in table


def test_metrics_registry_standalone():
    reg = MetricsRegistry()
    reg.observe("lat_ms", 3.0)
    reg.observe("lat_ms", 9.0)
    reg.set_gauge("depth", 4)
    reg.attach_counters({"steps": 12})
    snap = reg.snapshot()
    assert snap["histograms"]["lat_ms"]["count"] == 2
    assert snap["gauges"]["depth"] == 4
    assert snap["counters"]["steps"] == 12


# ==========================================================================
# fork observation (satellite: selector distributions)
# ==========================================================================

def test_fork_observation_distribution():
    @function
    def step(x):
        y = ops.mul(x, 2.0)
        if float(ops.reduce_sum(y)) > 10.0:        # gating fetch -> fork
            y = ops.mul(y, 10.0)
        else:
            y = ops.add(y, 1.0)
        return ops.reduce_sum(y)

    lp = ListProcessor()
    step.engine.events.attach(lp)
    vals = (0.5, 0.5, 3.0, 0.5, 3.0, 4.0, 0.1, 5.0)
    for v in vals:
        float(step(np.full(4, v, np.float32)))
    fam = step.engine.family
    step.close()
    obs = lp.of_type(T.ForkObserved)
    assert obs, "no ForkObserved events for a branchy program"
    assert len({e.case for e in obs}) == 2         # both arms observed
    assert len({e.family for e in obs}) == 1
    # the family accumulated the same distribution
    assert len(fam.sel_dist) >= 1
    dist = next(iter(fam.sel_dist.values()))
    assert sorted(dist) == [0, 1]
    assert sum(dist.values()) == len(obs)


# ==========================================================================
# regression guard
# ==========================================================================

def _load_serving_baseline():
    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")
    with open(path) as f:
        return json.load(f)


def test_check_regression_passes_on_baseline_and_fails_on_injection():
    from benchmarks.check_regression import SPECS, compare
    base = _load_serving_baseline()
    specs = SPECS["BENCH_serving.json"]
    assert compare(json.loads(json.dumps(base)), base, specs) == []
    bad = json.loads(json.dumps(base))
    bad["gates"]["token_equality"] = False          # gate flip
    bad["gates"]["tracing_ratio"] = 0.5             # profiling cost blowup
    bad["gates"]["retraces_post_warmup"] = 7        # counter regression
    del bad["gates"]["speedup_vs_lockstep"]         # schema regression
    fails = compare(bad, base, specs)
    assert len(fails) == 4
    assert any("token_equality" in m for m in fails)
    assert any("tracing_ratio" in m for m in fails)
    assert any("retraces_post_warmup" in m for m in fails)
    assert any("missing from fresh" in m for m in fails)


def test_check_regression_cli(tmp_path):
    from benchmarks.check_regression import main
    base = _load_serving_baseline()
    (tmp_path / "base").mkdir()
    (tmp_path / "fresh").mkdir()
    for d in ("base", "fresh"):
        with open(tmp_path / d / "BENCH_serving.json", "w") as f:
            json.dump(base, f)
    ok = main(["--base", str(tmp_path / "base"),
               "--fresh", str(tmp_path / "fresh"), "BENCH_serving.json"])
    assert ok == 0
    base["gates"]["terra_vs_noterra"] = 0.01
    with open(tmp_path / "fresh" / "BENCH_serving.json", "w") as f:
        json.dump(base, f)
    bad = main(["--base", str(tmp_path / "base"),
                "--fresh", str(tmp_path / "fresh"), "BENCH_serving.json"])
    assert bad == 1
