"""Correctness tests for the symbolic optimization pass pipeline
(core/passes/, DESIGN.md §10): legality rules per pass, the
divergence-not-crash contract of constant-feed folding, coalescing under
donation, and kernel-substitution numerics."""

import numpy as np
import pytest

from repro.core import Variable, function, ops

ALL = "all"
NONE = "none"


def _run(step, xs):
    return [float(np.asarray(step(x))) for x in xs]


def _xs(n, shape=(4,), seed=0):
    r = np.random.RandomState(seed)
    return [r.randn(*shape).astype(np.float32) for _ in range(n)]


# ==========================================================================
# DCE
# ==========================================================================

def test_dce_eliminates_dead_ops_and_preserves_values():
    def body(x):
        dead = ops.reduce_mean(ops.mul(x, 5.0))     # result discarded
        dead2 = ops.add(dead, 1.0)                  # dead consumer chain
        y = ops.mul(x, 2.0)
        return float(ops.reduce_sum(y))

    opt, ref = function(body, optimize=ALL), function(body, optimize=NONE)
    xs = _xs(6)
    assert _run(opt, xs) == pytest.approx(_run(ref, xs))
    assert opt.phase == "co-execution"
    assert opt.stats["nodes_eliminated"] >= 2
    assert ref.stats["nodes_eliminated"] == 0
    opt.close(); ref.close()


def test_dce_never_removes_variable_writes_or_fetched_values():
    w = Variable(np.ones(4, np.float32), "dce_w")

    @function(optimize=ALL)
    def step(x):
        w.assign(ops.mul(x, 3.0))          # write IS the only consumer
        m = ops.reduce_max(x)              # fetched below
        return float(m)

    xs = _xs(6, seed=1)
    for x in xs:
        got = step(x)
        assert got == pytest.approx(float(x.max()))
        step.wait()
        np.testing.assert_allclose(
            np.asarray(step.engine.variable_value(w)), x * 3.0, rtol=1e-6)
    assert step.phase == "co-execution"
    # nothing in this program is dead: both ops have observable effects
    assert step.stats["nodes_eliminated"] == 0
    step.close()


# ==========================================================================
# CSE
# ==========================================================================

def test_cse_merges_var_read_duplicates():
    w = Variable(np.full(4, 3.0, np.float32), "cse_w")

    def body(x):
        a = ops.mul(w.read(), 2.0)
        b = ops.mul(w.read(), 2.0)          # same expr, different line
        c = ops.add(a, 1.0)
        d = ops.add(b, 1.0)                 # second-level duplicate
        return float(ops.reduce_sum(ops.add(ops.mul(c, x), d)))

    opt, ref = function(body, optimize=ALL), function(body, optimize=NONE)
    xs = _xs(6, seed=2)
    assert _run(opt, xs) == pytest.approx(_run(ref, xs))
    assert opt.stats["cse_hits"] >= 2
    assert opt.stats["replays"] == 0
    opt.close(); ref.close()


def test_cse_never_merges_feed_slots():
    """Two ops consuming avals-identical feeds are NOT a common
    subexpression: the fed values are independent (per-iteration RNG keys
    are the canonical case)."""
    @function(optimize=ALL)
    def step(x):
        a = ops.random_normal((4,))          # distinct key feeds
        b = ops.random_normal((4,))
        return float(ops.reduce_sum(ops.sub(a, b)))

    outs = [step(x) for x in _xs(8, seed=3)]
    assert step.phase == "co-execution"
    assert step.stats["cse_hits"] == 0
    # if the two draws were merged the difference would be exactly zero
    assert any(abs(o) > 1e-6 for o in outs)
    step.close()


def test_cse_across_switch_branches_hoists():
    """The same pure subexpression inside both branches of a switch is
    hoisted before the fork and computed once — correct on both paths.
    Hoisting requires sources that strictly dominate the fork (variable
    reads qualify: a VarRef read always means the iteration-start value);
    a duplicate consuming the fork node's own output stays put."""
    w = Variable(np.full(4, 2.0, np.float32), "hoist_w")

    class Cfg:
        flag = False
    cfg = Cfg()

    def body(x):
        base = float(np.asarray(ops.reduce_sum(x)))   # pre-fork anchor
        if cfg.flag:                        # Python control flow -> switch
            y = ops.add(ops.mul(w.read(), 2.0), 1.0)
        else:
            y = ops.sub(ops.mul(w.read(), 2.0), 1.0)
        return float(ops.reduce_sum(ops.add(y, x))) + 0.0 * base

    opt, ref = function(body, optimize=ALL), function(body, optimize=NONE)
    xs = _xs(10, seed=4)
    outs_o, outs_r = [], []
    for i, x in enumerate(xs):
        cfg.flag = i % 2 == 1               # alternate: both branches trace
        outs_o.append(float(np.asarray(opt(x))))
        outs_r.append(float(np.asarray(ref(x))))
    assert outs_o == pytest.approx(outs_r)
    assert opt.phase == "co-execution"
    assert opt.stats["cse_hits"] >= 2       # mul(base,2.0) in both branches
    opt.close(); ref.close()


# ==========================================================================
# Constant-feed folding
# ==========================================================================

def test_feed_folding_diverges_not_crashes_on_value_change():
    m = [np.full(4, 2.0, np.float32)]

    @function(optimize=ALL)
    def step(x):
        return float(ops.reduce_sum(ops.add(x, m[0])))

    for i in range(4):                       # m stable across the streak
        step(np.full(4, float(i), np.float32))
    assert step.stats["feeds_folded"] >= 1
    assert step.phase == "co-execution"

    m[0] = np.full(4, 9.0, np.float32)       # folded value changes
    got = step(np.full(4, 1.0, np.float32))
    assert got == pytest.approx(4 * (1.0 + 9.0))     # correct, not stale
    assert step.stats["fold_divergences"] == 1

    # the slot is now varying: it unfolds, and further changes are plain
    # feed updates with no divergence
    for i in range(3):
        step(np.full(4, float(i), np.float32))
    assert step.phase == "co-execution"
    m[0] = np.full(4, 17.0, np.float32)
    got = step(np.full(4, 1.0, np.float32))
    assert got == pytest.approx(4 * (1.0 + 17.0))
    assert step.stats["fold_divergences"] == 1       # no second divergence
    step.close()


def test_feed_folding_disabled_under_safe_pipeline():
    m = np.full(4, 2.0, np.float32)

    @function(optimize="safe")
    def step(x):
        return float(ops.reduce_sum(ops.add(x, m)))

    for i in range(4):
        step(np.full(4, float(i), np.float32))
    assert step.phase == "co-execution"
    assert step.stats["feeds_folded"] == 0
    step.close()


# ==========================================================================
# Segment coalescing
# ==========================================================================

def test_coalescing_reduces_dispatches_for_late_reads():
    def body(x):
        a = ops.mul(x, 2.0)
        sa = ops.reduce_sum(a)
        b = ops.mul(a, 3.0)
        sb = ops.reduce_sum(b)
        return float(sa) + float(sb)         # both read late

    opt, ref = function(body, optimize=ALL), function(body, optimize=NONE)
    xs = _xs(8, seed=5)
    assert _run(opt, xs) == pytest.approx(_run(ref, xs))
    opt.wait(); ref.wait()
    assert opt.stats["segments_coalesced"] >= 1
    assert opt.stats["replays"] == 0
    assert opt.stats["segments_dispatched"] < ref.stats["segments_dispatched"]
    opt.close(); ref.close()


def test_coalescing_keeps_consumed_boundaries():
    """A gating fetch whose value steers Python control flow is read
    early every trace — its boundary must survive."""
    w = Variable(np.ones(4, np.float32), "co_w")

    @function(optimize=ALL)
    def step(x):
        s = float(ops.reduce_sum(ops.mul(x, 2.0)))
        if s > 0:                            # consumed by the continuation
            w.assign(ops.mul(x, 2.0))
        else:
            w.assign(ops.mul(x, -2.0))
        return s

    for i in range(8):
        sign = 1.0 if i % 2 else -1.0
        x = np.full(4, sign * (i + 1.0), np.float32)
        got = step(x)
        step.wait()
        np.testing.assert_allclose(np.asarray(step.engine.variable_value(w)),
                                   np.abs(x) * 2.0, rtol=1e-6)
    assert step.phase == "co-execution"
    assert step.stats["segments_coalesced"] == 0
    step.close()


def test_coalescing_preserves_mid_iteration_reads_under_donation():
    """Donation analysis runs post-coalescing; a mid-iteration
    variable_value read still sees the correct intermediate and the
    committed value survives."""
    w = Variable(np.full(256, 2.0, np.float32), "don_w")
    seen = []

    @function(optimize=ALL)
    def step(x):
        w.assign(ops.mul(w.read(), 2.0))
        s = ops.reduce_sum(w.read())
        w.assign(ops.mul(x, 3.0))
        t = ops.reduce_sum(w.read())
        seen.append(float(s))                # late reads -> coalescible
        return float(t)

    eng = step.engine
    for i in range(6):
        x = np.full(256, float(i + 1), np.float32)
        got = step(x)
        assert got == pytest.approx(3.0 * (i + 1) * 256)
        # mid-stream driver read of the committed value (under donation)
        np.testing.assert_allclose(np.asarray(eng.variable_value(w)),
                                   np.full(256, 3.0 * (i + 1)))
        want_s = (2.0 if i == 0 else 3.0 * i) * 2 * 256
        assert seen[-1] == pytest.approx(want_s), f"iter {i}"
    assert step.phase == "co-execution"
    step.close()


# ==========================================================================
# Kernel substitution
# ==========================================================================

KERNEL_PIPE = ("fold", "cse", "kernels", "dce", "coalesce")


def test_kernel_substitution_rmsnorm_numerics():
    g = Variable(np.linspace(0.5, 1.5, 16).astype(np.float32), "krms_g")

    def body(x):
        return float(ops.reduce_sum(ops.rms_norm(x, g.read(), eps=1e-6)))

    opt = function(body, optimize=KERNEL_PIPE)
    ref = function(body, optimize=NONE)
    xs = _xs(5, shape=(4, 16), seed=6)
    np.testing.assert_allclose(_run(opt, xs), _run(ref, xs),
                               rtol=1e-4, atol=1e-5)
    assert opt.stats["kernels_substituted"] == 1
    assert opt.stats["replays"] == 0
    opt.close(); ref.close()


def test_kernel_substitution_attention_numerics():
    D, S = 16, 8
    mask = np.tril(np.ones((S, S), np.float32))

    def body(q, k, v):
        s = ops.einsum(q, k, expr="bsd,btd->bst")
        s = ops.add(ops.mul(s, 1.0 / D ** 0.5),
                    ops.mul(ops.sub(mask, 1.0), 1e9))
        o = ops.einsum(ops.softmax(s, axis=-1), v, expr="bst,btd->bsd")
        return ops.reduce_sum(o)

    opt = function(body, optimize=KERNEL_PIPE)
    ref = function(body, optimize=NONE)
    r = np.random.RandomState(7)
    a, b = [], []
    for _ in range(5):
        q, k, v = (r.randn(2, S, D).astype(np.float32) for _ in range(3))
        a.append(float(np.asarray(opt(q, k, v).numpy())))
        b.append(float(np.asarray(ref(q, k, v).numpy())))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
    assert opt.stats["kernels_substituted"] == 1
    assert opt.stats["feeds_folded"] >= 1        # the causal mask folded
    assert opt.stats["nodes_eliminated"] >= 4    # unfused chain died
    opt.close(); ref.close()


def test_kernel_substitution_skips_differentiated_graphs():
    """Tape consumers keep the unfused chain alive: substitution must not
    fire when attention intermediates feed .vjp ops."""
    from repro.core import GradientTape
    D, S = 8, 4
    mask = np.tril(np.ones((S, S), np.float32))
    wv = Variable(np.eye(D).astype(np.float32), "ks_wv")

    @function(optimize=KERNEL_PIPE)
    def step(q, k, x):
        with GradientTape() as tape:
            v = ops.matmul(x, wv.read())
            s = ops.einsum(q, k, expr="bsd,btd->bst")
            s = ops.add(ops.mul(s, 1.0 / D ** 0.5),
                        ops.mul(ops.sub(mask, 1.0), 1e9))
            o = ops.einsum(ops.softmax(s, axis=-1), v, expr="bst,btd->bsd")
            loss = ops.reduce_sum(o)
        (gv,) = tape.gradient(loss, [wv])
        wv.assign_sub(ops.mul(gv, 0.01))
        return float(loss)

    r = np.random.RandomState(8)
    for _ in range(4):
        q, k, x = (r.randn(2, S, D).astype(np.float32) for _ in range(3))
        step(q, k, x)
    assert step.phase == "co-execution"
    assert step.stats["kernels_substituted"] == 0
    step.close()


# ==========================================================================
# Pipeline plumbing
# ==========================================================================

def test_optimize_none_is_inert():
    def body(x):
        dead = ops.mul(x, 5.0)
        a = ops.mul(x, 2.0)
        b = ops.mul(x, 2.0)
        return float(ops.reduce_sum(ops.add(a, b)))

    step = function(body, optimize=NONE)
    for x in _xs(5, seed=9):
        step(x)
    assert step.phase == "co-execution"
    for k in ("nodes_eliminated", "cse_hits", "feeds_folded",
              "segments_coalesced", "kernels_substituted"):
        assert step.stats[k] == 0, k
    assert step.engine.gp.opt is None
    assert step.engine.gp.otg is step.engine.gp.tg
    step.close()


def test_resolve_pipeline_validation():
    from repro.core.passes import resolve_pipeline
    assert resolve_pipeline("none") == ()
    assert resolve_pipeline("safe") == ("cse", "dce", "coalesce")
    assert "fold" in resolve_pipeline("all", backend="cpu")
    assert "kernels" not in resolve_pipeline("all", backend="cpu")
    assert "kernels" in resolve_pipeline("all", backend="tpu")
    assert resolve_pipeline(("dce", "cse")) == ("cse", "dce")
    with pytest.raises(ValueError):
        resolve_pipeline("everything")
    with pytest.raises(ValueError):
        resolve_pipeline(("dce", "nope"))


def test_passes_rerun_after_divergence_retrace():
    """A divergence that grows the graph regenerates the program and
    re-runs the pipeline over the new graph (per-family cache keyed on
    version + observation state)."""
    class Cfg:
        k = 1.0
    cfg = Cfg()

    @function(optimize=ALL)
    def step(x):
        dead = ops.reduce_mean(ops.mul(x, 5.0))
        y = ops.mul(ops.mul(x, 2.0), cfg.k)
        return float(ops.reduce_sum(y))

    xs = _xs(4, seed=10)
    for x in xs:
        step(x)
    base = step.stats["nodes_eliminated"]
    assert base >= 1
    cfg.k = 2.0                       # divergence -> retrace -> regen
    for x in xs:
        got = step(x)
        assert got == pytest.approx(float((x * 2.0 * 2.0).sum()), rel=1e-5)
    assert step.phase == "co-execution"
    assert step.stats["nodes_eliminated"] > base    # pipeline ran again
    step.close()
