"""Persistent artifact store + checkpoint/restore tests (core/persist/,
serve/scheduler/checkpoint.py, DESIGN.md §14): codec strictness, atomic
store semantics, cross-process warm boot (zero retraces / zero segment
recompiles), corruption and version-skew degrading to a clean cold start,
eviction-then-reactivation hydrating from disk, engine checkpoint
continuation, and mid-decode scheduler checkpoint exact-token equality
across a process boundary."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import Variable, function, ops
from repro.core.persist import codec
from repro.core.persist.store import ArtifactStore
from repro.core.trace import Aval, FeedRef, Ref, VarRef

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, cache_dir: str, **extra_env) -> dict:
    prog = textwrap.dedent(code)
    env = {**os.environ, "TERRA_CACHE_DIR": cache_dir,
           "PYTHONPATH": os.path.join(ROOT, "src")}
    env.update({k: str(v) for k, v in extra_env.items()})
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# ==========================================================================
# codec + store units
# ==========================================================================

def test_codec_roundtrip():
    vals = [None, True, 3, -1.5, "s", (1, (2, "x")), [1, [2]],
            {"a": 1, (1, 2): (3,)}, {3, 1, 2}, Aval((2, 3), "float32"),
            Ref(4, 1), FeedRef(2, 0), VarRef(7), slice(1, None, 2),
            Ellipsis, np.dtype("int32"), np.float32(2.5),
            np.arange(6, dtype=np.int64).reshape(2, 3)]
    for v in vals:
        enc = json.loads(json.dumps(codec.encode(v)))   # JSON-native
        dec = codec.decode(enc)
        if isinstance(v, np.ndarray):
            assert np.array_equal(dec, v) and dec.dtype == v.dtype
        else:
            assert dec == v and type(dec) is type(v)


def test_codec_is_strict():
    with pytest.raises(codec.CodecError):
        codec.encode(object())                  # unencodable value
    with pytest.raises(codec.CodecError):
        codec.decode(["nosuchtag", 1])          # unknown tag
    with pytest.raises(codec.CodecError):
        codec.decode(["i"])                     # malformed payload
    with pytest.raises(codec.CodecError):       # oversized array
        codec.encode(np.zeros(1 << 20, np.float32))


def test_store_atomic_and_corrupt(tmp_path):
    st = ArtifactStore(str(tmp_path), "ns")
    assert st.write_json("a/r.json", {"k": [1, 2]}) > 0
    assert st.read_json("a/r.json") == {"k": [1, 2]}
    assert st.read_json("a/absent.json") is None
    # corruption degrades to a miss, never an exception
    with open(os.path.join(str(tmp_path), "ns", "a", "r.json"), "w") as f:
        f.write('{"k": [1,')
    assert st.read_json("a/r.json") is None
    assert st.write_bytes("seg/x.bin", b"\x00\x01") == 2
    assert st.read_bytes("seg/x.bin") == b"\x00\x01"
    st.delete("seg/x.bin")
    assert st.read_bytes("seg/x.bin") is None
    assert "r.json" in st.list("a")


def test_artifacts_written_in_process(tmp_path):
    w = Variable(np.ones(8, np.float32))

    @function(cache_dir=str(tmp_path))
    def step(x):
        y = ops.mul(x, 2.0)
        w.assign(ops.add(w.read(), y))
        return float(ops.reduce_sum(w.read()))

    for i in range(4):
        step(np.full(8, 0.1 * i, np.float32))
    step.wait()
    assert step.stats["artifacts_stored"] > 0
    found = [f for _, _, fs in os.walk(str(tmp_path)) for f in fs]
    assert any(f.endswith(".json") for f in found)      # family record
    step.close()


# ==========================================================================
# cross-process warm boot
# ==========================================================================

TRAIN_PROG = """
    import json
    import numpy as np
    from repro.core import Variable, function, ops

    w = Variable(np.eye(4, dtype=np.float32))

    @function
    def step(x):
        y = ops.matmul(x, w.read())
        w.assign(ops.add(w.read(), ops.mul(y, 0.01)))
        return float(ops.reduce_sum(y))

    outs = [step(np.full((4, 4), i * 0.1, np.float32)) for i in range(8)]
    step.wait()
    st = step.stats
    print(json.dumps({"outs": outs, "retraces": st["retraces"],
                      "recompiled": st["segments_recompiled"],
                      "hits": st["artifact_hits"],
                      "warm": st["warm_families"],
                      "aot": st["aot_loads"],
                      "stored": st["artifacts_stored"]}))
    step.close()
"""


@pytest.mark.slow
def test_warmboot_cross_process(tmp_path):
    cold = run_sub(TRAIN_PROG, str(tmp_path))
    warm = run_sub(TRAIN_PROG, str(tmp_path))
    assert cold["stored"] > 0 and cold["warm"] == 0
    # the warm-boot contract: nothing traced, nothing recompiled
    assert warm["retraces"] == 0
    assert warm["recompiled"] == 0
    assert warm["hits"] > 0 and warm["warm"] >= 1 and warm["aot"] >= 1
    np.testing.assert_allclose(warm["outs"], cold["outs"], rtol=1e-6)


@pytest.mark.slow
def test_corruption_falls_back_to_cold(tmp_path):
    cold = run_sub(TRAIN_PROG, str(tmp_path))
    # truncate every stored artifact: hydration must degrade to a fresh
    # trace ("slower never wrong"), not crash or load a wrong value
    for root, _, files in os.walk(str(tmp_path)):
        for f in files:
            p = os.path.join(root, f)
            with open(p, "r+b") as fh:
                fh.truncate(os.path.getsize(p) // 2)
    warm = run_sub(TRAIN_PROG, str(tmp_path))
    assert warm["warm"] == 0 and warm["aot"] == 0
    np.testing.assert_allclose(warm["outs"], cold["outs"], rtol=1e-6)


@pytest.mark.slow
def test_version_skew_is_clean_miss(tmp_path):
    cold = run_sub(TRAIN_PROG, str(tmp_path), TERRA_CACHE_SALT="v1")
    skew = run_sub(TRAIN_PROG, str(tmp_path), TERRA_CACHE_SALT="v2")
    assert skew["hits"] == 0 and skew["warm"] == 0      # different namespace
    np.testing.assert_allclose(skew["outs"], cold["outs"], rtol=1e-6)
    warm = run_sub(TRAIN_PROG, str(tmp_path), TERRA_CACHE_SALT="v1")
    assert warm["warm"] >= 1                            # original still hits


# ==========================================================================
# eviction -> reactivation hydrates from disk (satellite fix)
# ==========================================================================

def test_evicted_family_rehydrates(tmp_path):
    @function(cache_dir=str(tmp_path), max_families=1)
    def step(x):
        return float(ops.reduce_sum(ops.mul(x, 3.0)))

    a = np.ones(4, np.float32)
    b = np.ones(8, np.float32)
    for _ in range(3):
        assert step(a) == 12.0
    for _ in range(3):
        assert step(b) == 24.0          # evicts family A -> saved to disk
    before = dict(step.stats)
    for _ in range(3):
        assert step(a) == 12.0          # reactivation hydrates, not traces
    step.wait()
    assert step.stats["warm_families"] - before["warm_families"] >= 1
    assert step.stats["traced_iterations"] == before["traced_iterations"]
    step.close()


# ==========================================================================
# engine checkpoint/restore
# ==========================================================================

def test_engine_checkpoint_continuation(tmp_path):
    w = Variable(np.zeros(4, np.float32))

    def stepfn(x):
        w.assign(ops.add(w.read(), x))
        return float(ops.reduce_sum(w.read()))

    tf1 = function(stepfn)
    feeds = [np.full(4, 0.5, np.float32)] * 4
    for x in feeds:
        tf1(x)
    tf1.save_checkpoint(str(tmp_path / "ck"))
    cont = [tf1(x) for x in feeds]      # the donor's own continuation
    tf1.close()

    tf2 = function(stepfn)              # fresh engine, same Variables
    tf2.restore_checkpoint(str(tmp_path / "ck"))
    resumed = [tf2(x) for x in feeds]
    tf2.wait()
    np.testing.assert_allclose(resumed, cont, rtol=1e-6)
    assert tf2.stats["checkpoint_restores"] == 1
    tf2.close()


def test_engine_restore_raises_on_missing(tmp_path):
    tf = function(lambda x: float(ops.reduce_sum(x)))
    with pytest.raises((OSError, ValueError)):
        tf.restore_checkpoint(str(tmp_path / "nowhere"))
    tf.close()


# ==========================================================================
# scheduler checkpoint: exact continuation across a process boundary
# ==========================================================================

SCHED_PROG = """
    import json, sys, numpy as np, jax
    from repro.configs import smoke_config
    from repro.models import model as M
    from repro.serve.engine import Request
    from repro.serve.scheduler import ContinuousBatchingScheduler

    role, path = sys.argv[1], sys.argv[2]
    cfg = smoke_config("llama3-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(0, cfg.vocab, 4 + i).astype(np.int32)
               for i in range(6)]
    reqs = [Request(prompt=p, max_new_tokens=10, arrival_time=0.0)
            for p in prompts]

    if role == "ref":
        sch = ContinuousBatchingScheduler(cfg, params, max_slots=4,
                                          max_len=64, temperature=0.0)
        sch.serve(reqs)
        print(json.dumps({"toks": [r.out_tokens for r in reqs]}))
    elif role == "ckpt":
        sch = ContinuousBatchingScheduler(cfg, params, max_slots=4,
                                          max_len=64, temperature=0.0)
        for r in reqs:
            sch.submit(r)
        sch.run(max_steps=7)    # stop mid-decode: 4 in flight, 2 queued
        sch.checkpoint(path)
        assert sch.pool.active_count > 0 and len(sch.queue) > 0
        print(json.dumps({"partial": {r.rid: r.out_tokens or []
                                      for r in reqs}}))
    else:
        sch = ContinuousBatchingScheduler.restore(path, cfg, params)
        partial = json.load(open(path + "/partial.json"))
        tracked = {r.rid: r for _, r in sch.pool.active_items()}
        tracked.update({r.rid: r for r in sch.queue._queue})
        sch.run()
        full = {int(k): v for k, v in partial.items()}
        for rid, r in tracked.items():
            full[rid] = r.out_tokens
        print(json.dumps({"toks": [full[rid] for rid in sorted(full)]}))
    sch.close()
"""


@pytest.mark.slow
def test_scheduler_checkpoint_token_equality(tmp_path):
    ck = str(tmp_path / "sched_ck")

    def run_role(role):
        env = {**os.environ, "PYTHONPATH": os.path.join(ROOT, "src")}
        out = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(SCHED_PROG), role, ck],
            env=env, capture_output=True, text=True, timeout=600)
        assert out.returncode == 0, out.stderr[-3000:]
        return json.loads(out.stdout.strip().splitlines()[-1])

    ref = run_role("ref")
    partial = run_role("ckpt")["partial"]
    with open(ck + "/partial.json", "w") as f:
        json.dump(partial, f)
    resumed = run_role("resume")
    # every request finishes with exactly the tokens the uninterrupted
    # donor would have produced — greedy continuation is bit-identical
    assert resumed["toks"] == ref["toks"]
