"""Shared neural-net building blocks (pure JAX, param pytrees)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical


def rms_norm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


def dense(x, w, b=None):
    y = jnp.einsum("...d,df->...f", x, w)
    if b is not None:
        y = y + b
    return y


# --------------------------------------------------------------------------
# Rotary position embedding
# --------------------------------------------------------------------------

def rope(x, positions, theta: float = 500000.0):
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def _hidden_names(ndim):
    return ("batch",) + (None,) * (ndim - 2) + ("d_ff",)


def mlp_swiglu(p, x):
    """Llama-family gated MLP: down(silu(gate(x)) * up(x))."""
    h = jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    h = logical(h, *_hidden_names(h.ndim))
    return dense(h, p["w_down"])


def mlp_gelu(p, x):
    h = jax.nn.gelu(dense(x, p["w_up"], p.get("b_up")))
    h = logical(h, *_hidden_names(h.ndim))
    return dense(h, p["w_down"], p.get("b_down"))


def embed(table, ids):
    return jnp.take(table, ids, axis=0)


def unembed(x, table):
    """Logits projection; table [vocab, d] (tied) -> [..., vocab]."""
    return jnp.einsum("...d,vd->...v", x, table)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def trunc_normal(key, shape, dtype, scale: float):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def he_init(key, shape, dtype):
    fan_in = shape[0] if len(shape) >= 1 else 1
    return trunc_normal(key, shape, dtype, (2.0 / max(fan_in, 1)) ** 0.5)
