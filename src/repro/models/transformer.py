"""Unified transformer assembly for all assigned architectures.

The model is a stack of *super-blocks*: each super-block applies the
config's ``block_pattern`` once (e.g. ("rglru","rglru","attn_local") for
RecurrentGemma).  Super-blocks are scanned with ``jax.lax.scan`` over
stacked parameters so the HLO contains one super-block body + a loop —
essential to keep 100-layer configs compilable — and the scan body is
rematerialized (``jax.checkpoint``) for training memory.

All functions are pure; parameters are nested dicts with a leading
``n_pattern_blocks`` axis per pattern slot.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.attention import attention_block
from repro.models.moe import moe_block
from repro.models.rglru import rglru_block
from repro.models.ssm import mamba2_block
from repro.parallel.sharding import logical

ATTN_KINDS = ("attn", "attn_swa", "attn_local", "moe", "enc_attn")


# ==========================================================================
# Parameter initialization (per block kind)
# ==========================================================================

def _dt(cfg):
    return jnp.dtype(cfg.param_dtype)


def _attn_params(cfg, key, cross: bool = False):
    d, H, Hkv, D = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.he_init(ks[0], (d, H * D), _dt(cfg)),
        "wk": L.he_init(ks[1], (d, Hkv * D), _dt(cfg)),
        "wv": L.he_init(ks[2], (d, Hkv * D), _dt(cfg)),
        "wo": L.he_init(ks[3], (H * D, d), _dt(cfg)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * D,), _dt(cfg))
        p["bk"] = jnp.zeros((Hkv * D,), _dt(cfg))
        p["bv"] = jnp.zeros((Hkv * D,), _dt(cfg))
    return p


def _mlp_params(cfg, key, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": L.he_init(ks[0], (d, f), _dt(cfg)),
        "w_up": L.he_init(ks[1], (d, f), _dt(cfg)),
        "w_down": L.he_init(ks[2], (f, d), _dt(cfg)),
    }


def _moe_params(cfg, key):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "w_router": L.he_init(ks[0], (d, E), jnp.float32),
        "w_gate": L.he_init(ks[1], (E, d, f), _dt(cfg)),
        "w_up": L.he_init(ks[2], (E, d, f), _dt(cfg)),
        "w_down": L.he_init(ks[3], (E, f, d), _dt(cfg)),
    }
    if cfg.n_shared_experts:
        p["shared"] = _mlp_params(cfg, ks[4],
                                  d_ff=f * cfg.n_shared_experts)
    return p


def _ssd_params(cfg, key):
    d = cfg.d_model
    H, P, N, K = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.conv_kernel
    d_inner = H * P
    dc = d_inner + 2 * N
    ks = jax.random.split(key, 4)
    return {
        "w_in": L.he_init(ks[0], (d, 2 * d_inner + 2 * N + H), _dt(cfg)),
        "w_conv": L.trunc_normal(ks[1], (dc, K), _dt(cfg), 0.1),
        "dt_bias": jnp.zeros((H,), _dt(cfg)),
        "a_log": jnp.zeros((H,), jnp.float32),
        "w_out": L.he_init(ks[3], (d_inner, d), _dt(cfg)),
    }


def _rglru_params(cfg, key):
    d, dr, K = cfg.d_model, cfg.rglru_width, cfg.conv_kernel
    ks = jax.random.split(key, 6)
    return {
        "w_in_x": L.he_init(ks[0], (d, dr), _dt(cfg)),
        "w_in_y": L.he_init(ks[1], (d, dr), _dt(cfg)),
        "w_conv": L.trunc_normal(ks[2], (dr, K), _dt(cfg), 0.1),
        "w_a": L.he_init(ks[3], (dr, dr), _dt(cfg)),
        "b_a": jnp.zeros((dr,), _dt(cfg)),
        "w_x": L.he_init(ks[4], (dr, dr), _dt(cfg)),
        "b_x": jnp.zeros((dr,), _dt(cfg)),
        "lam": jnp.full((dr,), 0.7, jnp.float32),
        "w_out": L.he_init(ks[5], (dr, d), _dt(cfg)),
    }


def _norm_params(cfg):
    if cfg.norm == "ln":
        return {"scale": jnp.ones((cfg.d_model,), _dt(cfg)),
                "bias": jnp.zeros((cfg.d_model,), _dt(cfg))}
    return {"scale": jnp.zeros((cfg.d_model,), _dt(cfg))}


def _block_params(cfg, key, kind: str):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm1": _norm_params(cfg)}
    if kind in ("attn", "attn_swa", "attn_local", "enc_attn"):
        p["attn"] = _attn_params(cfg, ks[0])
        p["norm2"] = _norm_params(cfg)
        p["mlp"] = _mlp_params(cfg, ks[1])
    elif kind == "moe":
        p["attn"] = _attn_params(cfg, ks[0])
        p["norm2"] = _norm_params(cfg)
        p["moe"] = _moe_params(cfg, ks[1])
    elif kind == "ssd":
        p["ssd"] = _ssd_params(cfg, ks[0])
    elif kind == "rglru":
        p["rglru"] = _rglru_params(cfg, ks[0])
        p["norm2"] = _norm_params(cfg)
        p["mlp"] = _mlp_params(cfg, ks[1])
    elif kind == "cross":
        p["cross"] = _attn_params(cfg, ks[0], cross=True)
        p["norm2"] = _norm_params(cfg)
        p["mlp"] = _mlp_params(cfg, ks[1])
        p["gate"] = jnp.zeros((1,), _dt(cfg))     # gated cross-attn (llama3.2)
    elif kind == "dec_attn_cross":
        p["attn"] = _attn_params(cfg, ks[0])
        p["norm2"] = _norm_params(cfg)
        p["cross"] = _attn_params(cfg, ks[1], cross=True)
        p["norm3"] = _norm_params(cfg)
        p["mlp"] = _mlp_params(cfg, ks[2])
    else:
        raise ValueError(f"unknown block kind {kind}")
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    cfg.validate()
    nb = cfg.n_pattern_blocks
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": L.trunc_normal(keys[0], (cfg.vocab, cfg.d_model),
                                _dt(cfg), cfg.d_model ** -0.5),
        "final_norm": _norm_params(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.trunc_normal(
            keys[1], (cfg.vocab, cfg.d_model), _dt(cfg),
            cfg.d_model ** -0.5)

    def stack_slot(slot_idx, kind):
        ks = jax.random.split(jax.random.fold_in(keys[2], slot_idx), nb)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[_block_params(cfg, k, kind) for k in ks])

    params["blocks"] = [stack_slot(i, kind)
                        for i, kind in enumerate(cfg.block_pattern)]
    params["extra"] = [_block_params(cfg, jax.random.fold_in(keys[3], i), k)
                       for i, k in enumerate(cfg.extra_blocks)]
    if cfg.enc_layers:
        kse = jax.random.split(keys[4], cfg.enc_layers)
        params["encoder"] = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[_block_params(cfg, k, "enc_attn") for k in kse])
        params["enc_final_norm"] = _norm_params(cfg)
        params["enc_pos"] = L.trunc_normal(
            keys[5], (cfg.frontend_tokens or 1500, cfg.d_model),
            _dt(cfg), 0.02)
    return params


# ==========================================================================
# Forward
# ==========================================================================

def _norm(cfg, p, x):
    if cfg.norm == "ln":
        return L.layer_norm(x, p["scale"], p["bias"])
    return L.rms_norm(x, p["scale"])


def block_forward(cfg, kind: str, p, x, *, positions, cache=None,
                  cache_len=None, cache_bt=None, cross_states=None,
                  causal=True):
    """One block of kind ``kind``.  Returns (x, new_cache).

    Attention caches are stored per layer as {"k","v"} (dense rows) or
    {"kp","vp"} (paged block arenas); the shared fill length — and, for
    paged caches, the shared block table ``cache_bt`` — is threaded
    separately so layer caches can be stacked and scanned.
    """
    def _with_len(c):
        if c is None:
            return None
        c = {**c, "len": cache_len}
        if cache_bt is not None and "kp" in c:
            c["bt"] = cache_bt
        return c

    def _strip_len(c):
        return None if c is None else {k: v for k, v in c.items()
                                       if k not in ("len", "bt")}

    new_cache = None
    if kind in ("attn", "attn_swa", "attn_local", "enc_attn", "moe"):
        window = {"attn_swa": cfg.window,
                  "attn_local": cfg.local_window}.get(kind, 0)
        h, new_cache = attention_block(
            p["attn"], _norm(cfg, p["norm1"], x), cfg, positions=positions,
            cache=_with_len(cache),
            causal=causal and kind != "enc_attn", window=window)
        new_cache = _strip_len(new_cache)
        # named checkpoint: the "attn_out" remat policy saves exactly these
        # (cheap to store, expensive to recompute) and remats the FFN
        h = jax.ad_checkpoint.checkpoint_name(h, "attn_out")
        x = x + h
        ff_in = _norm(cfg, p["norm2"], x)
        if kind == "moe":
            if cfg.moe_impl == "shard_map":
                from repro.models.moe_ep import moe_block_ep
                x = x + moe_block_ep(p["moe"], ff_in, cfg)
            else:
                x = x + moe_block(p["moe"], ff_in, cfg)
        else:
            x = x + L.mlp_swiglu(p["mlp"], ff_in)
    elif kind == "ssd":
        h, new_cache = mamba2_block(p["ssd"], _norm(cfg, p["norm1"], x),
                                    cfg, cache=cache)
        x = x + h
    elif kind == "rglru":
        h, new_cache = rglru_block(p["rglru"], _norm(cfg, p["norm1"], x),
                                   cfg, cache=cache)
        x = x + h
        x = x + L.mlp_swiglu(p["mlp"], _norm(cfg, p["norm2"], x))
    elif kind == "cross":
        h, _ = attention_block(p["cross"], _norm(cfg, p["norm1"], x), cfg,
                               positions=positions,
                               cross_states=cross_states)
        x = x + jnp.tanh(p["gate"]) * h
        x = x + L.mlp_swiglu(p["mlp"], _norm(cfg, p["norm2"], x))
        new_cache = cache    # cross caches are static
    elif kind == "dec_attn_cross":
        h, new_cache = attention_block(
            p["attn"], _norm(cfg, p["norm1"], x), cfg,
            positions=positions, cache=_with_len(cache), causal=True)
        new_cache = _strip_len(new_cache)
        x = x + h
        h, _ = attention_block(p["cross"], _norm(cfg, p["norm2"], x), cfg,
                               positions=positions,
                               cross_states=cross_states)
        x = x + h
        x = x + L.mlp_swiglu(p["mlp"], _norm(cfg, p["norm3"], x))
    else:
        raise ValueError(kind)
    return x, new_cache


def _superblock(cfg, slot_params, x, *, positions, caches=None,
                cache_len=None, cache_bt=None, cross_states=None):
    """Apply one instance of the block pattern.  slot_params/caches are
    per-slot lists (already sliced to this super-block)."""
    new_caches = []
    for slot, kind in enumerate(cfg.block_pattern):
        c = caches[slot] if caches is not None else None
        x, nc = block_forward(cfg, kind, slot_params[slot], x,
                              positions=positions, cache=c,
                              cache_len=cache_len, cache_bt=cache_bt,
                              cross_states=cross_states)
        new_caches.append(nc)
    return x, new_caches


def run_stack(cfg, params, x, *, positions, caches=None, cross_states=None):
    """Scan over super-blocks (+ unrolled extra blocks)."""
    x = logical(x, "batch", None, None)
    cache_len = caches["len"] if caches is not None else None
    cache_bt = caches.get("bt") if caches is not None else None

    def body(h, xs):
        slot_params, slot_caches = xs
        h, new_caches = _superblock(cfg, slot_params, h,
                                    positions=positions,
                                    caches=slot_caches,
                                    cache_len=cache_len,
                                    cache_bt=cache_bt,
                                    cross_states=cross_states)
        return h, new_caches

    if cfg.remat:
        policy = {
            "dots": jax.checkpoint_policies.dots_saveable,
            "attn_out": jax.checkpoint_policies.save_only_these_names(
                "attn_out"),
            "full": None,
        }[cfg.remat_policy]
        body_fn = jax.checkpoint(body, policy=policy)
    else:
        body_fn = body
    scanned_caches = (caches["layers"] if caches is not None
                      else [None] * len(cfg.block_pattern))
    if cfg.unroll:
        # cost-probe mode: unrolled super-blocks (see configs/base.py)
        ys = []
        for i in range(cfg.n_pattern_blocks):
            xs_i = jax.tree.map(lambda a: a[i],
                                (params["blocks"], scanned_caches))
            x, y = body_fn(x, xs_i)
            ys.append(y)
        new_layer_caches = jax.tree.map(lambda *zs: jnp.stack(zs), *ys) \
            if caches is not None else None
    else:
        x, new_layer_caches = jax.lax.scan(
            body_fn, x, (params["blocks"], scanned_caches))

    new_extra = []
    for i, kind in enumerate(cfg.extra_blocks):
        c = caches["extra"][i] if caches is not None else None
        x, nc = block_forward(cfg, kind, params["extra"][i], x,
                              positions=positions, cache=c,
                              cache_len=cache_len, cache_bt=cache_bt,
                              cross_states=cross_states)
        new_extra.append(nc)

    new_caches = None
    if caches is not None:
        new_caches = {"layers": new_layer_caches, "extra": new_extra,
                      "len": cache_len + x.shape[1]}
    return x, new_caches


_run_stack = run_stack   # back-compat alias


def encode(cfg, params, frontend_embeds):
    """Encoder stack (Whisper): frontend embeddings [B, T, d] -> states."""
    x = frontend_embeds.astype(jnp.dtype(cfg.dtype))
    x = x + params["enc_pos"][:x.shape[1]][None]
    positions = jnp.arange(x.shape[1])[None]

    def body(h, p):
        h, _ = block_forward(cfg, "enc_attn", p, h, positions=positions,
                             causal=False)
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.unroll:
        for i in range(cfg.enc_layers):
            x, _ = body_fn(x, jax.tree.map(lambda a: a[i],
                                           params["encoder"]))
    else:
        x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    return _norm(cfg, params["enc_final_norm"], x)


def forward(cfg: ModelConfig, params, tokens, *, cross_states=None,
            frontend_embeds=None):
    """Training/eval forward: tokens [B, S] -> logits [B, S, vocab].

    ``frontend_embeds``: [B, S, d] continuous inputs replacing the token
    embedding (Mamba/audio stubs use tokens; VLM passes vision states via
    ``cross_states``; Whisper encodes ``frontend_embeds`` first).
    """
    if cfg.enc_layers and frontend_embeds is not None:
        cross_states = encode(cfg, params, frontend_embeds)
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(tokens.shape[1])[None]
    x, _ = _run_stack(cfg, params, x, positions=positions,
                      cross_states=cross_states)
    x = _norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(x, head)
    return logical(logits, "batch", None, "vocab")
