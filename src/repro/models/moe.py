"""Mixture-of-Experts: top-k router + capacity-bounded sort-free dispatch.

Dispatch uses the Switch-Transformer position-in-expert construction
(cumsum over one-hot assignments) followed by scatter into per-expert
buffers [E, C, d].  With experts sharded over the ``model`` mesh axis the
scatter/gather lowers to the expected all-to-all exchange under SPMD
(expert parallelism); with few experts (Mixtral's 8 on a 16-way axis) the
expert dim stays replicated and the per-expert FFN weights shard over
``d_ff`` instead (TP inside experts) — both fall out of the divisibility
rules in parallel/sharding.py.

DeepSeek-MoE fine-grained routing (64 routed + 2 shared experts, top-6) is
the same code path with ``n_shared_experts`` > 0.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, mlp_swiglu
from repro.parallel.sharding import current_policy, logical


def _moe_axes(E: int):
    """Pick buffer sharding: when the expert dim divides the model axis
    (fine-grained MoE, DeepSeek 64e) shard experts only — adding a capacity
    axis makes XLA's scatter repartitioning pathological (measured 8.3s ->
    77s collective on deepseek train_4k).  When experts cannot shard
    (Mixtral 8e on a 16-way axis) shard capacity over data instead, which
    keeps the expert FFN compute distributed (44s -> 8.7s compute)."""
    pol = current_policy()
    if pol is None or pol.mesh is None:
        return "expert", None
    axes = tuple(a for a in pol.rules.get("expert", ())
                 if a in pol.mesh.axis_names)
    size = 1
    for a in axes:
        size *= pol.mesh.shape[a]
    if axes and E % size == 0:
        return "expert", None
    return None, "capacity"


def moe_block(p, x, cfg):
    """x: [B, S, d] -> [B, S, d]."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)

    # ---- router ----------------------------------------------------------
    logits = dense(xt.astype(jnp.float32), p["w_router"])       # [T, E]
    gate_w, gate_ids = jax.lax.top_k(logits, K)                 # [T, K]
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(x.dtype)

    # ---- capacity + position-in-expert ------------------------------------
    C = int(cfg.capacity_factor * T * K / E)
    C = max(8, min(C, T))
    flat_ids = gate_ids.reshape(-1)                             # [T*K]
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)       # [T*K, E]
    pos_in_exp = (jnp.cumsum(onehot, axis=0) - onehot)          # exclusive
    pos = jnp.sum(pos_in_exp * onehot, axis=1)                  # [T*K]
    keep = pos < C
    slot = jnp.where(keep, pos, C)                              # C = drop row

    # ---- dispatch: scatter tokens into [E, C+1, d] -------------------------
    # expert dim shards over `model` (EP) when divisible, capacity over
    # `data` — the scatter from token-sharded to expert-sharded layout is
    # the all-to-all exchange of expert parallelism
    buf = jnp.zeros((E, C + 1, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = buf.at[flat_ids, slot].add(xt[tok_idx])
    e_ax, c_ax = _moe_axes(E)
    buf = logical(buf[:, :C], e_ax, c_ax, None)                 # [E, C, d]

    # ---- expert FFNs -------------------------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = logical(h, e_ax, c_ax, "d_ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # [E, C, d]
    out_buf = logical(out_buf, e_ax, c_ax, None)

    # ---- combine: gather back and weight ------------------------------------
    gathered = out_buf[flat_ids, jnp.minimum(slot, C - 1)]      # [T*K, d]
    gathered = gathered * keep[:, None].astype(x.dtype)
    combined = (gathered.reshape(T, K, d)
                * gate_w[..., None]).sum(axis=1)                # [T, d]

    # ---- shared experts (DeepSeek-MoE) ---------------------------------------
    if cfg.n_shared_experts:
        combined = combined + mlp_swiglu(p["shared"], xt)

    return combined.reshape(B, S, d)
