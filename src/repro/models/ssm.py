"""Mamba-2 (SSD: state-space duality, arXiv:2405.21060) block.

Chunked SSD algorithm: the sequence is split into chunks of length Q;
within a chunk the output is the quadratic (attention-like) form masked by
the cumulative decay; across chunks a recurrence carries the state
[H, P, N].  This is the TPU-friendly formulation (dense matmuls for the
MXU); the Pallas kernel in repro.kernels/ssd_scan.py implements the same
contraction with explicit VMEM tiling, and this module doubles as its
reference.

Decode: a single recurrent state update per token — O(H*P*N) per step,
which is why the 500k-token decode cell runs for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense
from repro.parallel.sharding import logical


def _segsum(a_chunk):
    """log-space cumulative decay matrix L[i, j] = sum_{k=j+1..i} a_k for
    i >= j else -inf.  a_chunk: [..., Q]."""
    Q = a_chunk.shape[-1]
    cs = jnp.cumsum(a_chunk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # [.., i, j] = cs_i - cs_j
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int, return_final: bool = False,
                unroll: bool = False):
    """SSD forward.

    x:  [B, S, H, P]   (inputs per head)
    dt: [B, S, H]      (positive step sizes, post-softplus)
    A:  [H]            (negative decay rates)
    Bm: [B, S, N]      (input projection, shared across heads — Mamba-2)
    Cm: [B, S, N]      (output projection)
    returns y: [B, S, H, P]
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    a = (dt * A[None, None, :])                      # [B,S,H] log-decay (<0)
    xr = x.reshape(B, nc, Q, H, P)
    ar = a.reshape(B, nc, Q, H)
    dtr = dt.reshape(B, nc, Q, H)
    Br = Bm.reshape(B, nc, Q, N)
    Cr = Cm.reshape(B, nc, Q, N)

    # ---- intra-chunk (quadratic, attention-like) --------------------------
    L = jnp.exp(_segsum(ar.transpose(0, 1, 3, 2)))   # [B,nc,H,Q,Q]
    scores = jnp.einsum("bcqn,bckn->bcqk", Cr, Br)   # [B,nc,Q,Q]
    M = scores[:, :, None] * L                       # [B,nc,H,Q,Q]
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", M, dtr, xr)

    # ---- chunk states ------------------------------------------------------
    a_cum = jnp.cumsum(ar, axis=2)                   # [B,nc,Q,H]
    a_tot = a_cum[:, :, -1]                          # [B,nc,H]
    decay_states = jnp.exp(a_tot[:, :, None] - a_cum)          # [B,nc,Q,H]
    states = jnp.einsum("bcqn,bcqh,bcqh,bcqhp->bchpn",
                        Br, decay_states, dtr, xr)   # [B,nc,H,P,N]

    # ---- inter-chunk recurrence -------------------------------------------
    def step(h, inp):
        st, atot = inp                               # [B,H,P,N], [B,H]
        h_new = h * jnp.exp(atot)[:, :, None, None] + st
        return h_new, h                              # emit state BEFORE chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    xs = (states.astype(jnp.float32).transpose(1, 0, 2, 3, 4),
          a_tot.astype(jnp.float32).transpose(1, 0, 2))
    if unroll:
        h, ys = h0, []
        for c in range(nc):
            h, y = step(h, (xs[0][c], xs[1][c]))
            ys.append(y)
        h_final, prev_states = h, jnp.stack(ys)
    else:
        h_final, prev_states = jax.lax.scan(step, h0, xs)
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,P,N]

    # ---- contribution of carried state to each position --------------------
    state_decay = jnp.exp(a_cum)                     # [B,nc,Q,H]
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                       Cr.astype(jnp.float32), prev_states,
                       state_decay.astype(jnp.float32))

    y = (y_diag.astype(jnp.float32) + y_off).reshape(B, S, H, P)
    y = y.astype(x.dtype)
    if return_final:
        return y, h_final
    return y


def ssd_decode_step(state, x, dt, A, Bm, Cm):
    """One-token recurrent update.

    state: [B, H, P, N]; x: [B, H, P]; dt: [B, H]; Bm/Cm: [B, N]
    returns (y [B,H,P], new_state)
    """
    da = jnp.exp(dt * A[None, :]).astype(jnp.float32)    # [B,H]
    upd = jnp.einsum("bn,bh,bhp->bhpn", Bm, dt, x).astype(jnp.float32)
    new_state = state.astype(jnp.float32) * da[:, :, None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    return y.astype(x.dtype), new_state


# --------------------------------------------------------------------------
# Full Mamba-2 block (projections + conv + SSD + gate)
# --------------------------------------------------------------------------

def mamba2_block(p, x, cfg, *, cache=None):
    """x: [B, S, d].  cache: None or dict(conv [B,K-1,dc], ssm [B,H,P,N]).

    Projections follow Mamba-2: in_proj -> (z gate, x, B, C, dt heads).
    """
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    d_inner = H * P
    K = cfg.conv_kernel

    zxbcdt = dense(x, p["w_in"])            # [B,S, 2*d_inner + 2*N + H]
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N,
                 2 * d_inner + 2 * N], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])          # [B,S,H]

    # depthwise causal conv over (x, B, C) as in Mamba-2
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)            # [B,S,dc]
    dc = conv_in.shape[-1]
    new_conv_state = None
    if cache is None:
        pad = jnp.zeros((B, K - 1, dc), conv_in.dtype)
        ci = jnp.concatenate([pad, conv_in], axis=1)
    else:
        ci = jnp.concatenate([cache["conv"], conv_in], axis=1)
        new_conv_state = ci[:, -(K - 1):]
    win = jnp.stack([ci[:, i:i + S] for i in range(K)], axis=-1)  # [B,S,dc,K]
    conv_out = jax.nn.silu(jnp.einsum("bsdk,dk->bsd", win, p["w_conv"]))
    xc, Bc, Cc = jnp.split(conv_out, [d_inner, d_inner + N], axis=-1)
    xc = xc.reshape(B, S, H, P)

    A = -jnp.exp(p["a_log"])                         # [H], negative
    new_ssm_state = None
    if cache is None:
        y = ssd_chunked(xc, dt, A, Bc, Cc, cfg.ssd_chunk,
                        unroll=cfg.unroll)
    elif S > 1:
        # prefill-with-cache: also return the final recurrent state
        y, new_ssm_state = ssd_chunked(xc, dt, A, Bc, Cc, cfg.ssd_chunk,
                                       return_final=True, unroll=cfg.unroll)
    else:
        y1, new_ssm_state = ssd_decode_step(
            cache["ssm"], xc[:, 0], dt[:, 0], A, Bc[:, 0], Cc[:, 0])
        y = y1[:, None]

    y = y.reshape(B, S, d_inner)
    y = y * jax.nn.silu(z)
    out = dense(y, p["w_out"])
    if cache is not None:
        return out, {"conv": new_conv_state, "ssm": new_ssm_state}
    return out, None
