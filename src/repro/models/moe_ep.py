"""Explicit expert-parallel MoE: shard_map local dispatch + all_to_all.

The pjit capacity-scatter implementation (models/moe.py) is partitioned by
XLA SPMD with "involuntary full rematerialization" (its own warning),
inflating collectives ~250x over the ideal token exchange
(EXPERIMENTS.md §Roofline).  This module is the engineered fix, the
MaxText/Megatron formulation:

  1. inside shard_map, each (data-row, model-col) device routes its LOCAL
     tokens and scatters them into a local [E, C_loc, d] buffer — no
     cross-device indexing;
  2. one all_to_all over the model axis regroups by expert:
     [E, C_loc, d] -> [E/ep, ep*C_loc, d], aligning tokens with the
     expert weight shard resident on the device;
  3. local expert FFNs (dense MXU matmuls);
  4. the reverse all_to_all returns expert outputs to the owning shard,
     which combines them with the gate weights.

Wire cost per device per step = 2 x (top_k-expanded activations), the
information-theoretic minimum for capacity-based EP.

Requires n_experts % model_axis_size == 0 (DeepSeek 64e on a 16-way axis;
Mixtral's 8e keeps the pjit path)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
try:
    from jax import shard_map
    _REPL_CHECK_KW = "check_vma"
except ImportError:                     # jax < 0.5 ships it as experimental
    from jax.experimental.shard_map import shard_map
    _REPL_CHECK_KW = "check_rep"        # pre-rename replication-check kwarg
from jax.sharding import PartitionSpec as P

from repro.models.layers import dense
from repro.parallel.sharding import current_policy


def _shared_mlp(p, x):
    # plain gated MLP: no logical() constraints (illegal inside shard_map,
    # where the mesh axes are manual)
    h = jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    return dense(h, p["w_down"])


def _local_moe(p, xt, cfg, ep: int, model_axis: str):
    """Per-device body (inside shard_map).  xt: [T_loc, d] local tokens;
    expert weights already sharded: p['w_*'] leading dim E/ep."""
    T, d = xt.shape
    E, K = cfg.n_experts, cfg.top_k
    e_loc = E // ep

    logits = dense(xt.astype(jnp.float32), p["w_router"])       # [T, E]
    gate_w, gate_ids = jax.lax.top_k(logits, K)
    gate_w = jax.nn.softmax(gate_w, axis=-1).astype(xt.dtype)

    C = max(8, int(cfg.capacity_factor * T * K / E))
    flat_ids = gate_ids.reshape(-1)                             # [T*K]
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=1)
    keep = pos < C
    slot = jnp.where(keep, pos, C)

    # 1. local dispatch buffer [E, C+1, d]
    buf = jnp.zeros((E, C + 1, d), xt.dtype)
    tok_idx = jnp.repeat(jnp.arange(T), K)
    buf = buf.at[flat_ids, slot].add(xt[tok_idx])[:, :C]        # [E, C, d]

    # 2. all_to_all (tiled): split experts across the axis, concatenate the
    #    received capacity blocks — [E, C, d] -> [E/ep, ep*C, d]
    buf = jax.lax.all_to_all(buf, model_axis, split_axis=0, concat_axis=1,
                             tiled=True)

    # 3. local expert FFNs
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])            # [E/ep, ep*C, d]

    # 4. reverse all_to_all: back to [E, C, d] on the owning shard with the
    #    original slot layout (device order round-trips)
    out = jax.lax.all_to_all(out, model_axis, split_axis=1, concat_axis=0,
                             tiled=True)

    # combine locally
    gathered = out[flat_ids, jnp.minimum(slot, C - 1)]
    gathered = gathered * keep[:, None].astype(xt.dtype)
    combined = (gathered.reshape(T, K, d) * gate_w[..., None]).sum(axis=1)

    if cfg.n_shared_experts:
        combined = combined + _shared_mlp(p["shared"], xt)
    return combined


def moe_block_ep(p, x, cfg):
    """x: [B, S, d] -> [B, S, d] via explicit EP.  Falls back to the pjit
    path when no mesh is active or experts don't divide the model axis."""
    pol = current_policy()
    mesh = pol.mesh if pol is not None else None
    if mesh is None or "model" not in mesh.axis_names \
            or cfg.n_experts % mesh.shape["model"] != 0:
        from repro.models.moe import moe_block
        return moe_block(p, x, cfg)
    ep = mesh.shape["model"]
    B, S, d = x.shape
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names
                       and B % mesh.shape[a] == 0)

    def body(p_loc, x_loc):
        Bl, Sl, _ = x_loc.shape
        y = _local_moe(p_loc, x_loc.reshape(Bl * Sl, d), cfg, ep, "model")
        return y.reshape(Bl, Sl, d)

    pspec = {
        "w_router": P(),
        "w_gate": P("model", None, None),
        "w_up": P("model", None, None),
        "w_down": P("model", None, None),
    }
    if cfg.n_shared_experts:
        pspec["shared"] = {k: P() for k in p["shared"]}
    xspec = P(batch_axes if batch_axes else None, None, None)

    return shard_map(body, mesh=mesh, in_specs=(pspec, xspec),
                     out_specs=xspec, **{_REPL_CHECK_KW: False})(p, x)
