"""Model entry points: init, cache management, input specs for every
(arch × shape) cell, and the serve-path wrappers used by the dry-run and
the serving engine."""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import logical


def init_params(cfg: ModelConfig, key):
    return T.init_params(cfg, key)


def abstract_params(cfg: ModelConfig):
    """ShapeDtypeStruct pytree of the parameters — used by the dry-run so
    no memory is ever allocated for full-size configs."""
    return jax.eval_shape(lambda k: T.init_params(cfg, k),
                          jax.random.PRNGKey(0))


def param_count(cfg: ModelConfig) -> int:
    import math
    return sum(math.prod(x.shape)                # python ints: no overflow
               for x in jax.tree.leaves(abstract_params(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: top_k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    f = cfg.moe_d_ff or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    n_blocks = cfg.n_pattern_blocks * cfg.block_pattern.count("moe")
    inactive = n_blocks * (cfg.n_experts - cfg.top_k) * per_expert
    return total - inactive


# ==========================================================================
# KV / recurrent cache
# ==========================================================================

def _slot_cache(cfg, kind: str, nb: Optional[int], batch: int, max_len: int):
    """Cache pytree for one pattern slot; leading nb axis when scanned."""
    dt = jnp.dtype(cfg.dtype)

    def shp(*s):
        return (nb,) + tuple(s) if nb is not None else tuple(s)

    if kind in ("attn", "attn_swa", "attn_local", "moe", "dec_attn_cross"):
        Hkv, D = cfg.n_kv_heads, cfg.head_dim
        return {"k": jnp.zeros(shp(batch, max_len, Hkv, D), dt),
                "v": jnp.zeros(shp(batch, max_len, Hkv, D), dt)}
    if kind == "ssd":
        H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        dc = H * P + 2 * N                      # conv runs over (x, B, C)
        # recurrent state kept in f32 for numerical stability
        return {"conv": jnp.zeros(shp(batch, cfg.conv_kernel - 1, dc), dt),
                "ssm": jnp.zeros(shp(batch, H, P, N), jnp.float32)}
    if kind == "rglru":
        dr = cfg.rglru_width
        return {"conv": jnp.zeros(shp(batch, cfg.conv_kernel - 1, dr), dt),
                "h": jnp.zeros(shp(batch, dr), jnp.float32)}
    if kind == "cross":
        return None
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    nb = cfg.n_pattern_blocks
    return {
        "layers": [_slot_cache(cfg, kind, nb, batch, max_len)
                   for kind in cfg.block_pattern],
        "extra": [_slot_cache(cfg, kind, None, batch, max_len)
                  for kind in cfg.extra_blocks],
        "len": jnp.zeros((), jnp.int32),
    }


# ==========================================================================
# Serve-path entry points
# ==========================================================================

def prefill(cfg: ModelConfig, params, tokens, max_len: int, *,
            cross_states=None, frontend_embeds=None):
    """tokens [B, S] -> (last-position logits [B, vocab], cache)."""
    if cfg.enc_layers and frontend_embeds is not None:
        cross_states = T.encode(cfg, params, frontend_embeds)
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = jnp.arange(S)[None]
    x, cache = T.run_stack(cfg, params, x, positions=positions,
                           caches=cache, cross_states=cross_states)
    x = T._norm(cfg, params["final_norm"], x[:, -1:])
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(x[:, 0], head)
    return logical(logits, "batch", "vocab"), cache


def decode_step(cfg: ModelConfig, params, cache, tokens, *,
                cross_states=None):
    """One decode step: tokens [B, 1] -> (logits [B, vocab], new cache).

    The KV cache is donated by the serving engine (buffer reuse)."""
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    positions = cache["len"] + jnp.arange(1)[None]
    x, cache = T.run_stack(cfg, params, x, positions=positions,
                           caches=cache, cross_states=cross_states)
    x = T._norm(cfg, params["final_norm"], x)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.unembed(x[:, 0], head)
    return logical(logits, "batch", "vocab"), cache


forward = T.forward


# ==========================================================================
# Input specs (ShapeDtypeStruct stand-ins for every model input)
# ==========================================================================

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract inputs for the given cell.  ``train``: tokens+labels;
    ``prefill``: prompt tokens; ``decode``: one new token + a cache filled
    to seq_len.  Modality frontends are stubs: precomputed frame/patch
    embeddings (per the brief)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct

    extras: Dict[str, Any] = {}
    if cfg.family == "vlm":
        extras["cross_states"] = sds((B, cfg.frontend_tokens, cfg.d_model), bf)
    if cfg.family == "audio":
        extras["frontend_embeds"] = sds((B, cfg.frontend_tokens, cfg.d_model),
                                        jnp.float32)

    if shape.kind == "train":
        return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32),
                **extras}
    if shape.kind == "prefill":
        return {"tokens": sds((B, S), i32), **extras}
    if shape.kind == "decode":
        cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
        return {"tokens": sds((B, 1), i32), "cache": cache, **extras}
    raise ValueError(shape.kind)
