"""RecurrentGemma (arXiv:2402.19427) recurrent block: temporal conv + RG-LRU.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)              (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)              (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)    (decay, c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses an associative scan (log-depth on the sequence);
decode is a single recurrent update (why long_500k runs for this family).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense

_C = 8.0


def _rg_lru_scan(x_gated, a, h0=None):
    """h_t = a_t * h_{t-1} + x_gated_t via associative scan.
    x_gated/a: [B, S, D]."""
    if h0 is not None:
        # fold the initial state into the first element
        x_gated = x_gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, x1 = c1
        a2, x2 = c2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a, x_gated), axis=1)
    return h


def rglru_block(p, x, cfg, *, cache=None):
    """x: [B, S, d].  cache: None or dict(conv [B,K-1,dr], h [B,dr])."""
    B, S, d = x.shape
    dr = cfg.rglru_width                       # recurrent width
    K = cfg.conv_kernel

    xb = dense(x, p["w_in_x"])                 # [B,S,dr] linear branch
    yb = jax.nn.gelu(dense(x, p["w_in_y"]))    # gated branch

    # temporal conv (depthwise, causal)
    new_conv = None
    if cache is None:
        pad = jnp.zeros((B, K - 1, dr), xb.dtype)
        ci = jnp.concatenate([pad, xb], axis=1)
    else:
        ci = jnp.concatenate([cache["conv"], xb], axis=1)
        new_conv = ci[:, -(K - 1):]
    win = jnp.stack([ci[:, i:i + S] for i in range(K)], axis=-1)
    xc = jnp.einsum("bsdk,dk->bsd", win, p["w_conv"])

    # RG-LRU
    r = jax.nn.sigmoid(dense(xc, p["w_a"]) + p["b_a"])
    i = jax.nn.sigmoid(dense(xc, p["w_x"]) + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r          # [B,S,dr]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6)) * (i * xc)

    a = a.astype(jnp.float32)
    gated = gated.astype(jnp.float32)
    new_h = None
    if cache is None:
        h = _rg_lru_scan(gated, a)
    elif S == 1:
        h1 = a[:, 0] * cache["h"] + gated[:, 0]
        h = h1[:, None]
        new_h = h1
    else:
        h = _rg_lru_scan(gated, a, h0=cache["h"].astype(jnp.float32))
        new_h = h[:, -1]

    out = dense(h.astype(x.dtype) * yb, p["w_out"])
    if cache is not None:
        return out, {"conv": new_conv, "h": new_h}
    return out, None
