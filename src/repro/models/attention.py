"""Attention: GQA / MQA, causal, sliding-window, local, cross; chunked
memory-efficient XLA implementation (the Pallas flash kernel in
repro.kernels is the TPU-optimized path; this module is the portable
reference used by the dry-run and smoke tests).

The chunked implementation scans over query blocks and, within each, over
key/value blocks with an online-softmax accumulator, so peak memory is
O(Bq*Bk) instead of O(S^2) — required for the 32k-prefill and 4k-train
shapes at production batch sizes.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rope
from repro.parallel.sharding import logical

NEG_INF = -1e30

# Trace-time switch: when True, paged-cache decode attends through the
# Pallas paged-attention kernel instead of the gather + dense reference
# path.  Flipped by the kernel-substituted ``kernel.slot_decode_paged``
# op around its trace (pass pipeline ``kernels``, DESIGN.md §12).
PAGED_KERNEL = False


def _pick_block(s: int, target: int) -> int:
    """Largest divisor of s that is <= target (block sizes must tile s)."""
    b = min(target, s)
    while s % b:
        b -= 1
    return max(b, 1)


def _scan_or_unroll(f, init, n, unroll):
    """lax.scan over jnp.arange(n), or an unrolled Python loop (cost probes)."""
    if not unroll:
        return jax.lax.scan(f, init, jnp.arange(n))
    carry, ys = init, []
    for i in range(n):
        carry, y = f(carry, i)
        ys.append(y)
    out = (jnp.stack(ys) if ys and ys[0] is not None else None)
    return carry, out


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_block: int = 512, kv_block: int = 1024,
                      q_offset=0, kv_valid_len=None, unroll: bool = False):
    """q: [B,Sq,Hq,D], k/v: [B,Skv,Hkv,D] with Hq % Hkv == 0.

    ``window`` > 0 restricts attention to the last ``window`` keys (SWA /
    local attention).  ``q_offset`` is the absolute position of q[0]
    (used at decode time and for local attention in cache mode).
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = D ** -0.5

    qb = _pick_block(Sq, q_block)
    kb = _pick_block(Skv, kv_block)
    nq, nk = Sq // qb, Skv // kb

    # [B, Hkv, G, nq, qb, D]
    qr = q.reshape(B, nq, qb, Hkv, G, D).transpose(0, 3, 4, 1, 2, 5) * scale
    kr = k.reshape(B, nk, kb, Hkv, D).transpose(0, 3, 1, 2, 4)
    vr = v.reshape(B, nk, kb, Hkv, D).transpose(0, 3, 1, 2, 4)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, qb)
    k_pos = jnp.arange(Skv).reshape(nk, kb)

    def q_step(_, qi):
        qblk = qr[:, :, :, qi]                     # [B,Hkv,G,qb,D]
        qp = q_pos[qi]                             # [qb]

        def kv_step(carry, ki):
            acc, m, l = carry
            kblk = kr[:, :, ki]                    # [B,Hkv,kb,D]
            vblk = vr[:, :, ki]
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qblk, kblk,
                           preferred_element_type=jnp.float32)
            kp = k_pos[ki]
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window:
                mask &= kp[None, :] > qp[:, None] - window
            if kv_valid_len is not None:
                mask &= kp[None, :] < kv_valid_len
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32)
            return (acc_new, m_new, l_new), None

        init = (jnp.zeros((B, Hkv, G, qb, D), jnp.float32),
                jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, qb), jnp.float32))
        (acc, m, l), _ = _scan_or_unroll(kv_step, init, nk, unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = _scan_or_unroll(q_step, None, nq, unroll)
    # outs: [nq, B, Hkv, G, qb, D] -> [B, Sq, Hq, D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hq, D)
    return out


def decode_attention(q, k_cache, v_cache, cache_len, *, window: int = 0):
    """Single-token decode: q [B,1,Hq,D]; caches [B,Smax,Hkv,D];
    cache_len: [B] or scalar valid length."""
    B, _, Hq, D = q.shape
    _, Smax, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    qr = q.reshape(B, Hkv, G, D) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qr, k_cache,
                   preferred_element_type=jnp.float32)
    pos = jnp.arange(Smax)
    valid = pos[None, :] < jnp.reshape(cache_len, (-1, 1))
    if window:
        valid &= pos[None, :] >= jnp.reshape(cache_len, (-1, 1)) - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# --------------------------------------------------------------------------
# Full attention block (projections + rope + cache handling)
# --------------------------------------------------------------------------

def attention_block(p, x, cfg, *, positions=None, cache=None,
                    cross_states=None, causal=True, window=0,
                    use_rope=True):
    """Returns (out, new_cache).

    cache: None (training/prefill-no-cache) or dict with k/v [B,Smax,Hkv,D]
    and ``len`` (filled length).  When ``cross_states`` is given, k/v come
    from the encoder/vision states and no cache/causal masking applies
    (cross-attention caches are precomputed at prefill in serve mode).
    """
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    q = dense(x, p["wq"], p.get("bq")).reshape(B, S, H, D)
    kv_src = cross_states if cross_states is not None else x
    Skv = kv_src.shape[1]
    k = dense(kv_src, p["wk"], p.get("bk")).reshape(B, Skv, Hkv, D)
    v = dense(kv_src, p["wv"], p.get("bv")).reshape(B, Skv, Hkv, D)

    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope and cross_states is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, jnp.arange(Skv)[None, :] if cache is None else positions,
                 cfg.rope_theta)

    q = logical(q, "batch", None, "heads", None)
    k = logical(k, "batch", None, "kv_heads", None)
    v = logical(v, "batch", None, "kv_heads", None)

    new_cache = None
    if cache is not None and cross_states is None and "kp" in cache:
        # paged decode: K/V live in a flat block arena addressed through
        # the per-slot block table ``bt`` [B, nbps].  The new K/V lands at
        # the row's current position (block-table indirection); attention
        # gathers the row's blocks back into logical order, which is
        # bit-identical to the dense row, so paged == dense greedy tokens.
        idx = cache["len"]
        if S != 1 or not jnp.ndim(idx):
            raise NotImplementedError(
                "paged cache supports vector-position single-token decode")
        kp, vp, bt = cache["kp"], cache["vp"], cache["bt"]
        kv = k.astype(kp.dtype)[:, 0]              # [B, Hkv, D]
        vv = v.astype(vp.dtype)[:, 0]
        nblk, bs = kp.shape[0], kp.shape[1]
        blk = jnp.take_along_axis(bt, (idx // bs)[:, None], axis=1)[:, 0]
        dest = blk * bs + idx % bs                 # flat arena position
        kp = kp.reshape(nblk * bs, Hkv, D).at[dest].set(kv).reshape(kp.shape)
        vp = vp.reshape(nblk * bs, Hkv, D).at[dest].set(vv).reshape(vp.shape)
        new_cache = {"kp": kp, "len": idx + 1, "vp": vp}
        if PAGED_KERNEL:
            from repro.kernels import ops as kops
            out = kops.paged_attention(q, kp, vp, bt, idx + 1, window=window)
        else:
            Bq, nbps = bt.shape
            kg = kp[bt].reshape(Bq, nbps * bs, Hkv, D)
            vg = vp[bt].reshape(Bq, nbps * bs, Hkv, D)
            out = decode_attention(q, kg, vg, idx + 1, window=window)
    elif cache is not None and cross_states is None:
        # decode/step mode: append to cache then attend over it.  ``len``
        # is a scalar (lock-step serving: every row at the same fill) or a
        # [B] vector (slot-pooled serving: per-slot positions) — the vector
        # case writes each row at its own offset via a vmapped update.
        idx = cache["len"]
        kv, vv = k.astype(cache["k"].dtype), v.astype(cache["v"].dtype)
        if jnp.ndim(idx):
            if S != 1:
                raise NotImplementedError(
                    "per-row cache positions support single-token decode "
                    "only (got S=%d)" % S)
            upd = jax.vmap(functools.partial(
                jax.lax.dynamic_update_slice_in_dim, axis=0))
            k_cache = upd(cache["k"], kv, idx)
            v_cache = upd(cache["v"], vv, idx)
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], kv,
                                                          idx, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], vv,
                                                          idx, axis=1)
        new_cache = {"k": k_cache, "v": v_cache, "len": idx + S}
        if S == 1:
            out = decode_attention(q, k_cache, v_cache, idx + 1,
                                   window=window)
        else:
            out = chunked_attention(q, k_cache, v_cache, causal=causal,
                                    window=window, q_offset=idx,
                                    kv_valid_len=idx + S,
                                    q_block=cfg.q_block,
                                    kv_block=cfg.kv_block,
                                    unroll=cfg.unroll)
    else:
        out = chunked_attention(q, k, v,
                                causal=causal and cross_states is None,
                                window=window,
                                q_block=cfg.q_block, kv_block=cfg.kv_block,
                                unroll=cfg.unroll)

    out = logical(out, "batch", None, "heads", None)
    out = dense(out.reshape(B, S, H * D), p["wo"])
    return out, new_cache
