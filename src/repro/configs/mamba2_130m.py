"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].
Attention-free; the 500k-decode cell RUNS (recurrent state, O(1)/token)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=50280,
    block_pattern=("ssd",),
    ssm_heads=24, ssm_head_dim=64, ssm_state=128,   # d_inner = 2*d_model
    conv_kernel=4, ssd_chunk=256, tie_embeddings=True,
    head_dim=1,
)
