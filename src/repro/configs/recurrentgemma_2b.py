"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern
[arXiv:2402.19427].  26 layers = 8 x (rglru, rglru, attn_local) + 2 rglru.
Recurrent state is O(1)/token, so the 500k-decode cell RUNS."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    rope_theta=10000.0,
    block_pattern=("rglru", "rglru", "attn_local"),
    extra_blocks=("rglru", "rglru"),
    local_window=2048, rglru_width=2560, conv_kernel=4,
    tie_embeddings=True,
)
