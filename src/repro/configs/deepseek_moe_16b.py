"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts
[arXiv:2401.06066].  (The published model uses one dense first layer; we use
the MoE pattern uniformly — noted in DESIGN.md.)"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=102400, head_dim=128,
    rope_theta=10000.0, block_pattern=("moe",),
    n_experts=64, top_k=6, n_shared_experts=2, moe_d_ff=1408,
)
