"""whisper-small [audio] — enc-dec, conv frontend (stub) [arXiv:2212.04356].

n_layers counts the DECODER layers; enc_layers the encoder.  The conv
frontend is a stub: input_specs() provides precomputed frame embeddings
[B, 1500, d].  Decoder uses RoPE instead of learned positions (deviation
noted in DESIGN.md); assigned 32k shapes stress the architecture beyond its
trained 448 positions but are structurally well-defined.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64, norm="ln",
    rope_theta=10000.0,
    block_pattern=("dec_attn_cross",),
    enc_layers=12, frontend_tokens=1500,
)
