"""Architecture registry + reduced smoke-test variants.

``get_config(arch_id)`` returns the exact published configuration;
``smoke_config(arch_id)`` returns a reduced config of the same family
(small width, few layers/experts, tiny vocab) for CPU smoke tests — the
full configs are exercised only through the dry-run (ShapeDtypeStruct, no
allocation)."""

from __future__ import annotations

import dataclasses

from repro.configs import (codeqwen15_7b, deepseek_moe_16b, granite3_2b,
                           llama3_8b, llama32_vision_90b, mamba2_130m,
                           mixtral_8x22b, qwen25_14b, recurrentgemma_2b,
                           whisper_small)
from repro.configs.base import ModelConfig

ARCHS = {
    "llama3-8b": llama3_8b.CONFIG,
    "codeqwen1.5-7b": codeqwen15_7b.CONFIG,
    "qwen2.5-14b": qwen25_14b.CONFIG,
    "granite-3-2b": granite3_2b.CONFIG,
    "mixtral-8x22b": mixtral_8x22b.CONFIG,
    "deepseek-moe-16b": deepseek_moe_16b.CONFIG,
    "mamba2-130m": mamba2_130m.CONFIG,
    "llama-3.2-vision-90b": llama32_vision_90b.CONFIG,
    "whisper-small": whisper_small.CONFIG,
    "recurrentgemma-2b": recurrentgemma_2b.CONFIG,
}

# archs with a sub-quadratic long-context path: long_500k runs for these
LONG_CONTEXT_ARCHS = {"mixtral-8x22b", "mamba2-130m", "recurrentgemma-2b"}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    cfg = ARCHS[arch]
    cfg.validate()
    return cfg


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: one or two super-blocks, small dims."""
    cfg = get_config(arch)
    per = len(cfg.block_pattern)
    repl = dict(
        name=cfg.name + "-smoke",
        n_layers=per + len(cfg.extra_blocks),
        d_model=64,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.n_heads else 1,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        q_block=32, kv_block=32,
        remat=False,
    )
    if cfg.n_experts:
        # capacity_factor = E guarantees zero token drops, so the smoke
        # prefill/decode consistency check is exact (capacity dropping is a
        # train-time approximation, not a correctness bug)
        repl.update(n_experts=4, top_k=2,
                    moe_d_ff=64 if cfg.moe_d_ff else 0,
                    n_shared_experts=min(cfg.n_shared_experts, 1),
                    capacity_factor=4.0)
    if cfg.ssm_heads:
        repl.update(ssm_heads=4, ssm_head_dim=16, ssm_state=16, ssd_chunk=16)
    if cfg.rglru_width:
        repl.update(rglru_width=64)
    if cfg.enc_layers:
        repl.update(enc_layers=1)
    if cfg.frontend_tokens:
        repl.update(frontend_tokens=24)
    if cfg.window:
        repl.update(window=16)
    if cfg.local_window:
        repl.update(local_window=16)
    out = dataclasses.replace(cfg, **repl)
    out.validate()
    return out
