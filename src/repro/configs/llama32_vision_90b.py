"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer
[hf:meta-llama/Llama-3.2-90B-Vision].  The vision tower is a STUB per the
brief: input_specs() provides precomputed patch embeddings [B, 1600, d]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=28672, vocab=128256, head_dim=128,
    rope_theta=500000.0,
    block_pattern=("attn", "attn", "attn", "attn", "cross"),
    frontend_tokens=1600,
)
