"""mixtral-8x22b [moe] — 8 experts top-2, SWA [arXiv:2401.04088].

Sliding-window attention bounds the KV working set, so the 500k-decode
shape cell RUNS for this arch (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=32768, head_dim=128,
    rope_theta=1000000.0, block_pattern=("moe",),
    n_experts=8, top_k=2, window=4096,
)
