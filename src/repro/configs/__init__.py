from repro.configs.base import SHAPES, SMOKE_SHAPE, ModelConfig, ShapeConfig
from repro.configs.registry import ARCHS, get_config, smoke_config

__all__ = ["SHAPES", "SMOKE_SHAPE", "ModelConfig", "ShapeConfig", "ARCHS",
           "get_config", "smoke_config"]
