"""Model / run configuration schema.

Every assigned architecture is expressed as a ModelConfig with a
``block_pattern``: the repeating sequence of block kinds scanned over by the
transformer assembly (models/transformer.py).  Kinds:

    attn            global causal self-attention + SwiGLU MLP
    attn_swa        sliding-window self-attention + MLP (Mixtral)
    attn_local      local self-attention + MLP (RecurrentGemma, window)
    moe             self-attention + MoE FFN
    ssd             Mamba-2 SSD block (attention-free, no separate MLP)
    rglru           RG-LRU recurrent block + MLP
    cross           cross-attention (vision/encoder states) + MLP
    enc_attn        bidirectional self-attention + MLP (encoders)
    dec_attn_cross  decoder self-attn + cross-attn + MLP (Whisper decoder)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|vlm|audio|hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 500000.0
    norm: str = "rms"                 # rms | ln
    tie_embeddings: bool = False

    # block pattern
    block_pattern: Tuple[str, ...] = ("attn",)
    extra_blocks: Tuple[str, ...] = ()   # appended after the scanned stack
    window: int = 0                    # SWA window for attn_swa
    local_window: int = 0              # window for attn_local

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0                  # per-expert hidden (fine-grained MoE)
    capacity_factor: float = 1.25
    # "pjit": capacity-scatter dispatch partitioned by XLA SPMD (simple but
    # partitioner-limited, see EXPERIMENTS.md §Roofline); "shard_map":
    # explicit local-dispatch + all_to_all expert parallelism (requires
    # n_experts % model-axis == 0)
    moe_impl: str = "pjit"

    # SSM (Mamba-2)
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_state: int = 0
    conv_kernel: int = 4
    ssd_chunk: int = 256

    # RG-LRU
    rglru_width: int = 0

    # encoder-decoder (Whisper): n_layers = decoder layers
    enc_layers: int = 0

    # modality frontend stub (audio frames / vision patches): number of
    # frontend embedding tokens fed by input_specs()
    frontend_tokens: int = 0

    # compute
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    q_block: int = 512
    kv_block: int = 1024
    remat: bool = True
    # remat policy: "full" rematerializes everything; "dots" saves matmul
    # outputs (jax dots_saveable) trading HBM for ~25% less recompute
    remat_policy: str = "full"
    # unroll every lax.scan (layers, attention blocks, SSD chunks).  Used by
    # the dry-run cost probes: XLA cost_analysis counts a while-loop body
    # ONCE regardless of trip count, so loops must be unrolled for honest
    # FLOP/byte/collective accounting (launch/dryrun.py).
    unroll: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // max(self.n_heads, 1))

    @property
    def n_pattern_blocks(self) -> int:
        per = len(self.block_pattern)
        return (self.n_layers - len(self.extra_blocks)) // per

    def validate(self):
        per = len(self.block_pattern)
        assert (self.n_layers - len(self.extra_blocks)) % per == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by " \
            f"pattern {self.block_pattern} + extras {self.extra_blocks}"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}

# smoke-test shapes (reduced, CPU-friendly)
SMOKE_SHAPE = ShapeConfig("smoke", "train", 64, 2)
