"""repro: Terra (imperative-symbolic co-execution) as a multi-pod JAX framework."""

__version__ = "0.1.0"
