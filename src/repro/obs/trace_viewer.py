"""Chrome/Perfetto trace-event export of the co-execution timeline (§15).

``chrome_trace(events)`` renders a list of typed events (live objects or
``schema.load_jsonl`` output) as trace-event JSON — the format both
``chrome://tracing`` and https://ui.perfetto.dev load directly.  Track
layout makes the paper's overlap claim *visible*:

* process 1 ``terra-engine`` — one lane per runtime actor: the
  imperative Python thread (iteration spans), walker validation
  (divergence → rollback → replay instants, linked by flow arrows),
  GraphRunner execution (per-seq closure spans, from RunnerComplete),
  device execution (sampled SegmentProfile spans, host-dispatch split in
  ``args``), and the serving scheduler's step loop.
* process 2 ``requests`` — one lane per request id; the admit → retire
  span with per-token instants, and flow arrows chaining
  submit → admit → prefill → first token → retire.

:class:`TraceViewerExporter` is the live-processor wrapper: one list
append per event (the same discipline as ``JsonlSink``; this is what the
bench's ≥0.98× profiling-overhead gate measures), rendering deferred to
``export()``/``close()``.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.core.events import types as T
from repro.core.events.processors import Processor

PID_ENGINE, PID_REQ = 1, 2
TID_PY, TID_WALKER, TID_RUNNER, TID_DEVICE, TID_SCHED = 1, 2, 3, 4, 5
_TID_NAMES = {TID_PY: "python (imperative)", TID_WALKER: "walker",
              TID_RUNNER: "graph-runner", TID_DEVICE: "device (sampled)",
              TID_SCHED: "scheduler"}


def _meta(pid: int, tid: int, name: str, what: str = "thread_name") -> Dict:
    return {"ph": "M", "pid": pid, "tid": tid, "name": what,
            "args": {"name": name}}


def _x(name, pid, tid, ts, dur, args=None) -> Dict:
    e = {"ph": "X", "name": name, "pid": pid, "tid": tid,
         "ts": ts, "dur": max(dur, 0.0), "cat": "terra"}
    if args:
        e["args"] = args
    return e


def _i(name, pid, tid, ts, args=None) -> Dict:
    e = {"ph": "i", "name": name, "pid": pid, "tid": tid, "ts": ts,
         "s": "t", "cat": "terra"}
    if args:
        e["args"] = args
    return e


def _flow(ph, fid, name, pid, tid, ts) -> Dict:
    e = {"ph": ph, "id": fid, "name": name, "cat": "flow",
         "pid": pid, "tid": tid, "ts": ts}
    if ph == "f":
        e["bp"] = "e"               # bind to the enclosing slice
    return e


def chrome_trace(events: List[Any]) -> Dict[str, Any]:
    """Build the trace-event JSON dict for a list of typed events."""
    stamped = [e for e in events if e.ts is not None]
    t0 = min((e.ts for e in stamped), default=0.0)

    def us(ts: float) -> float:
        return (ts - t0) * 1e6

    out: List[Dict] = [_meta(PID_ENGINE, 0, "terra-engine", "process_name"),
                       _meta(PID_REQ, 0, "requests", "process_name")]
    out.extend(_meta(PID_ENGINE, tid, name)
               for tid, name in _TID_NAMES.items())

    iter_open: Dict[int, Any] = {}        # iter_id -> IterationStart
    req_admit: Dict[int, Any] = {}        # rid -> RequestAdmit
    seen_rids: List[int] = []
    for e in stamped:
        ts = us(e.ts)
        k = type(e)
        if k is T.IterationStart:
            iter_open[e.iter_id] = e
        elif k is T.IterationEnd:
            s = iter_open.pop(e.iter_id, None)
            if s is not None:
                out.append(_x(f"iter {e.iter_id} [{e.mode}]", PID_ENGINE,
                              TID_PY, us(s.ts), ts - us(s.ts),
                              {"ops_validated": e.ops_validated,
                               "fast_hits": e.fast_hits,
                               "family": s.family}))
        elif k is T.SegmentDispatch:
            out.append(_i(f"dispatch {e.kind}[{e.index}]", PID_ENGINE,
                          TID_PY, ts, {"seq": e.seq, "iter": e.iter_id,
                                       "feeds": e.feeds}))
        elif k is T.RunnerComplete:
            out.append(_x(f"seq {e.seq}", PID_ENGINE, TID_RUNNER,
                          ts - e.wall * 1e6, e.wall * 1e6,
                          {"stall_us": round(e.stall * 1e6, 1)}))
        elif k is T.SegmentProfile:
            out.append(_x(f"{e.kind}[{e.index}] device", PID_ENGINE,
                          TID_DEVICE, ts - e.device * 1e6, e.device * 1e6,
                          {"iter": e.iter_id,
                           "dispatch_us": round(e.dispatch * 1e6, 1),
                           "kernels": list(e.kernels)}))
        elif k is T.Divergence:
            fid = f"div:{e.iter_id}"
            out.append(_i(f"divergence {e.iter_id}", PID_ENGINE, TID_WALKER,
                          ts, {"reason": e.reason}))
            out.append(_flow("s", fid, "recovery", PID_ENGINE, TID_WALKER,
                             ts))
        elif k is T.Rollback:
            out.append(_i(f"rollback {e.iter_id}", PID_ENGINE, TID_WALKER,
                          ts, {"vars_restored": e.vars_restored}))
            out.append(_flow("t", f"div:{e.iter_id}", "recovery",
                             PID_ENGINE, TID_WALKER, ts))
        elif k is T.Replay:
            out.append(_i(f"replay {e.iter_id}", PID_ENGINE, TID_WALKER,
                          ts, {"entries": e.entries}))
            out.append(_flow("f", f"div:{e.iter_id}", "recovery",
                             PID_ENGINE, TID_WALKER, ts))
        elif k in (T.SteadyEnter, T.SteadyExit, T.SteadyProbe,
                   T.SteadyPoison, T.Transition, T.FamilySwitch,
                   T.ForkObserved):
            out.append(_i(k.__name__, PID_ENGINE, TID_WALKER, ts))
        elif k is T.StepDispatch:
            out.append(_x(f"{e.kind} step", PID_ENGINE, TID_SCHED,
                          ts - e.dur * 1e6, e.dur * 1e6,
                          {"rows": e.rows, "queue_depth": e.queue_depth,
                           "resident": e.resident}))
        elif k is T.StepHarvest:
            out.append(_x(f"{e.kind} harvest", PID_ENGINE, TID_SCHED,
                          ts - e.wait * 1e6, e.wait * 1e6))
        elif k is T.SchedulerIdle:
            out.append(_x("idle", PID_ENGINE, TID_SCHED, ts,
                          e.wait * 1e6))
        elif k is T.RequestSubmit:
            seen_rids.append(e.rid)
            out.append(_i(f"submit r{e.rid}", PID_ENGINE, TID_SCHED, ts,
                          {"prompt_len": e.prompt_len,
                           "max_new": e.max_new}))
            out.append(_flow("s", f"req:{e.rid}", "lifecycle",
                             PID_ENGINE, TID_SCHED, ts))
        elif k is T.RequestAdmit:
            req_admit[e.rid] = e
            out.append(_i(f"admit r{e.rid}", PID_REQ, e.rid, ts,
                          {"slot": e.slot,
                           "queued_ms": round(e.queued_s * 1e3, 3)}))
            out.append(_flow("t", f"req:{e.rid}", "lifecycle",
                             PID_REQ, e.rid, ts))
        elif k is T.RequestPrefill:
            out.append(_i(f"prefill r{e.rid}", PID_REQ, e.rid, ts,
                          {"bucket": e.bucket, "prompt_len": e.prompt_len}))
            out.append(_flow("t", f"req:{e.rid}", "lifecycle",
                             PID_REQ, e.rid, ts))
        elif k is T.RequestToken:
            out.append(_i(f"token[{e.index}]", PID_REQ, e.rid, ts))
            if e.index == 0:
                out.append(_flow("t", f"req:{e.rid}", "lifecycle",
                                 PID_REQ, e.rid, ts))
        elif k is T.RequestRetire:
            a = req_admit.pop(e.rid, None)
            if a is not None:
                out.append(_x(f"r{e.rid} [{e.reason}]", PID_REQ, e.rid,
                              us(a.ts), ts - us(a.ts),
                              {"tokens": e.tokens}))
            out.append(_flow("f", f"req:{e.rid}", "lifecycle",
                             PID_REQ, e.rid, ts))
    out.extend(_meta(PID_REQ, rid, f"request {rid}")
               for rid in dict.fromkeys(seen_rids))
    out.sort(key=lambda d: (d.get("ts", -1.0), d["pid"], d["tid"]))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


class TraceViewerExporter(Processor):
    """Live event processor buffering the stream for timeline export.

    Per-event cost is one list append; rendering happens in ``export()``
    (or ``close()`` when a path was given), never on the emit path.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.events: List[Any] = []

    def process(self, event) -> None:
        self.events.append(event)

    def trace(self) -> Dict[str, Any]:
        return chrome_trace(self.events)

    def export(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if path is None:
            raise ValueError("no export path given")
        with open(path, "w") as f:
            json.dump(self.trace(), f)
        return path

    def close(self) -> None:
        if self.path is not None and self.events:
            self.export()
