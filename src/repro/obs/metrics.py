"""Live metrics: streaming log-bucketed histograms + registry (§15).

The serving benchmarks used to buffer every latency sample and call
``np.percentile`` after the run; a serving process cannot do that — it
needs percentiles *online*, with bounded memory, updated from the same
event stream everything else reads.  :class:`Histogram` is the standard
log-bucketed answer: values map to geometric buckets (growth factor
1.05 ⇒ any percentile is exact to within ±2.5 % relative error), stored
sparsely, so an arbitrary stream costs O(occupied buckets) memory and
one dict update per observation.  :class:`MetricsRegistry` names a set
of histograms + gauges and renders them two ways — a JSON snapshot (the
benchmarks' one formatting path for stats) and Prometheus text
exposition (scraped via :mod:`repro.obs.http`).  :class:`MetricsProcessor`
is the event-stream adapter: a handler-dict processor (same shape as
``TimingProcessor``) that folds serving/request/engine events into the
registry as they are emitted.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional

from repro.core.events import types as T
from repro.core.events.processors import Processor

GROWTH = 1.05
_LOG_G = math.log(GROWTH)


class Histogram:
    """Sparse log-bucketed streaming histogram for non-negative samples.

    Bucket ``i`` covers ``[GROWTH**i, GROWTH**(i+1))``; values ``<= 0``
    land in a dedicated underflow bucket (reported as 0.0).  Percentiles
    return the geometric midpoint of the containing bucket, so relative
    error is bounded by ``sqrt(GROWTH) - 1`` (~2.47 %) regardless of the
    distribution — the property tests/test_obs.py checks against numpy.
    """

    __slots__ = ("buckets", "count", "total", "vmin", "vmax", "zeros")

    def __init__(self):
        self.buckets: Dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.zeros = 0

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        if v <= 0.0:
            self.zeros += 1
            return
        i = int(math.floor(math.log(v) / _LOG_G))
        self.buckets[i] = self.buckets.get(i, 0) + 1

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Geometric-midpoint percentile; exact for the underflow bucket
        and clamped to the observed min/max so p0/p100 stay honest."""
        if not self.count:
            return 0.0
        rank = max(1, math.ceil(q / 100.0 * self.count))
        if rank <= self.zeros:
            return 0.0
        seen = self.zeros
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= rank:
                mid = GROWTH ** (i + 0.5)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def snapshot(self) -> Dict[str, Any]:
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p95": self.percentile(95), "p99": self.percentile(99)}

    def cumulative_buckets(self) -> List:
        """(upper_bound, cumulative_count) per occupied bucket, for
        Prometheus exposition (le-labelled, cumulative by contract)."""
        out, cum = [], self.zeros
        if self.zeros:
            out.append((0.0, cum))
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            out.append((GROWTH ** (i + 1), cum))
        return out


class MetricsRegistry:
    """Named histograms + gauges with two render paths.

    ``snapshot()`` is the JSON dict the benchmarks and the report CLI
    print; ``prometheus_text()`` is the ``text/plain; version=0.0.4``
    exposition the scrape endpoint serves.  Counter dicts (the stream's
    flat counters) can be attached and are exported as untyped gauges.
    """

    def __init__(self):
        self.histograms: Dict[str, Histogram] = {}
        self.gauges: Dict[str, float] = {}
        self.counters: Optional[Dict[str, Any]] = None

    def histogram(self, name: str) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram()
        return h

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauges[name] = v

    def attach_counters(self, counters: Dict[str, Any]) -> None:
        self.counters = counters

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self.histograms.items())},
            "gauges": dict(sorted(self.gauges.items()))}
        if self.counters is not None:
            out["counters"] = {k: v for k, v in sorted(self.counters.items())
                               if isinstance(v, (int, float))}
        return out

    def prometheus_text(self, prefix: str = "terra") -> str:
        lines: List[str] = []
        for name, h in sorted(self.histograms.items()):
            m = f"{prefix}_{name}"
            lines.append(f"# TYPE {m} histogram")
            for le, cum in h.cumulative_buckets():
                lines.append(f'{m}_bucket{{le="{le:.6g}"}} {cum}')
            lines.append(f'{m}_bucket{{le="+Inf"}} {h.count}')
            lines.append(f"{m}_sum {h.total:.9g}")
            lines.append(f"{m}_count {h.count}")
        for name, v in sorted(self.gauges.items()):
            m = f"{prefix}_{name}"
            lines.append(f"# TYPE {m} gauge")
            lines.append(f"{m} {v:.9g}")
        if self.counters is not None:
            for name, v in sorted(self.counters.items()):
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    continue
                m = f"{prefix}_{name}"
                lines.append(f"# TYPE {m} counter")
                lines.append(f"{m} {v:.9g}")
        return "\n".join(lines) + "\n"


class MetricsProcessor(Processor):
    """Event-stream adapter: folds serving events into a registry online.

    Histograms maintained (units in the name):

    * ``ttft_ms`` — RequestSubmit → first RequestToken wall per request
    * ``token_latency_ms`` — inter-token gap per request
    * ``queue_wait_ms`` — admission queueing delay (RequestAdmit)
    * ``dispatch_us`` / ``fetch_us`` — per-step scheduler host time
    * ``queue_depth`` / ``resident_tokens`` — sampled at each StepDispatch

    Gauges: last queue depth / resident tokens, steady-state occupancy
    (fraction of dispatched segments that took the zero-walker path).
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._submit_ts: Dict[int, float] = {}
        self._last_token_ts: Dict[int, float] = {}
        self._segments = 0
        self._steady_segments = 0
        self._handlers = {T.RequestSubmit: self._submit,
                          T.RequestAdmit: self._admit,
                          T.RequestToken: self._token,
                          T.RequestRetire: self._retire,
                          T.StepDispatch: self._step,
                          T.StepHarvest: self._harvest,
                          T.SegmentDispatch: self._segment,
                          T.SegmentProfile: self._profile}

    def process(self, event) -> None:
        h = self._handlers.get(type(event))
        if h is not None:
            h(event)

    # -- request lifecycle -------------------------------------------------
    def _submit(self, e) -> None:
        self._submit_ts[e.rid] = e.ts

    def _admit(self, e) -> None:
        self.registry.observe("queue_wait_ms", e.queued_s * 1e3)

    def _token(self, e) -> None:
        r = self.registry
        last = self._last_token_ts.get(e.rid)
        if last is not None:
            r.observe("token_latency_ms", (e.ts - last) * 1e3)
        elif e.rid in self._submit_ts:
            r.observe("ttft_ms", (e.ts - self._submit_ts[e.rid]) * 1e3)
        self._last_token_ts[e.rid] = e.ts

    def _retire(self, e) -> None:
        self._submit_ts.pop(e.rid, None)
        self._last_token_ts.pop(e.rid, None)

    # -- scheduler step loop ----------------------------------------------
    def _step(self, e) -> None:
        r = self.registry
        r.observe("dispatch_us", e.dur * 1e6)
        r.observe("queue_depth", float(e.queue_depth))
        r.observe("resident_tokens", float(e.resident))
        r.set_gauge("queue_depth", float(e.queue_depth))
        r.set_gauge("resident_tokens", float(e.resident))

    def _harvest(self, e) -> None:
        self.registry.observe("fetch_us", e.wait * 1e6)

    # -- engine dispatch --------------------------------------------------
    def _segment(self, e) -> None:
        self._segments += 1
        if e.kind == "steady":
            self._steady_segments += 1
        self.registry.set_gauge(
            "steady_occupancy", self._steady_segments / self._segments)

    def _profile(self, e) -> None:
        r = self.registry
        r.observe("segment_dispatch_us", e.dispatch * 1e6)
        r.observe("segment_device_us", e.device * 1e6)


def counters_table(stats: Dict[str, Any],
                   keys: Optional[List[str]] = None) -> str:
    """One formatting path for counter dicts (fig6_breakdown, report CLI):
    aligned ``name value`` rows over the numeric entries of ``stats``."""
    items = [(k, stats[k]) for k in (keys if keys is not None
                                     else sorted(stats))
             if isinstance(stats.get(k), (int, float))
             and not isinstance(stats.get(k), bool)]
    if not items:
        return "(no counters)"
    w = max(len(k) for k, _ in items)
    rows = []
    for k, v in items:
        sv = f"{v:.6f}".rstrip("0").rstrip(".") if isinstance(v, float) \
            else str(v)
        rows.append(f"  {k:<{w}}  {sv}")
    return "\n".join(rows)
