"""Stdlib-only metrics scrape endpoint (DESIGN.md §15).

Optional: serving works fully without it.  :class:`MetricsServer` wraps a
``ThreadingHTTPServer`` on a daemon thread exposing a
:class:`~repro.obs.metrics.MetricsRegistry`:

* ``GET /metrics``       — Prometheus text exposition (version 0.0.4)
* ``GET /metrics.json``  — the JSON snapshot (same dict the benches print)

``port=0`` binds an ephemeral port (tests); ``server.port`` reports the
bound port either way.  Rendering happens in the request handler thread —
the serving loop never blocks on a scrape.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from repro.obs.metrics import MetricsRegistry

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class MetricsServer:
    """Serve one registry's metrics over HTTP until ``stop()``."""

    def __init__(self, registry: MetricsRegistry, host: str = "127.0.0.1",
                 port: int = 0, prefix: str = "terra"):
        self.registry = registry
        self.prefix = prefix
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                if self.path.split("?")[0] == "/metrics":
                    body = server.registry.prometheus_text(
                        server.prefix).encode()
                    ctype = PROM_CONTENT_TYPE
                elif self.path.split("?")[0] == "/metrics.json":
                    body = json.dumps(server.registry.snapshot()).encode()
                    ctype = "application/json"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                 # quiet by default
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="terra-metrics-http",
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
