"""Offline trace analysis CLI (DESIGN.md §15).

``python -m repro.obs.report trace.jsonl`` loads a JSONL event stream
(the ``JsonlSink`` artifact the benches export), validates it against the
schema, and prints:

* the per-segment host/device time table — ``SegmentDispatch`` joined to
  ``RunnerComplete`` on ``seq`` (host closure wall) and to the sampled
  ``SegmentProfile`` events on ``(iter_id, kind, index)`` (dispatch vs
  device split, per-kernel attribution),
* the divergence → rollback → replay audit,
* per-family fork selector distributions (``ForkObserved``),
* the serving metrics snapshot (the same ``MetricsRegistry`` the live
  scheduler uses, replayed over the stream),

and writes the Chrome/Perfetto export next to the input
(``<input>.trace.json`` unless ``--out`` says otherwise).
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List

from repro.core.events import types as T
from repro.core.events.schema import load_jsonl
from repro.obs.metrics import MetricsProcessor, counters_table
from repro.obs.trace_viewer import chrome_trace


def _fmt_us(s: float) -> str:
    return f"{s * 1e6:10.1f}"


def segment_table(events: List[Any]) -> str:
    """Aggregate per-(kind, index) segment rows: dispatch count, mean host
    closure wall (all iterations, via RunnerComplete), and — where sampled
    — mean host-dispatch and device time from SegmentProfile."""
    complete = {e.seq: e for e in events if type(e) is T.RunnerComplete}
    rows: Dict[tuple, Dict[str, Any]] = {}
    for e in events:
        if type(e) is T.SegmentDispatch:
            r = rows.setdefault((e.kind, e.index),
                                {"n": 0, "wall": 0.0, "walls": 0,
                                 "disp": 0.0, "dev": 0.0, "prof": 0,
                                 "kernels": ()})
            r["n"] += 1
            c = complete.get(e.seq)
            if c is not None:
                r["wall"] += c.wall
                r["walls"] += 1
        elif type(e) is T.SegmentProfile:
            r = rows.setdefault((e.kind, e.index),
                                {"n": 0, "wall": 0.0, "walls": 0,
                                 "disp": 0.0, "dev": 0.0, "prof": 0,
                                 "kernels": ()})
            r["disp"] += e.dispatch
            r["dev"] += e.device
            r["prof"] += 1
            if e.kernels:
                r["kernels"] = tuple(e.kernels)
    if not rows:
        return "(no segment dispatches in trace)"
    lines = [f"{'segment':<14}{'count':>7}{'host µs':>11}{'disp µs':>11}"
             f"{'device µs':>11}{'sampled':>9}  kernels"]
    for (kind, idx), r in sorted(rows.items()):
        wall = _fmt_us(r["wall"] / r["walls"]) if r["walls"] else " " * 10
        disp = _fmt_us(r["disp"] / r["prof"]) if r["prof"] else " " * 10
        dev = _fmt_us(r["dev"] / r["prof"]) if r["prof"] else " " * 10
        lines.append(f"{kind + '[' + str(idx) + ']':<14}{r['n']:>7}"
                     f"{wall:>11}{disp:>11}{dev:>11}{r['prof']:>9}  "
                     f"{','.join(r['kernels']) or '-'}")
    return "\n".join(lines)


def divergence_audit(events: List[Any]) -> str:
    """The recovery chains: every Divergence with its Rollback/Replay/
    Retrace events (joined on iter_id), plus steady-state transitions."""
    by_iter: Dict[int, List[str]] = {}
    for e in events:
        k = type(e)
        if k is T.Divergence:
            by_iter.setdefault(e.iter_id, []).append(
                f"divergence ({e.reason})")
        elif k is T.Rollback:
            by_iter.setdefault(e.iter_id, []).append(
                f"rollback ({e.vars_restored} vars)")
        elif k is T.Replay:
            by_iter.setdefault(e.iter_id, []).append(
                f"replay ({e.entries} entries)")
        elif k is T.Retrace:
            by_iter.setdefault(e.iter_id, []).append(
                f"retrace ({e.reason or 'trace'})")
    steady = sum(1 for e in events if type(e) is T.SteadyEnter)
    exits = sum(1 for e in events if type(e) is T.SteadyExit)
    probes = sum(1 for e in events if type(e) is T.SteadyProbe)
    lines = []
    if not by_iter:
        lines.append("  no divergences")
    for iter_id in sorted(by_iter):
        lines.append(f"  iter {iter_id}: " + " -> ".join(by_iter[iter_id]))
    lines.append(f"  steady-state: {steady} entries, {exits} exits, "
                 f"{probes} probes")
    return "\n".join(lines)


def fork_distribution(events: List[Any]) -> str:
    """Per-family selector distributions from ForkObserved events."""
    dist: Dict[tuple, Dict[int, int]] = {}
    for e in events:
        if type(e) is T.ForkObserved:
            d = dist.setdefault((e.family, e.fork), {})
            d[e.case] = d.get(e.case, 0) + 1
    if not dist:
        return "  no fork observations"
    lines = []
    for (fam, fork), cases in sorted(dist.items()):
        total = sum(cases.values())
        shares = ", ".join(f"case {c}: {n} ({n / total:.0%})"
                           for c, n in sorted(cases.items()))
        lines.append(f"  family {fam} fork {fork}: {shares}")
    return "\n".join(lines)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Analyze a Terra event-stream JSONL trace and export "
                    "a Chrome/Perfetto timeline.")
    p.add_argument("trace", help="trace.jsonl written by JsonlSink")
    p.add_argument("--out", default=None,
                   help="Perfetto JSON path (default: <trace>.trace.json)")
    p.add_argument("--no-metrics", action="store_true",
                   help="skip the metrics snapshot section")
    args = p.parse_args(argv)

    events = load_jsonl(args.trace)
    print(f"{args.trace}: {len(events)} events, "
          f"{len({type(e).__name__ for e in events})} types")

    print("\n== per-segment host/device time ==")
    print(segment_table(events))
    print("\n== divergence/replay audit ==")
    print(divergence_audit(events))
    print("\n== fork selector distribution ==")
    print(fork_distribution(events))

    if not args.no_metrics:
        mp = MetricsProcessor()
        for e in events:
            mp.process(e)
        snap = mp.registry.snapshot()
        if snap["histograms"]:
            print("\n== serving metrics ==")
            for name, h in snap["histograms"].items():
                print(f"  {name}: n={h['count']} mean={h['mean']:.3f} "
                      f"p50={h['p50']:.3f} p95={h['p95']:.3f} "
                      f"p99={h['p99']:.3f}")
        if snap["gauges"]:
            print(counters_table(snap["gauges"]))

    out = args.out or (args.trace + ".trace.json")
    trace = chrome_trace(events)
    with open(out, "w") as f:
        json.dump(trace, f)
    print(f"\nwrote {out} ({len(trace['traceEvents'])} trace events) — "
          f"load in ui.perfetto.dev or chrome://tracing")
    return 0


if __name__ == "__main__":
    sys.exit(main())
