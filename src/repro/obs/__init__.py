"""Observability layer: profiling, timeline export, live serving metrics
(DESIGN.md §15).

Built entirely on top of ``core/events/`` — nothing here touches the
executor hot path.  The executor's sampled device-time attribution
(``terra.function(profile=N)``) emits ``SegmentProfile`` events through
the same stream every other structured event uses; this package consumes
them:

* :mod:`repro.obs.metrics` — streaming log-bucketed histograms and the
  :class:`MetricsRegistry` (Prometheus text exposition + JSON snapshot),
  updated online by :class:`MetricsProcessor` from serving events.
* :mod:`repro.obs.trace_viewer` — :class:`TraceViewerExporter`, a
  processor that renders the event stream as Chrome/Perfetto trace-event
  JSON: engine tracks (imperative Python, walker, GraphRunner, device,
  scheduler) plus per-request lanes with flow events linking each
  request's lifecycle and each divergence's recovery chain.
* :mod:`repro.obs.report` — the ``python -m repro.obs.report`` CLI:
  per-segment host/device tables, the divergence/replay audit, selector
  distributions, a metrics snapshot, and the ``.trace.json`` export.
* :mod:`repro.obs.http` — stdlib-only optional HTTP scrape endpoint
  serving ``/metrics`` (Prometheus text) and ``/metrics.json``.
"""

from repro.obs.metrics import (GROWTH, Histogram, MetricsProcessor,
                               MetricsRegistry, counters_table)
from repro.obs.trace_viewer import TraceViewerExporter, chrome_trace

__all__ = ["GROWTH", "Histogram", "MetricsRegistry", "MetricsProcessor",
           "counters_table", "TraceViewerExporter", "chrome_trace"]
