"""Deterministic synthetic data pipeline with background prefetch.

Real deployments swap ``SyntheticLMDataset`` for a tokenized corpus reader;
the pipeline contract (shard-aware, deterministic per (seed, step, shard),
prefetching iterator) is what the trainer and the fault-tolerance story
depend on: after a restart, ``seek(step)`` resumes the exact stream."""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLMDataset:
    """Zipf-distributed token stream, deterministic per (seed, step, shard)."""

    def __init__(self, vocab: int, seq_len: int, batch: int, seed: int = 0,
                 shard: int = 0, n_shards: int = 1, extras: Optional[dict] = None):
        self.vocab, self.seq_len, self.batch = vocab, seq_len, batch
        self.seed, self.shard, self.n_shards = seed, shard, n_shards
        self.extras = extras or {}
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._p = (p / p.sum()).astype(np.float64)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + self.shard) % (2 ** 31))
        tokens = rng.choice(self.vocab, size=(self.batch, self.seq_len + 1),
                            p=self._p).astype(np.int32)
        out = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
        for k, spec in self.extras.items():
            out[k] = rng.randn(self.batch, *spec["shape"]).astype(
                spec.get("dtype", np.float32))
        return out


class PrefetchIterator:
    """Background-thread prefetch (depth-N) over a step-indexed dataset.

    ``seek(step)`` makes the stream resumable after checkpoint restart —
    part of the fault-tolerance contract."""

    def __init__(self, dataset, start_step: int = 0, depth: int = 2):
        self.dataset = dataset
        self.depth = depth
        self._step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._start_worker()

    def _start_worker(self):
        self._stop.clear()

        def work(first_step):
            s = first_step
            while not self._stop.is_set():
                b = self.dataset.batch_at(s)
                while not self._stop.is_set():
                    try:
                        self._q.put((s, b), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                s += 1

        self._thread = threading.Thread(target=work, args=(self._step,),
                                        daemon=True, name="data-prefetch")
        self._thread.start()

    def seek(self, step: int):
        self._stop.set()
        self._thread.join()
        self._q = queue.Queue(maxsize=self.depth)
        self._step = step
        self._start_worker()

    def __next__(self):
        s, b = self._q.get()
        self._step = s + 1
        return b

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        if self._thread is not None:
            self._thread.join(timeout=2.0)
