"""Sharded, atomic, async checkpointing with elastic restore.

Layout:
    <dir>/step_<N>/manifest.json       step, keys, shapes, dtypes
    <dir>/step_<N>/arrays.npz          flattened pytree (path -> array)
    <dir>/latest                       text file naming the committed step

Commit protocol: write into ``step_<N>.tmp`` then ``os.rename`` (atomic on
POSIX) and update ``latest`` — a crash mid-save never corrupts the previous
checkpoint (fault-tolerance requirement).

Elastic restore: ``restore(..., shardings=...)`` device_puts every leaf with
the *current* mesh's NamedSharding, so a run checkpointed on one mesh
resumes on a different device count (reshard-on-load)."""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np
import jax

SEP = "|"
_COMMIT_LOCK = threading.Lock()   # serializes the atomic swap


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, blocking: bool = True):
    """Checkpoint ``tree`` at ``step``.  With blocking=False the disk write
    happens on a background thread (async checkpointing) after the host
    copy has been snapshotted."""
    flat = _flatten(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}   # device->host snapshot
    # npz cannot store ml_dtypes (bfloat16 &c.) — bit-cast and record dtype
    true_dtypes = {k: str(v.dtype) for k, v in host.items()}
    host = {k: (v.view(np.uint16) if str(v.dtype) == "bfloat16" else v)
            for k, v in host.items()}

    def commit():
        # unique tmp dir: concurrent async+blocking saves of the same step
        # must not collide (the rename is still the atomic commit point)
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp.{os.getpid()}."
                                     f"{threading.get_ident()}")
        final = os.path.join(ckpt_dir, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": sorted(host.keys()),
            "shapes": {k: list(v.shape) for k, v in host.items()},
            "dtypes": true_dtypes,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with _COMMIT_LOCK:
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            lat = os.path.join(ckpt_dir, f"latest.tmp.{threading.get_ident()}")
            with open(lat, "w") as f:
                f.write(str(step))
            os.replace(lat, os.path.join(ckpt_dir, "latest"))

    os.makedirs(ckpt_dir, exist_ok=True)
    if blocking:
        commit()
        return None
    t = threading.Thread(target=commit, daemon=True, name="ckpt-save")
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: int, template, *, shardings=None):
    """Restore into the structure of ``template``.  ``shardings``: optional
    matching pytree (or single sharding) applied via device_put — this is
    the elastic reshard-on-load path."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(final, "arrays.npz")) as z:
        host = {k: z[k] for k in z.files}
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes
    for k, dt in manifest["dtypes"].items():
        if dt == "bfloat16" and host[k].dtype == np.uint16:
            host[k] = host[k].view(ml_dtypes.bfloat16)
    flat_keys = list(_flatten(template).keys())
    missing = [k for k in flat_keys if k not in host]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keyed = _flatten(template)
    new_leaves = []
    shard_flat = (_flatten(shardings) if shardings is not None
                  and not hasattr(shardings, "device_set") else None)
    for key, tmpl in keyed.items():
        arr = host[key].astype(tmpl.dtype)
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[key])
        elif shardings is not None:
            arr = jax.device_put(arr, shardings)
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
