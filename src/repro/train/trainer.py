"""The imperative training driver, executed through Terra co-execution.

This is the paper's technique integrated as a first-class framework
feature: the user-visible training loop is ordinary imperative Python
(logging, checkpointing, adaptive hyper-parameters, third-party calls all
work), while the heavy ``train_step`` — a single composite Terra op wrapping
the pjit-ready step function — runs on the GraphRunner asynchronously.
Python-side overhead (data staging, bookkeeping, checkpoint scheduling) is
hidden behind device execution exactly as in the paper's Fig. 6.

Fault tolerance:
  * periodic checkpoints (async commit, atomic rename) + auto-resume,
  * a step watchdog flags stragglers (slow steps) and records them — the
    mitigation hook for a real cluster scheduler,
  * the data pipeline reseeks deterministically on restart.
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional

import numpy as np
import jax

from repro.configs.base import ModelConfig
from repro.core import Variable, function as terra_function, ops as terra_ops
from repro.core.ops import def_op
from repro.models import model as M
from repro.parallel.sharding import ShardingPolicy, use_policy
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt
from repro.train.train_step import build_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: Optional[opt.OptConfig] = None,
                 *, ckpt_dir: Optional[str] = None, seed: int = 0,
                 batch: int = 8, seq_len: int = 128, microbatches: int = 1,
                 mesh=None, log_every: int = 10, ckpt_every: int = 100,
                 straggler_factor: float = 3.0, use_terra: bool = True):
        self.cfg = cfg
        self.opt_cfg = opt_cfg or opt.OptConfig()
        self.ckpt_dir = ckpt_dir
        self.batch, self.seq_len = batch, seq_len
        self.log_every, self.ckpt_every = log_every, ckpt_every
        self.straggler_factor = straggler_factor
        self.mesh = mesh
        self.policy = ShardingPolicy(mesh)
        self.use_terra = use_terra
        self.history: list = []
        self.straggler_events: list = []

        key = jax.random.PRNGKey(seed)
        params = M.init_params(cfg, key)
        opt_state = opt.init(params)
        self.start_step = 0
        if ckpt_dir is not None:
            last = ckpt.latest_step(ckpt_dir)
            if last is not None:
                # auto-resume: params+opt are stored together as one tree
                tree = ckpt.restore(ckpt_dir, last,
                                    {"params": params, "opt": opt_state})
                params, opt_state = tree["params"], tree["opt"]
                self.start_step = last

        # flatten state into Terra Variables (graph-resident)
        self._p_leaves, self._p_def = jax.tree_util.tree_flatten(params)
        self._o_leaves, self._o_def = jax.tree_util.tree_flatten(opt_state)
        self.p_vars = [Variable(x, f"p{i}") for i, x in
                       enumerate(self._p_leaves)]
        self.o_vars = [Variable(x, f"o{i}") for i, x in
                       enumerate(self._o_leaves)]

        step_fn = build_train_step(cfg, self.opt_cfg,
                                   microbatches=microbatches)
        n_p, n_o = len(self._p_leaves), len(self._o_leaves)
        p_def, o_def = self._p_def, self._o_def

        def flat_step(*args):
            p = jax.tree_util.tree_unflatten(p_def, args[:n_p])
            o = jax.tree_util.tree_unflatten(o_def, args[n_p:n_p + n_o])
            tokens, labels = args[n_p + n_o], args[n_p + n_o + 1]
            new_p, new_o, metrics = step_fn(p, o, {"tokens": tokens,
                                                   "labels": labels})
            return (tuple(jax.tree.leaves(new_p))
                    + tuple(jax.tree.leaves(new_o))
                    + (metrics["loss"], metrics["grad_norm"]))

        self._flat_step_op = def_op(f"train_step::{cfg.name}", flat_step)
        self.dataset = data_mod.SyntheticLMDataset(
            cfg.vocab, seq_len, batch, seed=seed)

        def train_iteration(tokens, labels):
            args = ([v.read() for v in self.p_vars]
                    + [v.read() for v in self.o_vars]
                    + [tokens, labels])
            outs = self._flat_step_op(*args)
            for v, o in zip(self.p_vars, outs[:n_p]):
                v.assign(o)
            for v, o in zip(self.o_vars, outs[n_p:n_p + n_o]):
                v.assign(o)
            return outs[-2], outs[-1]          # loss, grad_norm

        if use_terra:
            self._iteration = terra_function(train_iteration, seed=seed)
        else:
            self._iteration = train_iteration     # plain eager-via-jit path

    # ------------------------------------------------------------------
    def state_tree(self):
        params = jax.tree_util.tree_unflatten(
            self._p_def, [v.value() for v in self.p_vars])
        ostate = jax.tree_util.tree_unflatten(
            self._o_def, [v.value() for v in self.o_vars])
        return {"params": params, "opt": ostate}

    # ------------------------------------------------------------------
    def train(self, num_steps: int, verbose: bool = True):
        it = data_mod.PrefetchIterator(self.dataset,
                                       start_step=self.start_step)
        step_times: list = []
        ctx = use_policy(self.policy)
        ctx.__enter__()
        mesh_ctx = self.mesh if self.mesh is not None else None
        if mesh_ctx is not None:
            mesh_ctx.__enter__()
        try:
            for step in range(self.start_step, self.start_step + num_steps):
                batch = next(it)
                t0 = time.perf_counter()
                loss_t, gnorm_t = self._iteration(batch["tokens"],
                                                  batch["labels"])
                dt = time.perf_counter() - t0
                step_times.append(dt)
                # straggler watchdog (mitigation hook)
                med = float(np.median(step_times[-50:]))
                if len(step_times) > 10 and dt > self.straggler_factor * med:
                    self.straggler_events.append((step, dt, med))
                if (step + 1) % self.log_every == 0:
                    loss = float(loss_t)           # Output Fetching
                    self.history.append((step + 1, loss))
                    if verbose:
                        phase = (self._iteration.phase
                                 if self.use_terra else "eager")
                        print(f"step {step + 1:5d} loss {loss:.4f} "
                              f"[{phase}] {dt * 1e3:.1f}ms")
                if (self.ckpt_dir is not None
                        and (step + 1) % self.ckpt_every == 0):
                    ckpt.save(self.ckpt_dir, step + 1, self.state_tree(),
                              blocking=False)
        finally:
            if mesh_ctx is not None:
                mesh_ctx.__exit__(None, None, None)
            ctx.__exit__(None, None, None)
            it.close()
        if self.ckpt_dir is not None:
            ckpt.save(self.ckpt_dir, self.start_step + num_steps,
                      self.state_tree(), blocking=True)
        return self.history
