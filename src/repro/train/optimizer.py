"""AdamW + schedules + clipping, pure JAX (no optax dependency).

Mixed precision: when model params are bf16, the optimizer keeps f32 master
copies and casts back after the update (2+4+4+4 bytes/param total with the
two moments — the memory figure used in the roofline/memory analysis)."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    """Linear warmup + cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def init(params) -> dict:
    def zeros_f32(p):
        return jnp.zeros(p.shape, jnp.float32)
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_f32, params),
        "v": jax.tree.map(zeros_f32, params),
        "master": master,
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply(cfg: OptConfig, state: dict, grads, params) -> Tuple[Any, dict, dict]:
    """One AdamW update.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                                    + cfg.weight_decay * master)
        return m, v, new_master

    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    treedef = jax.tree.structure(grads)
    out = [upd(g, m, v, ma) for g, m, v, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda ma, p: ma.astype(p.dtype),
                              new_master, params)
    new_state = {"step": step, "m": new_m, "v": new_v, "master": new_master}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
