"""Train-step builder: loss, microbatched gradient accumulation, remat,
mixed precision, and the pjit shardings for the production mesh.

``build_train_step(cfg, opt_cfg, microbatches=k)`` returns a pure function
    step(params, opt_state, batch) -> (params, opt_state, metrics)
suitable for jax.jit with in/out shardings (launch/dryrun.py) or for
registration as a single Terra composite op (train/trainer.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.parallel.sharding import logical
from repro.train import optimizer as opt


def lm_loss(cfg: ModelConfig, params, tokens, labels, *, extras=None,
            z_loss: float = 1e-4):
    """Next-token cross-entropy with z-loss, in f32.

    The label logit is extracted with a one-hot contraction rather than
    take_along_axis: a gather across the vocab-sharded axis forces XLA to
    all-gather the full logits (measured ~17 GB/device/step on llama3-8b
    train_4k, EXPERIMENTS.md §Perf), while the one-hot einsum stays local
    and reduces with a scalar psum."""
    kw = extras or {}
    logits = M.forward(cfg, params, tokens, **kw).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    ll = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = (lse - ll).mean()
    zl = z_loss * jnp.square(lse).mean()
    return nll + zl, {"nll": nll}


def build_train_step(cfg: ModelConfig, opt_cfg: opt.OptConfig,
                     microbatches: int = 1, z_loss: float = 1e-4):
    def grads_of(params, tokens, labels, extras):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: lm_loss(cfg, p, tokens, labels, extras=extras,
                              z_loss=z_loss), has_aux=True)(params)
        return loss, grads

    def step(params, opt_state, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        extras = {k: v for k, v in batch.items()
                  if k not in ("tokens", "labels")}

        if microbatches == 1:
            loss, grads = grads_of(params, tokens, labels, extras)
        else:
            # gradient accumulation over the leading batch axis
            B = tokens.shape[0]
            mb = B // microbatches

            def re(x):
                return x.reshape((microbatches, mb) + x.shape[1:])

            mtok, mlab = re(tokens), re(labels)
            mext = {k: re(v) for k, v in extras.items()}

            def body(carry, xs):
                acc, lsum = carry
                t, l = xs[0], xs[1]
                e = {k: xs[2 + i] for i, k in enumerate(sorted(mext))}
                loss, g = grads_of(params, t, l, e)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            xs = (mtok, mlab) + tuple(mext[k] for k in sorted(mext))
            (gsum, lsum), _ = jax.lax.scan(body, (zero, 0.0), xs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches

        new_params, new_state, om = opt.apply(opt_cfg, opt_state, grads,
                                              params)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return step


def eval_step(cfg: ModelConfig, params, batch, z_loss: float = 0.0):
    extras = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    loss, aux = lm_loss(cfg, params, batch["tokens"], batch["labels"],
                        extras=extras, z_loss=z_loss)
    return {"loss": loss, **aux}
