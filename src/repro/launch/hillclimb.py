"""§Perf hillclimb driver: run the chosen (arch x shape) cells with
candidate optimizations and record hypothesis -> before -> after.

Cells (selection per EXPERIMENTS.md §Roofline):
  A. llama3-8b x train_4k       — representative; collective-bound baseline
  B. llama3-8b x prefill_32k    — most collective-bound serve cell
  C. qwen2.5-14b x train_4k     — worst roofline fraction (40 heads do not
                                   divide the 16-way model axis -> attention
                                   compute replicates)

Run:  PYTHONPATH=src python -m repro.launch.hillclimb --out hillclimb.json
"""

import os  # noqa: E402  (dryrun import sets XLA_FLAGS first)

from repro.launch.dryrun import run_cell  # noqa: E402  sets 512 devices

import argparse
import json

EXPERIMENTS = [
    # (cell-id, arch, shape, variant-name, opts, hypothesis)
    ("A", "llama3-8b", "train_4k", "baseline", {},
     "baseline: FSDP all-gather repeats per microbatch (8x)"),
    ("A", "llama3-8b", "train_4k", "mb4", {"microbatches": 4},
     "halving microbatches halves per-step param all-gather wire bytes; "
     "activation memory doubles but still fits"),
    ("A", "llama3-8b", "train_4k", "mb4+dots",
     {"microbatches": 4, "remat_policy": "dots"},
     "saving matmul outputs (dots policy) removes most remat recompute: "
     "compute term -> ~model_flops; memory grows by saved dots"),
    ("A", "llama3-8b", "train_4k", "mb2+dots",
     {"microbatches": 2, "remat_policy": "dots"},
     "quartering the all-gather again if memory still fits"),

    ("B", "llama3-8b", "prefill_32k", "baseline", {},
     "baseline: FSDP-sharded params are all-gathered per layer at "
     "inference"),
    ("B", "llama3-8b", "prefill_32k", "pure-tp", {"serve_fsdp": False},
     "inference params need no FSDP: shard over model axis only -> "
     "per-layer weight all-gather disappears (16 GB bf16 / 16 = 1 GiB/chip "
     "fits)"),

    ("C", "qwen2.5-14b", "train_4k", "baseline", {},
     "baseline: 40 heads % 16-way model axis != 0 -> attention activations "
     "replicate across the model axis (measured 3.5x compute bloat)"),
    ("C", "qwen2.5-14b", "train_4k", "mesh32x8", {"mesh_shape": (32, 8)},
     "re-factor the 256-chip pod as (data=32, model=8): 40 heads, 8 kv "
     "heads, d_ff 13824 and vocab 152064 all divide 8 -> attention shards; "
     "DP width doubles (batch 256/32=8 per replica still >= 1)"),
    ("C", "qwen2.5-14b", "train_4k", "mesh32x8+dots",
     {"mesh_shape": (32, 8), "remat_policy": "dots"},
     "stack the remat win on top of the mesh fix"),
]

# round 2 (after analyzing round-1 per-collective breakdowns): the shared
# residual bottleneck is the TP activation all-reduce (~ tokens x d_model /
# device) plus a logits all-gather caused by take_along_axis on the
# vocab-sharded axis (fixed in code by the one-hot loss contraction).
ROUND2 = [
    ("A", "llama3-8b", "train_4k", "onehot-loss", {"microbatches": 4},
     "one-hot label contraction removes the vocab-axis logits all-gather "
     "(~17 GB/device/step)"),
    ("A", "llama3-8b", "train_4k", "mesh32x8+mb4",
     {"microbatches": 4, "mesh_shape": (32, 8)},
     "data=32/model=8 halves per-device tokens -> TP activation all-reduce "
     "halves; weight all-gather grows (shards are 2x bigger) but nets out"),
    ("B", "llama3-8b", "prefill_32k", "mesh32x8+pure-tp",
     {"serve_fsdp": False, "mesh_shape": (32, 8)},
     "prefill collective is TP activation all-reduce (139.6 GB/device): "
     "data=32 halves per-device tokens -> AR halves"),
    ("B", "llama3-8b", "prefill_32k", "mesh32x8-fsdp",
     {"mesh_shape": (32, 8)},
     "same mesh refactor with FSDP params kept (ablation)"),
    ("C", "qwen2.5-14b", "train_4k", "mesh64x4",
     {"mesh_shape": (64, 4), "microbatches": 8},
     "push further: model=4 still divides heads(40)/kv(8)/d_ff/vocab; "
     "TP activation AR drops another 2x; weight shards grow 2x"),
]
EXPERIMENTS = EXPERIMENTS + ROUND2


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="hillclimb.json")
    ap.add_argument("--cell", default=None, choices=[None, "A", "B", "C"])
    args = ap.parse_args()
    results = []
    for cell, arch, shape, variant, opts, hyp in EXPERIMENTS:
        if args.cell and cell != args.cell:
            continue
        print(f"--- {cell}/{variant}: {hyp[:70]}...", flush=True)
        rec = run_cell(arch, shape, "single", opts=opts)
        rec.update(cell=cell, variant=variant, hypothesis=hyp)
        results.append(rec)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        if rec["status"] == "ok" and "roofline" in rec:
            r = rec["roofline"]
            est = max(r["compute_s"], r["memory_s"], r["collective_s"])
            ideal = r["model_flops_per_device"] / 197e12
            print(f"    compute {r['compute_s']:.3f}s  "
                  f"mem {r['memory_s']:.3f}s  coll {r['collective_s']:.3f}s "
                  f"-> frac {100 * ideal / est:.1f}% "
                  f"(fits={rec['fits_hbm']}, "
                  f"HBM {rec['memory']['total_nonalias_bytes'] / 2**30:.1f}"
                  f"GiB)", flush=True)


if __name__ == "__main__":
    main()
