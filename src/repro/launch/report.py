"""Render EXPERIMENTS.md §Dry-run / §Roofline tables from dryrun_results.json
and pick the three hillclimb candidates (worst roofline fraction, most
collective-bound, most representative of the paper's technique)."""

from __future__ import annotations

import json
import sys

from repro.launch.mesh import PEAK_FLOPS_BF16


def fmt_bytes(b):
    return f"{b / 2**30:.2f}GiB"


def load(*paths):
    """Load and merge result files; later files override earlier records
    for the same (arch, shape, mesh) cell."""
    merged = {}
    for path in paths:
        with open(path) as f:
            for r in json.load(f):
                if r.get("opts"):
                    continue           # hillclimb variants stay separate
                merged[(r["arch"], r["shape"], r["mesh"])] = r
    return list(merged.values())


def roofline_rows(results):
    rows = []
    for r in results:
        if r.get("mesh") != "single" or r.get("status") != "ok":
            continue
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        est = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        ideal = rf["model_flops_per_device"] / PEAK_FLOPS_BF16
        frac = ideal / est if est > 0 else 0.0
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"], "dominant": rf["dominant"],
            "useful": rf.get("useful_ratio"),
            "mem_gib": r["memory"]["total_nonalias_bytes"] / 2 ** 30,
            "fits": r["fits_hbm"], "frac": frac, "est_s": est,
            "ideal_s": ideal,
        })
    return rows


def render_table(rows):
    hdr = ("| arch | shape | compute (s) | memory (s) | collective (s) | "
           "dominant | useful ratio | HBM/chip | roofline frac |")
    sep = "|" + "---|" * 9
    out = [hdr, sep]
    for w in rows:
        u = f"{w['useful']:.2f}" if w["useful"] else "-"
        out.append(
            f"| {w['arch']} | {w['shape']} | {w['compute_s']:.3e} | "
            f"{w['memory_s']:.3e} | {w['collective_s']:.3e} | "
            f"{w['dominant']} | {u} | {w['mem_gib']:.2f}GiB"
            f"{'' if w['fits'] else ' (!)'} | {w['frac'] * 100:.1f}% |")
    return "\n".join(out)


def pick_hillclimb(rows):
    """worst roofline fraction among train cells, most collective-bound,
    most representative (train_4k of the largest dense arch)."""
    train = [r for r in rows if r["shape"] == "train_4k"]
    worst = min(rows, key=lambda r: r["frac"] if r["ideal_s"] > 1e-6 else 1)
    coll = max(rows, key=lambda r: (r["collective_s"]
                                    / max(r["est_s"], 1e-12)))
    rep = next((r for r in train if r["arch"] == "llama3-8b"), train[0])
    return {"worst": worst, "collective": coll, "representative": rep}


def dryrun_summary(results):
    lines = []
    n = {"ok": 0, "skipped": 0, "error": 0}
    for r in results:
        n[r["status"]] = n.get(r["status"], 0) + 1
        tag = f"{r['arch']} x {r['shape']} x {r['mesh']}"
        if r["status"] == "ok":
            mem = r["memory"]["total_nonalias_bytes"]
            lines.append(f"- {tag}: ok, {fmt_bytes(mem)}/chip, "
                         f"fits={r['fits_hbm']}, compile {r['compile_s']}s")
        elif r["status"] == "skipped":
            lines.append(f"- {tag}: SKIPPED ({r['reason'][:60]}...)")
        else:
            lines.append(f"- {tag}: ERROR {r['error'][:120]}")
    return n, lines


def main():
    paths = sys.argv[1:] or ["dryrun_results.json"]
    results = load(*paths)
    n, lines = dryrun_summary(results)
    print(f"cells: {n}")
    rows = roofline_rows(results)
    print(render_table(rows))
    hc = pick_hillclimb(rows)
    print("\nhillclimb candidates:")
    for k, v in hc.items():
        print(f"  {k}: {v['arch']} x {v['shape']} "
              f"(frac {v['frac'] * 100:.1f}%, dom {v['dominant']})")


if __name__ == "__main__":
    main()
