"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization, and smoke tests must keep seeing a single device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2 pods x 256 = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices_per_axis: dict):
    """Elastic helper: build a mesh for whatever devices are available,
    e.g. {'data': 4, 'model': 2} on an 8-device slice."""
    shape = tuple(devices_per_axis.values())
    axes = tuple(devices_per_axis.keys())
    return jax.make_mesh(shape, axes)


# Hardware constants for the roofline analysis (TPU v5e, per brief):
PEAK_FLOPS_BF16 = 197e12          # FLOP/s per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link
ICI_LINKS_PER_RING = 2            # 2D torus: one ring per mesh axis, 1 link
                                  # each direction => 100 GB/s ring bandwidth
ICI_BW = ICI_LINK_BW * ICI_LINKS_PER_RING
HBM_PER_CHIP = 16 * 2 ** 30       # 16 GiB
