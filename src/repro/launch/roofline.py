"""Roofline-term extraction from compiled (AOT) artifacts.

Three terms per (arch x shape x mesh), all in seconds (per step, per chip):

    compute    = HLO_FLOPs / PEAK_FLOPS_BF16
    memory     = HLO_bytes_accessed / HBM_BW
    collective = wire_bytes / ICI_BW

``cost_analysis`` supplies per-device FLOPs and bytes for the partitioned
module.  Collective bytes are NOT in cost_analysis: we parse the optimized
post-SPMD HLO text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, weighted by the ring
wire-cost factor of the op (all-reduce moves ~2x its operand bytes on a
ring; gather/scatter/a2a ~1x; permute 1x).

Known caveats (documented, consistent across all cells so comparisons
hold): XLA's cost analysis may not multiply `while`-loop bodies by their
trip counts, so we also report MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D
(MoE) and the useful-compute ratio; when the ratio is far from ~1 the
analytic number is the one to trust for absolute times."""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*[a-z0-9]+\[[0-9,]*\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_WIRE_FACTOR = {
    "all-gather": 1.0,        # each chip receives (N-1)/N of the result
    "all-reduce": 2.0,        # ring: reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum wire bytes per collective kind from optimized HLO text."""
    out: Dict[str, float] = {k: 0.0 for k in _WIRE_FACTOR}
    count = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand shapes: everything inside the call parens
        paren = line[m.end():]
        shapes = _SHAPE_RE.findall(paren)
        if not shapes:
            # fall back to the result shape at line start
            shapes = _SHAPE_RE.findall(line[:m.end()])[:1]
        bytes_ = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += bytes_ * _WIRE_FACTOR[kind]
        count += 1
    out["n_collectives"] = count
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    wire_bytes: float            # per device
    compute_s: float
    memory_s: float
    collective_s: float
    per_coll: Dict[str, float]
    model_flops_per_device: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> Optional[float]:
        if self.model_flops_per_device and self.flops:
            return self.model_flops_per_device / self.flops
        return None

    def as_dict(self):
        d = dataclasses.asdict(self)
        d["dominant"] = self.dominant
        d["useful_ratio"] = self.useful_ratio
        return d


def analyze(compiled, *, model_flops_total: float = 0.0,
            n_chips: int = 1) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):      # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    text = compiled.as_text()
    coll = collective_bytes(text)
    wire = sum(v for k, v in coll.items() if k != "n_collectives")
    return Roofline(
        flops=flops,
        bytes_accessed=byt,
        wire_bytes=wire,
        compute_s=flops / PEAK_FLOPS_BF16,
        memory_s=byt / HBM_BW,
        collective_s=wire / ICI_BW,
        per_coll=coll,
        model_flops_per_device=model_flops_total / max(n_chips, 1),
    )


def analytic_memory_bytes(cfg, shape, n_chips: int,
                          microbatches: int = 1) -> Dict[str, float]:
    """Analytic per-chip HBM traffic model (the honest memory term).

    XLA-CPU's ``bytes accessed`` counts every operand of every unfused op —
    a gross upper bound that has little to do with TPU HBM traffic after
    fusion.  This model instead counts the structurally unavoidable
    traffic, assuming attention/SSD internals stay in VMEM (the Pallas
    kernels in repro.kernels are exactly that guarantee):

      train:   params re-read per microbatch x3 (fwd, bwd, remat recompute)
               + optimizer state r/w (34 B/param: bf16 params w, f32
               master/m/v r+w, f32 grads r+w)
               + activation checkpoints w+r (scan carry per super-block)
               + KV streamed per attention query block
      prefill: params read once + cache written + KV re-read per q block
      decode:  params read once + full cache read + one-token cache write
    """
    from repro.models import model as M

    n_params = M.param_count(cfg)
    n_active = M.active_param_count(cfg)
    p_bytes = 2.0 * n_params / n_chips                 # bf16 shard per chip
    a_bytes = 2.0 * n_active / n_chips
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    bf = 2.0
    # data-parallel degree: batch shards over (pod, data) = n_chips / 16
    dp = max(n_chips // 16, 1)
    b_loc = max(B // dp, 1)

    n_super = cfg.n_pattern_blocks
    attn_layers = sum(cfg.block_pattern.count(k)
                      for k in ("attn", "attn_swa", "attn_local", "moe",
                                "dec_attn_cross")) * n_super
    kvh, hd = max(cfg.n_kv_heads, 1), cfg.head_dim

    if shape.kind == "train":
        mb = max(microbatches, 1)
        opt = 34.0 * n_params / n_chips
        # active params re-read per microbatch: fwd + bwd + remat recompute
        param_traffic = 3.0 * mb * a_bytes
        # activation checkpoints: one carry per super-block, written + read
        carry = (b_loc / mb) * S * d * bf
        act = 2.0 * carry * n_super * mb
        # flash attention: KV streamed once per query block (kv heads are
        # below the model-axis width -> replicated, full kv per chip)
        nq = max(S // cfg.q_block, 1)
        kv_bytes = (b_loc / mb) * S * kvh * hd * 2 * bf
        attn = attn_layers * nq * kv_bytes * mb * 3           # fwd+bwd+remat
        total = opt + param_traffic + act + attn
        return {"total": total, "opt": opt, "params": param_traffic,
                "activations": act, "attention_kv": attn}
    if shape.kind == "prefill":
        nq = max(S // cfg.q_block, 1)
        kv_total = attn_layers * B * S * kvh * hd * 2 * bf / n_chips
        attn = nq * kv_total
        act = B * S * d * bf * n_super / n_chips
        total = p_bytes + kv_total + attn + act
        return {"total": total, "params": p_bytes, "cache_write": kv_total,
                "attention_kv": attn, "activations": act}
    # decode: one token
    cache_read = attn_layers * B * S * kvh * hd * 2 * bf / n_chips
    state = 0.0
    if cfg.ssm_heads:
        state = (cfg.n_layers * B * cfg.ssm_heads * cfg.ssm_head_dim
                 * cfg.ssm_state * 4.0 * 2) / n_chips
    if cfg.rglru_width:
        state += (cfg.n_layers * B * cfg.rglru_width * 4.0 * 2) / n_chips
    if cfg.window:
        cache_read = attn_layers * B * min(S, cfg.window) * kvh * hd * 2 \
            * bf / n_chips
    if cfg.local_window:
        cache_read = attn_layers * B * min(S, cfg.local_window) * kvh * hd \
            * 2 * bf / n_chips
    total = p_bytes + cache_read + state
    return {"total": total, "params": p_bytes, "cache_read": cache_read,
            "state": state}


def memory_report(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_nonalias_bytes"] = (out.get("argument_size_in_bytes", 0)
                                   + out.get("output_size_in_bytes", 0)
                                   + out.get("temp_size_in_bytes", 0)
                                   - out.get("alias_size_in_bytes", 0))
    return out
