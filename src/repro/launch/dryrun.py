import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on placeholder host devices and extract memory / cost / roofline
data from the AOT artifacts.  No arrays are ever allocated — parameters,
optimizer state, caches and batches are ShapeDtypeStructs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

The very first lines of this file force 512 host devices BEFORE any jax
import (jax locks the device count on first init).  Do not import this
module from code that needs a single-device view.
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.registry import ARCHS, LONG_CONTEXT_ARCHS
from repro.launch import roofline as RL
from repro.launch.mesh import HBM_PER_CHIP, make_production_mesh
from repro.models import model as M
from repro.parallel import specs as S
from repro.parallel.sharding import ShardingPolicy, use_policy
from repro.serve.serve_step import build_decode_step, build_prefill_step
from repro.train import optimizer as opt
from repro.train.train_step import build_train_step


def cell_is_defined(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


# train cells use gradient accumulation (production-realistic): global batch
# 256 x 4096 tokens does not fit activations otherwise.
TRAIN_MICROBATCHES = 8


def reduced_cfg(cfg, k: int):
    """Same architecture with k super-blocks (and k encoder layers) — used
    for the two-point cost extrapolation: XLA's cost_analysis counts a
    while-loop body ONCE regardless of trip count (verified empirically),
    so per-layer marginal cost = F(2) - F(1), total = F(1) + (nb-1)*(F2-F1).
    Exact for homogeneous scanned stacks."""
    import dataclasses
    repl = {"n_layers": k * len(cfg.block_pattern) + len(cfg.extra_blocks),
            "unroll": True}
    if cfg.enc_layers:
        repl["enc_layers"] = k
    # keep the unrolled attention-block count small: FLOPs are invariant to
    # the block size (fully-masked blocks are still computed), so probes use
    # coarse blocks for compile speed.
    repl["q_block"] = 8192
    repl["kv_block"] = 16384
    repl["ssd_chunk"] = 4096
    return dataclasses.replace(cfg, **repl)


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N*D for single-pass inference
    (N = active params, D = tokens processed in the step)."""
    n_active = M.active_param_count(cfg)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n_active * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n_active * toks
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape_name: str, mesh, *, microbatches: int = 1,
               donate: bool = True, cost_probe: bool = False,
               opts: Optional[Dict[str, Any]] = None):
    """Build, lower and return (lowered, aux) for one cell."""
    opts = opts or {}
    cfg = get_config(arch)
    if opts.get("remat_policy") or opts.get("moe_impl"):
        import dataclasses as _dc
        repl = {}
        if opts.get("remat_policy"):
            repl["remat_policy"] = opts["remat_policy"]
        if opts.get("moe_impl"):
            repl["moe_impl"] = opts["moe_impl"]
        cfg = _dc.replace(cfg, **repl)
    shape = SHAPES[shape_name]
    aparams = M.abstract_params(cfg)
    fsdp = opts.get("serve_fsdp", True) if shape_name != "train_4k" else True
    pspecs = S.tree_param_specs(mesh, aparams, fsdp=fsdp)
    psh = _ns(mesh, pspecs)

    extras_specs: Dict[str, Any] = {}
    if cfg.family == "vlm":
        extras_specs["cross_states"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        extras_specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.float32)

    if shape.kind == "train":
        oc = opt.OptConfig()
        aopt = jax.eval_shape(opt.init, aparams)
        ospecs = S.opt_state_specs(mesh, aopt, pspecs)
        osh = _ns(mesh, ospecs)
        batch = {"tokens": jax.ShapeDtypeStruct(
                     (shape.global_batch, shape.seq_len), jnp.int32),
                 "labels": jax.ShapeDtypeStruct(
                     (shape.global_batch, shape.seq_len), jnp.int32),
                 **extras_specs}
        bsh = _ns(mesh, {k: S.batch_spec(mesh, v.shape)
                         for k, v in batch.items()})
        mb = (microbatches if cost_probe else
              max(microbatches, opts.get("microbatches",
                                         TRAIN_MICROBATCHES)))
        step = build_train_step(cfg, oc, microbatches=mb)
        msh = {"loss": NamedSharding(mesh, P()),
               "grad_norm": NamedSharding(mesh, P()),
               "lr": NamedSharding(mesh, P())}
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, msh))
        lowered = jitted.lower(aparams, aopt, batch)
        return lowered, {"cfg": cfg, "shape": shape}

    if shape.kind == "prefill":
        tokens = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                      jnp.int32)
        acache = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
        csh = _ns(mesh, S.tree_cache_specs(mesh, acache))
        tsh = NamedSharding(mesh, S.batch_spec(mesh, tokens.shape))
        esh = {k: NamedSharding(mesh, S.batch_spec(mesh, v.shape))
               for k, v in extras_specs.items()}
        fn = build_prefill_step(cfg, shape.seq_len)

        def prefill_pos(params, tokens, *extra_vals):
            kw = dict(zip(sorted(extras_specs), extra_vals))
            return fn(params, tokens, **kw)

        jitted = jax.jit(
            prefill_pos,
            in_shardings=(psh, tsh) + tuple(esh[k]
                                            for k in sorted(extras_specs)),
            out_shardings=(NamedSharding(
                mesh, S.batch_spec(mesh, (shape.global_batch,))), csh))
        lowered = jitted.lower(aparams, tokens,
                               *[extras_specs[k]
                                 for k in sorted(extras_specs)])
        return lowered, {"cfg": cfg, "shape": shape}

    # decode
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    acache = jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len))
    csh = _ns(mesh, S.tree_cache_specs(mesh, acache))
    tsh = NamedSharding(mesh, S.batch_spec(mesh, tokens.shape))
    esh = tuple(NamedSharding(mesh,
                              S.batch_spec(mesh, extras_specs[k].shape))
                for k in sorted(extras_specs) if k != "frontend_embeds")
    dec_extra_keys = [k for k in sorted(extras_specs)
                      if k != "frontend_embeds"]
    fn = build_decode_step(cfg)

    def decode_pos(params, cache, tokens, *extra_vals):
        kw = dict(zip(dec_extra_keys, extra_vals))
        return fn(params, cache, tokens, **kw)

    # audio decode attends to encoder states: supply them as cross_states
    extra_vals = []
    if cfg.family == "audio":
        dec_extra_keys = ["cross_states"]
        esh = (NamedSharding(mesh, S.batch_spec(
            mesh, (shape.global_batch, cfg.frontend_tokens, cfg.d_model))),)
        extra_vals = [jax.ShapeDtypeStruct(
            (shape.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype))]
    elif cfg.family == "vlm":
        extra_vals = [extras_specs["cross_states"]]

    jitted = jax.jit(
        decode_pos,
        in_shardings=(psh, csh, tsh) + esh,
        out_shardings=(NamedSharding(
            mesh, S.batch_spec(mesh, (shape.global_batch, 1))), csh),
        donate_argnums=(1,) if donate else ())
    lowered = jitted.lower(aparams, acache, tokens, *extra_vals)
    return lowered, {"cfg": cfg, "shape": shape}


def _cost_tuple(arch, shape_name, mesh, cfg_override, opts=None):
    """(flops, bytes, per-collective wire bytes) for a reduced config.

    Cost probes run at MICROBATCH scale with no accumulation loop (the
    grad-accum scan body would also be counted once); the caller multiplies
    train-cell results by TRAIN_MICROBATCHES — matching the real step,
    whose per-microbatch backward includes its gradient reduction."""
    import dataclasses as _dc
    import repro.configs.registry as REG
    orig = REG.ARCHS[arch]
    REG.ARCHS[arch] = cfg_override
    shape = SHAPES[shape_name]
    opts = opts or {}
    n_mb = opts.get("microbatches", TRAIN_MICROBATCHES)
    probe_shape = shape
    if shape.kind == "train":
        probe_shape = _dc.replace(
            shape, name=shape.name + "-probe",
            global_batch=shape.global_batch // n_mb)
    SHAPES[probe_shape.name] = probe_shape
    try:
        lowered, _ = lower_cell(arch, probe_shape.name, mesh,
                                microbatches=1, cost_probe=True, opts=opts)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        coll = RL.collective_bytes(compiled.as_text())
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)), coll)
    finally:
        REG.ARCHS[arch] = orig
        if probe_shape.name != shape.name:
            del SHAPES[probe_shape.name]


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             verbose: bool = True, extrapolate: bool = True,
             opts: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    opts = opts or {}
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "opts": opts}
    if not cell_is_defined(arch, shape_name):
        rec["status"] = "skipped"
        rec["reason"] = ("full-attention arch: long_500k requires "
                         "sub-quadratic attention (DESIGN.md §5)")
        return rec
    if opts.get("mesh_shape"):
        import jax as _jax
        mesh = _jax.make_mesh(tuple(opts["mesh_shape"]), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    # the roofline table is single-pod only (per the brief); the multi-pod
    # pass proves the pod axis shards (lower+compile+memory), no probes
    if mesh_kind == "multi":
        extrapolate = False
    t0 = time.perf_counter()
    roof = None
    try:
        with mesh, use_policy(ShardingPolicy(mesh)):
            lowered, aux = lower_cell(arch, shape_name, mesh, opts=opts)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
            mem = RL.memory_report(compiled)
            mf = model_flops(aux["cfg"], aux["shape"])
            # ---- two-point extrapolation over scanned layers -----------
            # k=2,3 (a scan of length 1 gets inlined by XLA, breaking
            # linearity); train costs are per-microbatch, scaled back up.
            if extrapolate:
                cfg = aux["cfg"]
                nb = cfg.n_pattern_blocks
                f2, b2, c2 = _cost_tuple(arch, shape_name, mesh,
                                         reduced_cfg(cfg, 2), opts=opts)
                f3, b3, c3 = _cost_tuple(arch, shape_name, mesh,
                                         reduced_cfg(cfg, 3), opts=opts)
                scale = (opts.get("microbatches", TRAIN_MICROBATCHES)
                         if aux["shape"].kind == "train" else 1)
                flops = (f2 + (nb - 2) * (f3 - f2)) * scale
                byt = (b2 + (nb - 2) * (b3 - b2)) * scale
                per_coll = {k: (c2[k] + (nb - 2) * (c3[k] - c2[k])) * scale
                            for k in c2}
                wire = sum(v for k, v in per_coll.items()
                           if k != "n_collectives")
                from repro.launch.mesh import (HBM_BW, ICI_BW,
                                               PEAK_FLOPS_BF16)
                amem = RL.analytic_memory_bytes(
                    cfg, aux["shape"], n_chips,
                    microbatches=opts.get("microbatches",
                                          TRAIN_MICROBATCHES))
                rec["analytic_memory"] = {k: round(v)
                                          for k, v in amem.items()}
                rec["xla_bytes_upper_bound"] = byt
                roof = RL.Roofline(
                    flops=flops, bytes_accessed=amem["total"],
                    wire_bytes=wire,
                    compute_s=flops / PEAK_FLOPS_BF16,
                    memory_s=amem["total"] / HBM_BW,
                    collective_s=wire / ICI_BW, per_coll=per_coll,
                    model_flops_per_device=mf / n_chips)
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem,
                   fits_hbm=mem["total_nonalias_bytes"] <= HBM_PER_CHIP,
                   model_flops_total=mf, n_chips=n_chips)
        if roof is not None:
            rec["roofline"] = roof.as_dict()
    except Exception as e:  # noqa: BLE001 — failures ARE the result here
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    if verbose:
        status = rec["status"]
        extra = ""
        if status == "ok" and "roofline" in rec:
            r = rec["roofline"]
            extra = (f" compute={r['compute_s']:.3e}s "
                     f"memory={r['memory_s']:.3e}s "
                     f"coll={r['collective_s']:.3e}s dom={r['dominant']}"
                     f" fits={rec['fits_hbm']}")
        elif status == "ok":
            extra = (f" compiled; fits={rec['fits_hbm']} "
                     f"(compile {rec['compile_s']}s)")
        elif status == "error":
            extra = " " + rec["error"][:140]
        print(f"[{arch} x {shape_name} x {mesh_kind}] {status}{extra}",
              flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])
    if args.all:
        archs, shapes, meshes = sorted(ARCHS), list(SHAPES), ["single",
                                                              "multi"]
    results = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_kind)
                results.append(rec)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
