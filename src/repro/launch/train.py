"""Training launcher: config-driven entry point wiring the mesh, sharding
policy, Terra-driven Trainer, checkpointing and elastic restart.

    # single-process (CPU dev / one accelerator):
    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

    # elastic: the launcher builds a mesh from whatever devices exist and
    # reshards the checkpoint on load (data x model factorization chosen by
    # --model-parallel)
    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --smoke --steps 100 --model-parallel 2

On a real TPU slice this process is started once per host by the cluster
scheduler (GKE/Borg); jax.distributed.initialize() is invoked when the
standard TPU env vars are present.  Fault tolerance: crash at any point and
re-launch with the same --ckpt-dir — training resumes from the last
committed step with the data stream reseeked deterministically.
"""

from __future__ import annotations

import argparse
import os

import jax

from repro.configs import get_config, smoke_config
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer


def build_mesh(model_parallel: int):
    n = jax.device_count()
    if n == 1 or model_parallel <= 1:
        return None
    assert n % model_parallel == 0, \
        f"{n} devices not divisible by model_parallel={model_parallel}"
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--no-terra", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if "TPU_WORKER_ID" in os.environ or "MEGASCALE_COORDINATOR_ADDRESS" \
            in os.environ:
        jax.distributed.initialize()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = build_mesh(args.model_parallel)
    print(f"launch: arch={cfg.name} devices={jax.device_count()} "
          f"mesh={'1-device' if mesh is None else dict(mesh.shape)}")

    trainer = Trainer(
        cfg,
        OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 2),
                  total_steps=args.steps),
        ckpt_dir=args.ckpt_dir, batch=args.batch, seq_len=args.seq_len,
        microbatches=args.microbatches, mesh=mesh,
        log_every=args.log_every, ckpt_every=args.ckpt_every,
        use_terra=not args.no_terra, seed=args.seed)
    if trainer.start_step:
        print(f"auto-resumed from step {trainer.start_step}")
    hist = trainer.train(args.steps)
    if hist:
        print(f"done: loss {hist[0][1]:.4f} -> {hist[-1][1]:.4f}")
    if trainer.straggler_events:
        print(f"stragglers flagged: {len(trainer.straggler_events)}")
    if not args.no_terra:
        print("terra:", {k: v for k, v in trainer._iteration.stats.items()
                         if isinstance(v, int)})
        trainer._iteration.close()


if __name__ == "__main__":
    main()
