"""Compatibility shim — the Terra runtime now lives in ``core/executor/``.

The original runner god-module (engine + walker + dispatch + fallback +
variable store in one file) was decomposed into the executor package; see
DESIGN.md §3 for the layout and executor/__init__.py for the map.  This
module keeps every historical import path working:

    from repro.core.runner import TerraEngine, GraphRunner, Walker, ...
"""

from repro.core.executor import (  # noqa: F401
    IMPERATIVE,
    SKELETON,
    TRACING,
    ChainDispatcher,
    Dispatcher,
    DivergenceError,
    DivergenceHandler,
    GraphRunner,
    ReplayRequired,
    SegmentCache,
    SegmentDispatcher,
    TerraEngine,
    VariableStore,
    Walker,
)

__all__ = [
    "TerraEngine", "GraphRunner", "Walker", "VariableStore",
    "Dispatcher", "SegmentDispatcher", "ChainDispatcher",
    "DivergenceHandler", "SegmentCache", "DivergenceError",
    "ReplayRequired", "IMPERATIVE", "TRACING", "SKELETON",
]
