"""The Terra runtime: TerraEngine, PythonRunner walker, GraphRunner.

Phases (paper §4.1, Fig. 2):

* **tracing phase** — the program executes imperatively; every DL op is
  recorded into a Trace; at iteration end the (loop-rolled) trace is merged
  into the TraceGraph.  When the newest trace is already covered, the
  GraphGenerator emits a GraphProgram and the engine enters the
  co-execution phase.
* **co-execution phase** — the PythonRunner executes the *skeleton*
  program: DL ops return placeholder tensors and are *validated* against
  the TraceGraph by the Walker, which resolves Case Select / Loop Cond
  values and collects Input Feeding values.  At every segment boundary the
  segment is dispatched to the GraphRunner (a dedicated thread driving the
  XLA executor asynchronously).  Output Fetching blocks only the Python
  side, exactly like the paper's PythonRunner stall.
* **divergence fallback** — if validation fails (a new trace), Terra
  cancels the GraphRunner's work for the iteration (drain + restore the
  variable snapshot), *replays* the already-validated prefix eagerly to
  rematerialize live placeholder tensors, and finishes the iteration
  imperatively — Python side effects are never re-executed.  The extended
  trace is merged and the symbolic graph regenerated.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ops as ops_mod
from repro.core.graphgen import GraphProgram, SegProg
from repro.core.ops import Const
from repro.core.tensor import TerraTensor, Variable, current_engine, set_current_engine
from repro.core.trace import (Aval, FeedRef, Ref, SyncMarker, Trace,
                              TraceEntry, VarAssign, VarRef)
from repro.core.tracegraph import TraceGraph, roll_loops

IMPERATIVE, TRACING, SKELETON = "imperative", "tracing", "skeleton"


class DivergenceError(Exception):
    """Raised by the Walker when the current trace escapes the TraceGraph."""


class ReplayRequired(Exception):
    """Materialization needs a value the symbolic graph does not output."""


# ==========================================================================
# GraphRunner: ordered async executor with a device-resident variable store
# ==========================================================================

class GraphRunner:
    def __init__(self, lazy: bool = False):
        self.lazy = lazy
        self.store: Dict[int, Any] = {}       # var_id -> buffer
        self._q: "queue.Queue" = queue.Queue()
        self._pending = 0
        self._cv = threading.Condition()
        self.exec_time = 0.0
        self.stall_time = 0.0
        self._last_done = time.perf_counter()
        self._open = False                     # an iteration is in flight
        if not lazy:
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="terra-graphrunner")
            self._worker.start()

    # ------------------------------------------------------------------
    def submit(self, closure) -> None:
        with self._cv:
            self._pending += 1
        self._q.put(closure)
        if self.lazy:
            pass  # executed on demand by drain()/fetch

    def _run_one(self, closure):
        t0 = time.perf_counter()
        if self._open:
            self.stall_time += max(0.0, t0 - self._last_done)
        try:
            closure()
        finally:
            t1 = time.perf_counter()
            self.exec_time += t1 - t0
            self._last_done = t1
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    def _run(self):
        while True:
            closure = self._q.get()
            if closure is None:
                return
            self._run_one(closure)

    # ------------------------------------------------------------------
    def run_pending_now(self):
        """Lazy mode: execute queued work on the calling thread (this is
        the LazyTensor-style serialized evaluation of Table 2)."""
        while True:
            try:
                closure = self._q.get_nowait()
            except queue.Empty:
                return
            if closure is not None:
                self._run_one(closure)

    def drain(self):
        if self.lazy:
            self.run_pending_now()
            return
        with self._cv:
            while self._pending > 0:
                self._cv.wait()

    def stop(self):
        if not self.lazy:
            self._q.put(None)


# ==========================================================================
# Walker: the PythonRunner's TraceGraph cursor (validation + Case Select)
# ==========================================================================

class _LoopState:
    def __init__(self, node):
        self.node = node
        self.body = node.body
        self.pos = 0
        self.trips = 0
        self.prev_prod: Dict[Tuple[int, int], int] = {}  # local (j,oi) -> ordinal
        self.cur_prod: Dict[Tuple[int, int], int] = {}
        self.entry_ordinals: List[int] = []


class Walker:
    """Advances through the TraceGraph as the skeleton executes, recording
    Case Select / Loop Cond / Input Feeding values and detecting new
    traces (paper §4.1 'continuously compares the trace with the
    TraceGraph')."""

    def __init__(self, gp: GraphProgram):
        self.gp = gp
        self.tg = gp.tg
        self.cursor = self.tg.start.uid
        self.region_stack: List[int] = []      # join uids
        self.seg_idx = 0
        self.sels: Dict[int, int] = {}
        self.trips: Dict[int, int] = {}
        self.feed_vals: Dict[Tuple[int, int], Any] = {}
        self.ord_to_uid: Dict[int, int] = {}
        self.loop: Optional[_LoopState] = None
        self.boundary_reached: Optional[int] = None

    # -- src resolution (must mirror TraceGraph.merge_trace) --------------
    def _src_of(self, ref, pos, entry):
        if isinstance(ref, Ref):
            uid = self.ord_to_uid.get(ref.entry)
            if uid is None:
                raise DivergenceError("ref to unknown producer")
            n = self.tg.nodes[uid]
            if n.kind == "loop":
                return ("node", uid, n.body.out_slot_for(ref, ()))
            return ("node", uid, ref.out_idx)
        if isinstance(ref, FeedRef):
            return ("feed", dict(entry.feed_avals).get(pos))
        if isinstance(ref, VarRef):
            return ("var", ref.var_id)
        if isinstance(ref, Const):
            return ("const", ref.value)
        raise DivergenceError(f"unknown ref {ref!r}")

    def _entry_sig(self, entry: TraceEntry):
        srcs = tuple(self._src_of(r, i, entry)
                     for i, r in enumerate(entry.input_refs))
        return (entry.op_name, entry.attrs, entry.location, srcs)

    # -- loop-body matching -------------------------------------------------
    def _match_body_entry(self, ls: _LoopState, entry: TraceEntry) -> bool:
        body, j = ls.body, ls.pos
        if j >= len(body.entries):
            return False
        be = body.entries[j]
        if (entry.op_name, entry.attrs, entry.location) != (
                be.op_name, be.attrs, be.location):
            return False
        n_car = len(body.carries)
        for pos, (ref, s) in enumerate(zip(entry.input_refs, be.srcs_local)):
            kind = s[0]
            if kind == "node":
                if not (isinstance(ref, Ref)
                        and ls.cur_prod.get((s[1], s[2])) == ref.entry):
                    return False
            elif kind == "carry":
                init_src, prod = body.carries[s[1]]
                if ls.trips == 0:
                    want = self.gp.tg.nodes[ls.node.uid].srcs[s[1]]
                    if self._src_of(ref, pos, entry) != want:
                        return False
                else:
                    if not (isinstance(ref, Ref)
                            and ls.prev_prod.get(prod) == ref.entry):
                        return False
            elif kind == "inv":
                want = self.gp.tg.nodes[ls.node.uid].srcs[n_car + s[1]]
                if self._src_of(ref, pos, entry) != want:
                    return False
            elif kind == "const":
                if not (isinstance(ref, Const) and ref.value == s[1]):
                    return False
            elif kind == "var":
                if not (isinstance(ref, VarRef) and ref.var_id == s[1]):
                    return False
            else:
                return False
        return True

    def _loop_step(self, ls: _LoopState, entry: TraceEntry, ordinal: int):
        j = ls.pos
        for oi in range(len(ls.body.entries[j].out_avals)):
            ls.cur_prod[(j, oi)] = ordinal
        ls.cur_prod.setdefault((j, -1), ordinal)
        ls.entry_ordinals.append(ordinal)
        ls.pos += 1
        if ls.pos == len(ls.body.entries):
            ls.trips += 1
            ls.pos = 0
            ls.prev_prod = ls.cur_prod
            ls.cur_prod = {}
        return ls.body.entries[j].out_avals

    def _exit_loop(self):
        ls = self.loop
        n = ls.node
        if ls.pos != 0:
            raise DivergenceError("loop exited mid-body")
        if len(n.trips) == 1:
            if ls.trips != next(iter(n.trips)):
                raise DivergenceError("unrolled loop trip-count changed")
        else:
            self.trips[n.uid] = ls.trips
        for o in ls.entry_ordinals:
            self.ord_to_uid[o] = n.uid
        n._last_ordinals = tuple(ls.entry_ordinals)
        self.loop = None
        self.cursor = n.uid

    # -- main advance ---------------------------------------------------------
    def advance(self, entry: TraceEntry, ordinal: int,
                feed_values: Dict[int, Any]) -> Tuple[Tuple[Aval, ...], int]:
        """Validate one op; returns (out_avals, node_uid or body marker)."""
        if self.loop is not None:
            ls = self.loop
            if self._match_body_entry(ls, entry):
                avals = self._loop_step(ls, entry, ordinal)
                return avals, ls.node.uid
            if ls.pos == 0:
                self._exit_loop()       # try to continue after the loop
            else:
                raise DivergenceError("loop body mismatch")

        children = []
        seen = set()
        for c in self.tg.nodes[self.cursor].children:
            if c not in seen:
                seen.add(c)
                children.append(c)
        if not children:
            raise DivergenceError("walk past end of TraceGraph")
        sig = self._entry_sig(entry)
        matched_idx = None
        for i, cuid in enumerate(children):
            n = self.tg.nodes[cuid]
            if n.kind == "op" and n.sig() == sig:
                matched_idx = i
                break
            if n.kind == "loop":
                ls = _LoopState(n)
                if (entry.op_name, entry.attrs, entry.location) == (
                        n.body.entries[0].op_name, n.body.entries[0].attrs,
                        n.body.entries[0].location):
                    self.loop = ls
                    if self._match_body_entry(ls, entry):
                        matched_idx = i
                        break
                    self.loop = None
        if matched_idx is None:
            raise DivergenceError(
                f"no TraceGraph node matches {entry.op_name} at "
                f"{entry.location}")
        cuid = children[matched_idx]
        if len(children) > 1:
            self.sels[self.cursor] = matched_idx
            join = self.gp.structure.ipdom.get(self.cursor)
            if join is not None:
                self.region_stack.append(join)
        # record feed values keyed by (uid, argpos)
        for pos, v in feed_values.items():
            self.feed_vals[(cuid, pos)] = v

        node = self.tg.nodes[cuid]
        if node.kind == "loop":
            avals = self._loop_step(self.loop, entry, ordinal)
            # cursor stays; region bookkeeping on exit
            return avals, cuid

        self.ord_to_uid[ordinal] = cuid
        self.cursor = cuid
        while self.region_stack and self.region_stack[-1] == cuid:
            self.region_stack.pop()
        if node.sync_after and not self.region_stack:
            self.boundary_reached = self.seg_idx
        return node.out_avals, cuid

    # -- finishing -------------------------------------------------------------
    def at_end(self) -> bool:
        if self.loop is not None:
            if self.loop.pos != 0:
                return False
            self._exit_loop()
        return self.tg.end.uid in self.tg.nodes[self.cursor].children

    def uid_of(self, ref: Ref) -> Tuple[int, int]:
        uid = self.ord_to_uid.get(ref.entry)
        if uid is None:
            raise ReplayRequired()
        n = self.tg.nodes[uid]
        if n.kind == "loop":
            return uid, n.body.out_slot_for(ref, ())
        return uid, ref.out_idx


# ==========================================================================
# TerraEngine
# ==========================================================================

class TerraEngine:
    """One engine per TerraFunction.  Owns the TraceGraph, the phase state
    machine, the GraphRunner and all per-iteration bookkeeping."""

    def __init__(self, lazy: bool = False, seed: int = 0,
                 min_covered: int = 1):
        self.tg = TraceGraph()
        self.mode = TRACING
        self.runner = GraphRunner(lazy=lazy)
        self.gp: Optional[GraphProgram] = None
        self.min_covered = min_covered
        self._covered_streak = 0
        self.skip_files: Tuple[str, ...] = ()
        self.vars: Dict[int, Variable] = {}
        self._base_key = jax.random.PRNGKey(seed)

        # path-specialized dispatch (gating fetches inside branch regions):
        # jitted linear chains keyed by the exact op path, replacing the
        # eager replay fallback for structurally-awkward programs
        self._chain_cache: Dict[Tuple, Any] = {}
        self._path_mode = False
        self._chain_start = 0
        self._chain_futures: Dict[Tuple[int, int], Future] = {}

        # per-iteration state
        self.iter_id = -1
        self.trace: Optional[Trace] = None
        self._vals: Dict[Tuple[int, int], Any] = {}
        self._tensors: Dict[Tuple[int, int], TerraTensor] = {}
        self._feed_log: Dict[Tuple[int, int], Any] = {}
        self._var_binding: Dict[int, TerraTensor] = {}
        self._rng_count = 0
        self.walker: Optional[Walker] = None
        self._fetch_futures: Dict[Tuple[int, int], Future] = {}
        self._dispatched_through = -1
        self._iter_env_keys: set = set()
        self._snapshot_slot: Dict[int, Any] = {}
        self._iter_env: Dict[Tuple[int, int], Any] = {}   # runner-thread env

        # stats (benchmarks: Fig. 6 breakdown, App. F transitions)
        self.stats = {
            "iterations": 0, "traced_iterations": 0, "transitions": 0,
            "replays": 0, "py_stall_time": 0.0, "graph_versions": 0,
            "segments_dispatched": 0,
        }

    # ------------------------------------------------------------------
    # iteration lifecycle
    # ------------------------------------------------------------------
    def start_iteration(self):
        self.iter_id += 1
        self.trace = Trace()
        self._vals.clear()
        self._tensors = {}
        self._feed_log = {}
        self._var_binding = {}
        self._rng_count = 0
        self._fetch_futures = {}
        self._dispatched_through = -1
        self._iter_env = {}
        self._iter_open = True
        self._path_mode = False
        self._chain_start = 0
        self._chain_futures = {}
        self._ordinal_at_dispatch = 0
        if self.mode == SKELETON:
            self.walker = Walker(self.gp)
            snap: Dict[int, Any] = {}
            self._snapshot_slot = snap
            store = self.runner.store

            def take_snapshot():
                snap.update(store)
            self.runner.submit(take_snapshot)
            self.runner._open = True

    def end_iteration(self):
        self.stats["iterations"] += 1
        self._iter_open = False
        if self.mode == SKELETON:
            try:
                if not self.walker.at_end():
                    raise DivergenceError("iteration ended mid-TraceGraph")
            except DivergenceError:
                self._fallback_replay()
                self._finish_traced_iteration()
                return
            if self._path_mode:
                self._dispatch_chain()       # trailing chain (side effects)
            else:
                self._dispatch_through(len(self.gp.seg_progs) - 1)
            self.runner._open = False
            return
        self._finish_traced_iteration()

    def _finish_traced_iteration(self):
        self.stats["traced_iterations"] += 1
        # commit final variable bindings to the store (direct buffer access:
        # a variable commit is not a user-visible fetch point)
        for vid, t in self._var_binding.items():
            self.runner.store[vid] = (t._eager if t._eager is not None
                                      else t.value())
        rolled = roll_loops(self.trace)
        covered = self.tg.merge_trace(self.trace, rolled)
        self._covered_streak = self._covered_streak + 1 if covered else 0
        if self._covered_streak >= self.min_covered:
            if self.gp is None or self.gp.version != self.tg.version:
                var_avals = {vid: v.aval for vid, v in self.vars.items()}
                self.gp = GraphProgram(self.tg, var_avals)
                self.stats["graph_versions"] += 1
            if self.mode != SKELETON:
                self.stats["transitions"] += 1
            self.mode = SKELETON
        else:
            self.mode = TRACING

    # ------------------------------------------------------------------
    # op recording (called from ops._call_op)
    # ------------------------------------------------------------------
    def record_op(self, name: str, args, attrs_t, loc):
        refs: List[Any] = []
        vals: List[Any] = []
        feed_avals: List[Tuple[int, Aval]] = []
        feed_values: Dict[int, Any] = {}
        ordinal = len(self.trace.entries)
        for pos, (kind, a) in enumerate(args):
            if kind == "tensor":
                t = a
                if t.ref is None or t._iter != self.iter_id:
                    # value from outside this iteration — becomes a feed
                    v = t._eager if t._eager is not None else t.value()
                    refs.append(FeedRef(ordinal, pos))
                    feed_avals.append((pos, Aval.of(v)))
                    feed_values[pos] = v
                    self._feed_log[(ordinal, pos)] = v
                    vals.append(v)
                else:
                    refs.append(t.ref)
                    vals.append(t._eager)
            elif kind == "const":
                refs.append(Const(a))
                vals.append(a)
            else:  # feed
                refs.append(FeedRef(ordinal, pos))
                feed_avals.append((pos, Aval.of(a)))
                feed_values[pos] = a
                self._feed_log[(ordinal, pos)] = a
                vals.append(a)

        entry = TraceEntry(op_name=name, attrs=attrs_t, location=loc,
                           input_refs=tuple(refs), out_avals=(),
                           feed_avals=tuple(feed_avals))

        if self.mode == SKELETON:
            try:
                avals, uid = self.walker.advance(entry, ordinal, feed_values)
            except DivergenceError:
                self._fallback_replay()
                # placeholders now hold concrete values — rebuild the args
                vals = self._vals_for_entry(entry, ordinal)
                return self._exec_eager(entry, ordinal, vals)
            entry.out_avals = avals
            self.trace.add_entry(entry)
            outs = tuple(
                TerraTensor(Ref(ordinal, oi), avals[oi], engine=self,
                            iter_id=self.iter_id)
                for oi in range(len(avals)))
            for oi, t in enumerate(outs):
                self._tensors[(ordinal, oi)] = t
            if self.walker.boundary_reached is not None:
                seg = self.walker.boundary_reached
                self.walker.boundary_reached = None
                self.walker.seg_idx = seg + 1
                if not self._path_mode:
                    self._dispatch_through(seg)
            return outs if len(outs) > 1 else outs[0]

        return self._exec_eager(entry, ordinal, vals)

    def _vals_for_entry(self, entry: TraceEntry, ordinal: int):
        vals = []
        for pos, r in enumerate(entry.input_refs):
            if isinstance(r, Ref):
                vals.append(self._vals[(r.entry, r.out_idx)])
            elif isinstance(r, FeedRef):
                vals.append(self._feed_log[(ordinal, pos)])
            elif isinstance(r, VarRef):
                vals.append(self.runner.store[r.var_id])
            elif isinstance(r, Const):
                vals.append(r.value)
        return vals

    def _exec_eager(self, entry: TraceEntry, ordinal: int, vals):
        out = ops_mod.OPS[entry.op_name].impl(*vals, **dict(entry.attrs))
        outs = out if isinstance(out, tuple) else (out,)
        entry.out_avals = tuple(Aval.of(o) for o in outs)
        self.trace.add_entry(entry)
        ts = tuple(TerraTensor(Ref(ordinal, oi), entry.out_avals[oi],
                               eager=o, engine=self, iter_id=self.iter_id)
                   for oi, o in enumerate(outs))
        for oi, t in enumerate(ts):
            self._tensors[(ordinal, oi)] = t
            self._vals[(ordinal, oi)] = outs[oi]
        return ts if len(ts) > 1 else ts[0]

    # ------------------------------------------------------------------
    # segment dispatch (co-execution)
    # ------------------------------------------------------------------
    def _dispatch_through(self, seg_idx: int):
        gp, walker = self.gp, self.walker
        for si in range(self._dispatched_through + 1, seg_idx + 1):
            sp = gp.seg_progs[si]
            feeds = []
            for (uid, pos, aval) in sp.feed_keys:
                v = walker.feed_vals.get((uid, pos))
                if v is None:
                    v = jnp.zeros(aval.shape, aval.dtype)
                feeds.append(v)
            sels = np.array([walker.sels.get(uid, 0) for uid, slot in
                             sorted(gp.selector_slot.items(),
                                    key=lambda kv: kv[1])], dtype=np.int32)
            trips = np.array([walker.trips.get(uid, 0) for uid, slot in
                              sorted(gp.trip_slot.items(),
                                     key=lambda kv: kv[1])], dtype=np.int32)
            futures = {k: Future() for k in sp.fetch_keys}
            self._fetch_futures.update(futures)
            store = self.runner.store
            iter_env = self._iter_env

            def run(sp=sp, feeds=tuple(feeds), sels=sels, trips=trips,
                    futures=futures):
                var_in = tuple(store[v] for v in sp.var_reads)
                carries = tuple(iter_env[k] for k in sp.carries_in)
                try:
                    var_out, fetches, carries_out = sp.fn(
                        var_in, feeds, sels, trips, carries)
                    jax.block_until_ready(var_out + fetches + carries_out)
                except Exception as e:      # propagate into futures
                    for f in futures.values():
                        if not f.done():
                            f.set_exception(e)
                    raise
                for vid, v in zip(sp.var_writes, var_out):
                    store[vid] = v
                for k, v in zip(sp.carries_out, carries_out):
                    iter_env[k] = v
                for k, v in zip(sp.fetch_keys, fetches):
                    futures[k].set_result(v)

            self.runner.submit(run)
            self.stats["segments_dispatched"] += 1
            self._dispatched_through = si
        self._ordinal_at_dispatch = len(self.trace.entries)

    # ------------------------------------------------------------------
    # materialization (Output Fetching)
    # ------------------------------------------------------------------
    def materialize(self, t: TerraTensor):
        if t._eager is not None:
            return t._eager
        ref = t.ref
        if isinstance(ref, VarRef):
            return self.variable_value(self.vars[ref.var_id])
        if t._iter != self.iter_id or self.mode != SKELETON:
            # stale placeholder from an earlier iteration
            raise RuntimeError("placeholder escaped its iteration without "
                               "being fetch-marked")
        if self._iter_open:
            self.trace.events.append(SyncMarker(ref))
        self.trace.fetches.append(ref)
        try:
            uid, oi = self.walker.uid_of(ref)
        except ReplayRequired:
            self._recover_value()
            return t._eager
        node = self.tg.nodes[uid]
        if self._path_mode:
            # chains output every produced value — no replay needed even
            # for never-before-seen fetches (annotate for future graphs)
            node.fetch_idxs.add(oi)
            fut = self._chain_futures.get((ref.entry, ref.out_idx))
            if fut is None and self._iter_open:
                self._dispatch_chain()
                fut = self._chain_futures.get((ref.entry, ref.out_idx))
            if fut is not None:
                t0 = time.perf_counter()
                if self.runner.lazy:
                    self.runner.run_pending_now()
                v = fut.result()
                self.stats["py_stall_time"] += time.perf_counter() - t0
                t._eager = v
                return v
            self._recover_value()
            return t._eager
        if oi not in node.fetch_idxs:
            # never-before-seen fetch: annotate & recover via replay
            node.fetch_idxs.add(oi)
            if self._iter_open:
                node.sync_after = True
            self.tg.version += 1
            self._recover_value()
            return t._eager
        fut = self._fetch_futures.get((uid, oi))
        if fut is None and self._iter_open:
            # fetch gates Python mid-segment (e.g. inside a branch region):
            # switch to path-specialized dispatch — jit the exact walked
            # chain instead of replaying eagerly (DESIGN.md §2)
            self._dispatch_chain()
            fut = self._chain_futures.get((ref.entry, ref.out_idx))
        if fut is None:
            self._recover_value()
            return t._eager
        t0 = time.perf_counter()
        if self.runner.lazy:
            self.runner.run_pending_now()
        v = fut.result()
        self.stats["py_stall_time"] += time.perf_counter() - t0
        t._eager = v
        return v

    def note_fetch(self, t: TerraTensor):
        """Record a fetch point observed while the value was already eager
        (tracing phase, or post-replay).  Paper §4.2: fetch points are
        captured during tracing and annotated in the TraceGraph."""
        ref = t.ref
        if not isinstance(ref, Ref):
            return
        if t._iter == self.iter_id and self._iter_open:
            self.trace.events.append(SyncMarker(ref))
            self.trace.fetches.append(ref)
        elif t._iter == self.iter_id and not self._iter_open:
            # materialized after the iteration closed (e.g. the returned
            # loss): annotate the merged node as a non-gating fetch
            ord_map = getattr(self.tg, "last_ord_to_uid", None)
            if ord_map and ref.entry in ord_map:
                n = self.tg.nodes[ord_map[ref.entry]]
                oi = (n.body.out_slot_for(ref, ()) if n.kind == "loop"
                      else ref.out_idx)
                if oi not in n.fetch_idxs:
                    n.fetch_idxs.add(oi)
                    self.tg.version += 1

    # ------------------------------------------------------------------
    # path-specialized dispatch: jitted linear chain of the exact walked
    # ops (selectors already resolved by walking), used when a gating
    # fetch is not at a top-level segment boundary
    # ------------------------------------------------------------------
    def _dispatch_chain(self):
        if not self._path_mode:
            self._path_mode = True
            self._chain_env = {}
            # chain picks up after whatever segments already dispatched
            self._chain_start = getattr(self, "_ordinal_at_dispatch", 0)
        start = self._chain_start
        end = len(self.trace.entries)
        if end <= start:
            return
        entries = self.trace.entries[start:end]

        key_parts = []
        ext_plan = []            # ('chain', e, oi) | ('seg', uid, oi)
        ext_index: Dict[Tuple, int] = {}
        feeds = []
        var_ids = []
        var_index: Dict[int, int] = {}
        arg_plans = []
        for local, e in enumerate(entries):
            plan = []
            for pos, r in enumerate(e.input_refs):
                if isinstance(r, Ref) and r.entry >= start:
                    plan.append(("i", r.entry - start, r.out_idx))
                elif isinstance(r, Ref):
                    k = ("r", r.entry, r.out_idx)
                    if k not in ext_index:
                        ext_index[k] = len(ext_plan)
                        uid = self.walker.ord_to_uid.get(r.entry)
                        if (r.entry, r.out_idx) in self._chain_env or \
                                uid is None:
                            ext_plan.append(("chain", r.entry, r.out_idx))
                        else:
                            n = self.tg.nodes[uid]
                            oi = (n.body.out_slot_for(r, ())
                                  if n.kind == "loop" else r.out_idx)
                            ext_plan.append(("seg", uid, oi))
                    plan.append(("x", ext_index[k]))
                elif isinstance(r, FeedRef):
                    plan.append(("f", len(feeds)))
                    feeds.append(self._feed_log[(start + local, pos)])
                elif isinstance(r, VarRef):
                    if r.var_id not in var_index:
                        var_index[r.var_id] = len(var_ids)
                        var_ids.append(r.var_id)
                    plan.append(("v", var_index[r.var_id]))
                else:
                    plan.append(("c", r.value))
            arg_plans.append(tuple(plan))
            key_parts.append((e.op_name, e.attrs, e.location,
                              tuple((p[0],) + tuple(p[1:]) for p in plan)))
        key = (start == 0, tuple(key_parts))

        fn = self._chain_cache.get(key)
        if fn is None:
            impls = [ops_mod.OPS[e.op_name].impl for e in entries]
            attrs = [dict(e.attrs) for e in entries]
            n_outs = [len(e.out_avals) for e in entries]
            plans = list(arg_plans)

            def chain_fn(var_vals, feed_vals, ext_vals):
                env: Dict[Tuple[int, int], Any] = {}
                flat_out = []
                for j, impl in enumerate(impls):
                    vals = []
                    for p in plans[j]:
                        if p[0] == "i":
                            vals.append(env[(p[1], p[2])])
                        elif p[0] == "x":
                            vals.append(ext_vals[p[1]])
                        elif p[0] == "f":
                            vals.append(feed_vals[p[1]])
                        elif p[0] == "v":
                            vals.append(var_vals[p[1]])
                        else:
                            vals.append(p[1])
                    out = impl(*vals, **attrs[j])
                    outs = out if isinstance(out, tuple) else (out,)
                    for oi, v in enumerate(outs):
                        env[(j, oi)] = v
                    flat_out.extend(outs)
                return tuple(flat_out)

            fn = jax.jit(chain_fn)
            self._chain_cache[key] = fn

        # futures for every produced value
        produced = []
        futures = {}
        for j, e in enumerate(entries):
            for oi in range(len(e.out_avals)):
                futures[(start + j, oi)] = Future()
                produced.append((start + j, oi))
        self._chain_futures.update(futures)

        assigns = {vid: ref for vid, ref in self.trace.var_assigns.items()
                   if isinstance(ref, Ref) and start <= ref.entry < end}
        store = self.runner.store
        iter_env = self._iter_env
        chain_env = self._chain_env

        def run(fn=fn, var_ids=tuple(var_ids), feeds=tuple(feeds),
                ext_plan=tuple(ext_plan), futures=futures,
                assigns=assigns):
            var_vals = tuple(store[v] for v in var_ids)
            exts = tuple(chain_env[(p[1], p[2])] if p[0] == "chain"
                         else iter_env[(p[1], p[2])] for p in ext_plan)
            try:
                outs = fn(var_vals, feeds, exts)
                jax.block_until_ready(outs)
            except Exception as exc:        # noqa: BLE001
                for f in futures.values():
                    if not f.done():
                        f.set_exception(exc)
                raise
            for (ordv, v) in zip(produced, outs):
                chain_env[ordv] = v
                futures[ordv].set_result(v)
            for vid, ref in assigns.items():
                store[vid] = chain_env[(ref.entry, ref.out_idx)]

        self.runner.submit(run)
        self.stats["segments_dispatched"] += 1
        self._chain_start = end

    def _recover_value(self):
        """Replay to materialize values the graph did not output.  Inside an
        open iteration this is the divergence fallback; after end_iteration
        it replays and re-commits the final variable bindings."""
        if self._iter_open:
            self._fallback_replay()
            return
        self._fallback_replay()
        for vid, ref in self.trace.var_assigns.items():
            self.runner.store[vid] = self._vals[(ref.entry, ref.out_idx)]

    # ------------------------------------------------------------------
    # divergence fallback (paper: cancel GraphRunner, back to tracing)
    # ------------------------------------------------------------------
    def _fallback_replay(self):
        self.stats["replays"] += 1
        self.stats["transitions"] += 1
        self.runner.drain()
        self.runner._open = False
        # cancel this iteration's effects: restore variable snapshot
        if self._snapshot_slot:
            self.runner.store.clear()
            self.runner.store.update(self._snapshot_slot)
        # eager replay of the validated prefix (DL ops only — Python side
        # effects are NOT re-run)
        self._vals.clear()
        for ordinal, entry in enumerate(self.trace.entries):
            vals = []
            for pos, r in enumerate(entry.input_refs):
                if isinstance(r, Ref):
                    vals.append(self._vals[(r.entry, r.out_idx)])
                elif isinstance(r, FeedRef):
                    vals.append(self._feed_log[(ordinal, pos)])
                elif isinstance(r, VarRef):
                    vals.append(self.runner.store[r.var_id])
                elif isinstance(r, Const):
                    vals.append(r.value)
            out = ops_mod.OPS[entry.op_name].impl(*vals, **dict(entry.attrs))
            outs = out if isinstance(out, tuple) else (out,)
            for oi, v in enumerate(outs):
                self._vals[(ordinal, oi)] = v
                t = self._tensors.get((ordinal, oi))
                if t is not None:
                    t._eager = v
        self.mode = TRACING
        self._covered_streak = 0
        self.walker = None
        self._iter_env = {}

    # ------------------------------------------------------------------
    # variables
    # ------------------------------------------------------------------
    def _ensure_var(self, var: Variable):
        if var.var_id not in self.vars:
            self.vars[var.var_id] = var
            if var.var_id not in self.runner.store:
                self.runner.store[var.var_id] = var._value

    def read_variable(self, var: Variable) -> TerraTensor:
        self._ensure_var(var)
        bound = self._var_binding.get(var.var_id)
        if bound is not None:
            return bound
        if self.mode == SKELETON:
            return TerraTensor(VarRef(var.var_id), var.aval, engine=self,
                               iter_id=self.iter_id)
        # eager modes read the committed store value
        return TerraTensor(VarRef(var.var_id), var.aval,
                           eager=self.runner.store.get(var.var_id,
                                                       var._value),
                           engine=self, iter_id=self.iter_id)

    def assign_variable(self, var: Variable, value):
        self._ensure_var(var)
        if not isinstance(value, TerraTensor):
            value = ops_mod.identity(value)
        if not isinstance(value.ref, Ref) or value._iter != self.iter_id:
            value = ops_mod.identity(value)
        self.trace.events.append(VarAssign(var.var_id, value.ref))
        self.trace.var_assigns[var.var_id] = value.ref
        self._var_binding[var.var_id] = value

    def variable_value(self, var: Variable):
        self._ensure_var(var)
        bound = self._var_binding.get(var.var_id)
        if bound is not None and bound._eager is not None:
            return bound._eager
        self.runner.drain()
        return self.runner.store[var.var_id]

    def variable_read_ref(self, var: Variable):
        return VarRef(var.var_id)

    # ------------------------------------------------------------------
    # tape support
    # ------------------------------------------------------------------
    def tape_mark(self) -> int:
        return len(self.trace.entries)

    def tape_slice(self, start: int):
        entries = [(i, e) for i, e in enumerate(self.trace.entries[start:],
                                                start=start)]

        def tensors_of(ordinal):
            e = self.trace.entries[ordinal]
            return [self._tensors[(ordinal, oi)]
                    for oi in range(len(e.out_avals))]
        return entries, tensors_of

    def tensors_for_input_slots(self, ordinal: int, entry: TraceEntry):
        out = []
        for pos, r in enumerate(entry.input_refs):
            if isinstance(r, Ref):
                out.append(self._tensors[(r.entry, r.out_idx)])
            elif isinstance(r, FeedRef):
                out.append(self._feed_log[(ordinal, pos)])
            elif isinstance(r, VarRef):
                var = self.vars[r.var_id]
                t = TerraTensor(VarRef(r.var_id), var.aval, engine=self,
                                iter_id=self.iter_id)
                if self.mode != SKELETON:
                    t._eager = self.runner.store.get(r.var_id, var._value)
                out.append(t)
            elif isinstance(r, Const):
                out.append(r.value)
        return out

    # ------------------------------------------------------------------
    # RNG
    # ------------------------------------------------------------------
    def next_rng_key(self):
        k = jax.random.fold_in(jax.random.fold_in(self._base_key,
                                                  self.iter_id),
                               self._rng_count)
        self._rng_count += 1
        return k

    def close(self):
        self.runner.drain()
        self.runner.stop()
