"""Symbolic graph generation: TraceGraph -> executable jitted segments.

The GraphGenerator (paper §4.2) converts the merged TraceGraph into the
symbolic graph the GraphRunner executes:

* each TraceGraph op node -> its registered JAX impl,
* fork nodes -> ``jax.lax.switch`` over a *Case Select* input
  (``selectors[slot]``) provided by the PythonRunner,
* rolled loop nodes -> unrolled when every collected trace agrees on the
  trip count (the paper's unrolling optimization), otherwise a
  ``jax.lax.fori_loop`` whose trip count is a *Loop Cond* input,
* feed points -> *Input Feeding*: function inputs filled by the
  PythonRunner each iteration,
* fetch points -> *Output Fetching*: function outputs the PythonRunner
  materializes on demand,
* Variables -> resource inputs/outputs threaded through the GraphRunner's
  device-resident store.

The program is cut into *segments* at gating fetch points (DESIGN.md §2 —
the XLA adaptation of TF's mid-graph blocking ops); values produced in one
segment and consumed in a later one are carried through explicit
carry inputs/outputs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ops as ops_mod
from repro.core.casing import NodeItem, Structure, SwitchItem
from repro.core.passes.analysis import FoldedConst
from repro.core.trace import Aval
from repro.core.tracegraph import TGNode, TraceGraph

Key = Tuple[int, int]           # (uid, out_idx) — a produced value
FeedKey = Tuple[int, int]       # (uid, arg_pos) — an Input Feeding slot


def _zeros(aval: Aval):
    return jnp.zeros(aval.shape, aval.dtype)


@dataclasses.dataclass
class SegProg:
    index: int
    items: list
    var_reads: List[int]
    var_writes: List[int]
    carries_in: List[Key]
    carries_out: List[Key]
    feed_keys: List[Tuple[int, int, Aval]]
    fetch_keys: List[Key]
    fn: Any = None                   # jitted callable
    # donation split of var_reads: ``don_var_ids`` buffers are donated to
    # XLA (safe only for intermediates produced earlier in the same
    # iteration — see _analyze_donation / DESIGN.md §4.2)
    don_var_ids: List[int] = dataclasses.field(default_factory=list)
    keep_var_ids: List[int] = dataclasses.field(default_factory=list)
    signature: Any = None            # structural key for the segment cache
    plan: "DispatchPlan" = None      # precomputed dispatch layout (§4.4)


@dataclasses.dataclass(frozen=True)
class DispatchPlan:
    """Flat per-segment dispatch layout, precomputed at compile time
    (DESIGN.md §4.4).

    Everything ``SegmentDispatcher.dispatch_through`` needs per iteration is
    baked into tuples here — selector/trip slot orders (fork/loop uids in
    globally assigned slot order), the Input Feeding layout, and the
    variable read order split into the donated and retained halves — so the
    per-iteration hot path is straight array fills with no sorting and no
    dict probing."""
    sel_uids: Tuple[int, ...]        # fork uids in selector-slot order
    trip_uids: Tuple[int, ...]       # loop uids in trip-slot order
    feed_keys: Tuple[Tuple[int, int, Aval], ...]
    don_var_ids: Tuple[int, ...]
    keep_var_ids: Tuple[int, ...]
    var_writes: Tuple[int, ...]
    carries_in: Tuple[Key, ...]
    carries_out: Tuple[Key, ...]
    fetch_keys: Tuple[Key, ...]
    kernel_ops: Tuple[str, ...] = ()  # Pallas-substituted ops in the segment
    #                                   (pass metadata for profiling events)


class GraphProgram:
    """Executable artifact for one version of one family's TraceGraph.

    ``family_key`` is the shape-class signature the program was generated
    under (DESIGN.md §8); sibling shape classes get sibling GraphPrograms,
    and structurally identical segments are shared between them through
    the engine-lifetime SegmentCache (canonical-uid signatures)."""

    def __init__(self, tg: TraceGraph, var_avals: Dict[int, Aval],
                 jit_each: bool = True, seg_cache=None, family_key=None,
                 opt=None):
        # ``tg`` stays the Walker-facing graph (validation, stamps,
        # divergence); ``otg`` is what this program COMPILES — the pass
        # pipeline's rewrite clone when optimization is on (uids
        # preserved, so walker-collected selector/trip/feed values key
        # straight into the optimized plans), otherwise tg itself.
        self.tg = tg
        self.opt = opt
        self.otg = opt.otg if opt is not None else tg
        self.version = tg.version
        self.opt_token = None       # set by the coordinator (passes cache)
        self.family_key = (family_key if family_key is not None
                           else tg.family_key)
        self.structure = Structure(self.otg)
        self.var_avals = var_avals
        self._switch_specs: Dict[Tuple[int, int], Tuple] = {}
        self._dead = opt.dead if opt is not None else ()
        self._alias = opt.alias_nodes if opt is not None else {}
        self.folded_feeds = opt.folded if opt is not None else {}

        otg_nodes = self.otg.nodes
        # ---- slot assignment (Case Select / Loop Cond inputs) -----------
        self.selector_slot: Dict[int, int] = {}
        self.trip_slot: Dict[int, int] = {}
        for item in self.structure.iter_items():
            if isinstance(item, SwitchItem):
                self.selector_slot.setdefault(item.fork_uid,
                                              len(self.selector_slot))
            elif isinstance(item, NodeItem):
                n = otg_nodes[item.uid]
                if n.kind == "loop" and len(n.trips) != 1:
                    self.trip_slot.setdefault(item.uid, len(self.trip_slot))
        self.n_selectors = len(self.selector_slot)
        self.n_trips = len(self.trip_slot)

        # ---- global consumer map (used for switch-region exports) --------
        # effective sources: dead nodes consume nothing, alias nodes
        # consume their representative (passes/__init__.OptResult)
        self.consumers: Dict[Key, set] = {}
        for uid, n in otg_nodes.items():
            if n.kind not in ("op", "loop"):
                continue
            for s in self._eff_srcs(n):
                if s[0] == "node":
                    self.consumers.setdefault((s[1], s[2]), set()).add(uid)

        # ---- per-segment IO analysis -------------------------------------
        segs = list(self.structure.segments)
        if opt is not None and opt.drop_empty_trailing and segs \
                and not segs[-1]:
            segs.pop()              # coalesce pass: no-op trailing segment
        produced_in: Dict[Key, int] = {}
        consumed: List[set] = [set() for _ in segs]
        for si, seg in enumerate(segs):
            for uid in self.structure.uids_in(seg):
                n = otg_nodes[uid]
                if uid in self._dead:
                    continue
                for oi in range(self._n_out(n)):
                    produced_in[(uid, oi)] = si
                for s in self._eff_srcs(n):
                    if s[0] == "node":
                        consumed[si].add((s[1], s[2]))

        self.seg_progs: List[SegProg] = []
        self.feed_slot: Dict[FeedKey, Tuple[int, int]] = {}
        self.fetch_slot: Dict[Key, Tuple[int, int]] = {}

        feed_moved = opt.feed_moved if opt is not None else {}
        for si, seg in enumerate(segs):
            uids = self.structure.uids_in(seg)
            var_reads, var_writes = set(), set()
            feed_keys: List[Tuple[int, int, Aval]] = []
            feed_consumers: List[FeedKey] = []
            fetch_keys: List[Key] = []
            for uid in uids:
                n = otg_nodes[uid]
                if uid in self._dead:
                    continue
                if uid not in self._alias:
                    for pos, s in enumerate(n.srcs):
                        if s[0] == "var":
                            var_reads.add(s[1])
                        elif s[0] == "feed":
                            # dispatch keys follow the Walker's collection
                            # slot — the ORIGINAL consumer when kernel
                            # substitution moved the source
                            fk = feed_moved.get((uid, pos), (uid, pos))
                            feed_keys.append((fk[0], fk[1], s[1]))
                            feed_consumers.append((uid, pos))
                for (vid, oi) in n.var_assigns:
                    var_writes.add(vid)
                if n.kind == "loop" and n.body is not None:
                    var_writes.update(n.body.var_binds.keys())
                for oi in sorted(n.fetch_idxs):
                    fetch_keys.append((uid, oi))
            later = set().union(*consumed[si + 1:]) if si + 1 < len(segs) else set()
            carries_in = sorted(k for k in consumed[si]
                                if produced_in.get(k, si) < si)
            carries_out = sorted(k for k in later
                                 if produced_in.get(k, -1) == si)
            for j, ck in enumerate(feed_consumers):
                self.feed_slot[ck] = (si, j)    # exec-time lookup key
            for j, k in enumerate(fetch_keys):
                self.fetch_slot[k] = (si, j)
            sp = SegProg(si, seg, sorted(var_reads | var_writes),
                         sorted(var_writes), carries_in, carries_out,
                         feed_keys, fetch_keys)
            self.seg_progs.append(sp)

        # ---- donation analysis + compilation (through the segment cache) --
        self._analyze_donation()
        self.donatable_var_ids = {v for sp in self.seg_progs
                                  for v in sp.don_var_ids}
        # ---- dispatch plans: bake the per-iteration layout (§4.4) --------
        sel_uids = tuple(u for u, _ in sorted(self.selector_slot.items(),
                                              key=lambda kv: kv[1]))
        trip_uids = tuple(u for u, _ in sorted(self.trip_slot.items(),
                                               key=lambda kv: kv[1]))
        for sp in self.seg_progs:
            kernel_ops = tuple(
                otg_nodes[uid].op_name
                for uid in self.structure.uids_in(sp.items)
                if uid not in self._dead and uid not in self._alias
                and otg_nodes[uid].op_name.startswith("kernel."))
            sp.plan = DispatchPlan(
                sel_uids, trip_uids, tuple(sp.feed_keys),
                tuple(sp.don_var_ids), tuple(sp.keep_var_ids),
                tuple(sp.var_writes), tuple(sp.carries_in),
                tuple(sp.carries_out), tuple(sp.fetch_keys), kernel_ops)
        for sp in self.seg_progs:
            if seg_cache is not None:
                from repro.core.executor.segment_cache import \
                    segment_signature
                # signatures are computed strictly POST-pass (over the
                # optimized graph + dead/alias/fold state), so a segment
                # whose optimized form is unchanged is a cache hit even
                # when coalescing or folding reshaped its neighbours
                sp.signature = (jit_each, segment_signature(self, sp))
                persist = getattr(seg_cache, "persist", None)
                if persist is not None:
                    # warm boot (DESIGN.md §14): consult the on-disk AOT
                    # executable before compiling; a fresh compile is
                    # serialized back into the store
                    sp.fn = seg_cache.get_or_build(
                        sp.signature,
                        lambda sp=sp: persist.build_segment(
                            self, sp, jit_each),
                        loader=lambda sp=sp: persist.load_segment(
                            self, sp, jit_each))
                else:
                    sp.fn = seg_cache.get_or_build(
                        sp.signature,
                        lambda sp=sp: self._compile_segment(sp, jit_each))
            else:
                sp.fn = self._compile_segment(sp, jit_each)

        # Walker-facing boundary set (optimized sync flags) and the value
        # keys dispatched segments publish to iter_env (chain dispatch
        # checks ext availability against this, dispatch.py)
        self.boundary_uids = {uid for uid, n in otg_nodes.items()
                              if n.sync_after}
        self.published = {k for sp in self.seg_progs for k in sp.carries_out}

    # ------------------------------------------------------------------
    def _node(self, uid: int) -> TGNode:
        return self.otg.nodes[uid]

    def _eff_srcs(self, n: TGNode) -> Tuple:
        if self.opt is not None:
            return self.opt.eff_srcs(n)
        return n.srcs

    # ------------------------------------------------------------------
    def _final_var_products(self, sp: SegProg) -> Dict[int, Optional[Key]]:
        """vid -> (uid, oi) producing its final value in this segment, or
        None when the producer is ambiguous / potentially buffer-aliased
        (switch phi outputs)."""
        prods: Dict[int, Optional[Key]] = {}
        for item in sp.items:
            if isinstance(item, NodeItem):
                n = self._node(item.uid)
                if item.uid in self._dead:
                    continue
                alias = self._alias.get(item.uid)
                if n.kind == "loop" and n.body is not None:
                    for vid, slot in n.body.var_binds.items():
                        prods[vid] = (n.uid, slot)
                for vid, oi in n.var_assigns:
                    # an alias node's write is backed by its
                    # representative's buffer, which may also travel as a
                    # cross-segment carry THIS segment's escape set cannot
                    # see — treat like a switch phi: never donatable
                    prods[vid] = None if alias is not None else (n.uid, oi)
            else:       # SwitchItem: per-path producers; lax.switch outputs
                _, interior_vars, _ = self.switch_spec(item, sp)
                for vid in interior_vars:
                    prods[vid] = None
        return prods

    def _analyze_donation(self) -> None:
        """Static per-segment donation eligibility for variable buffers.

        A segment may donate ``var_in[v]`` only when (a) it also writes v
        (so XLA has an output to alias the buffer into), and (b) the buffer
        it will read is an *intermediate* of this same iteration — produced
        by an earlier segment — whose sole owner is the variable store.
        Iteration-start buffers are never donatable: the divergence snapshot
        holds them for rollback.  A producing value that is also a fetch
        output or a carry (or a switch phi, or shared by two variables)
        escapes the store, so it is retained and never donated either.
        """
        # vid -> retained?  (present only once some segment wrote the vid)
        last_write: Dict[int, bool] = {}
        for sp in self.seg_progs:
            writes = set(sp.var_writes)
            don = [v for v in sp.var_reads
                   if v in writes and last_write.get(v) is False]
            sp.don_var_ids = don
            don_set = set(don)
            sp.keep_var_ids = [v for v in sp.var_reads if v not in don_set]

            prods = self._final_var_products(sp)
            seen_products: Dict[Key, int] = {}
            escaped = set(sp.fetch_keys) | set(sp.carries_out)
            for v in sp.var_writes:
                p = prods.get(v)
                retained = p is None or p in escaped
                if p is not None:
                    if p in seen_products:      # two vars share one buffer
                        retained = True
                        last_write[seen_products[p]] = True
                    seen_products[p] = v
                last_write[v] = retained

    # ------------------------------------------------------------------
    def _n_out(self, n: TGNode) -> int:
        if n.kind == "loop":
            return len(n.body.carries)
        return len(n.out_avals)

    # ------------------------------------------------------------------
    def _compile_segment(self, sp: SegProg, jit_each: bool):
        def seg_fn(don_var_in: tuple, keep_var_in: tuple, feeds: tuple,
                   sels, trips, carries_in: tuple):
            env: Dict[Key, Any] = dict(zip(sp.carries_in, carries_in))
            var_start = dict(zip(sp.don_var_ids, don_var_in))
            var_start.update(zip(sp.keep_var_ids, keep_var_in))
            ctx = {
                "env": env,
                "var_start": var_start,
                "var_env": dict(var_start),
                "fetch_buf": {},
                "feeds": feeds,
                "sels": sels,
                "trips": trips,
            }
            self._interp(sp.items, sp, ctx)
            var_out = tuple(ctx["var_env"][v] for v in sp.var_writes)
            fetches = tuple(ctx["fetch_buf"][k] for k in sp.fetch_keys)
            carries_out = tuple(env[k] for k in sp.carries_out)
            return var_out, fetches, carries_out

        # arg 0 carries exactly the donation-eligible buffers (may be empty)
        return jax.jit(seg_fn, donate_argnums=(0,)) if jit_each else seg_fn

    # ------------------------------------------------------------------
    def _resolve(self, src, sp: SegProg, ctx, uid: int, pos: int):
        kind = src[0]
        if kind == "node":
            return ctx["env"][(src[1], src[2])]
        if kind == "feed":
            si, j = self.feed_slot[(uid, pos)]
            assert si == sp.index
            return ctx["feeds"][j]
        if kind == "var":
            return ctx["var_start"][src[1]]
        if kind == "const":
            v = src[1]
            # a constant-folded feed (passes/feed_fold.py) bakes its value
            # behind a hashable wrapper; unwrap at compile time
            return v.value if isinstance(v, FoldedConst) else v
        raise ValueError(f"unresolvable src {src}")

    # ------------------------------------------------------------------
    def _interp(self, items, sp: SegProg, ctx):
        for item in items:
            if isinstance(item, NodeItem):
                self._exec_node(self._node(item.uid), sp, ctx)
            else:
                self._exec_switch(item, sp, ctx)

    # ------------------------------------------------------------------
    def _exec_node(self, n: TGNode, sp: SegProg, ctx):
        if n.uid in self._dead:
            return                  # DCE: computation skipped, CFG intact
        alias = self._alias.get(n.uid)
        if alias is not None:
            # CSE alias node: outputs are the representative's values;
            # fetch and Variable annotations still apply to them
            outs = tuple(ctx["env"][k] for k in alias)
            for oi, v in enumerate(outs):
                ctx["env"][(n.uid, oi)] = v
            for oi in n.fetch_idxs:
                ctx["fetch_buf"][(n.uid, oi)] = outs[oi]
            for vid, oi in n.var_assigns:
                ctx["var_env"][vid] = outs[oi]
            return
        if n.kind == "loop":
            self._exec_loop(n, sp, ctx)
            return
        vals = [self._resolve(s, sp, ctx, n.uid, pos)
                for pos, s in enumerate(n.srcs)]
        out = ops_mod.OPS[n.op_name].impl(*vals, **dict(n.attrs))
        outs = out if isinstance(out, tuple) else (out,)
        for oi, v in enumerate(outs):
            ctx["env"][(n.uid, oi)] = v
        for oi in n.fetch_idxs:
            ctx["fetch_buf"][(n.uid, oi)] = outs[oi]
        for vid, oi in n.var_assigns:
            ctx["var_env"][vid] = outs[oi]

    # ------------------------------------------------------------------
    def _exec_loop(self, n: TGNode, sp: SegProg, ctx):
        body = n.body
        n_car = len(body.carries)
        outer = [self._resolve(s, sp, ctx, n.uid, pos)
                 for pos, s in enumerate(n.srcs)]
        init = tuple(outer[:n_car])
        invs = tuple(outer[n_car:])

        def run_body(carry):
            lenv: Dict[Tuple[int, int], Any] = {}
            for j, e in enumerate(body.entries):
                vals = []
                for s in e.srcs_local:
                    if s[0] == "carry":
                        vals.append(carry[s[1]])
                    elif s[0] == "inv":
                        vals.append(invs[s[1]])
                    elif s[0] == "node":
                        vals.append(lenv[(s[1], s[2])])
                    elif s[0] == "const":
                        vals.append(s[1])
                    elif s[0] == "var":
                        vals.append(ctx["var_start"][s[1]])
                    else:
                        raise ValueError(f"bad body src {s}")
                out = ops_mod.OPS[e.op_name].impl(*vals, **dict(e.attrs))
                outs = out if isinstance(out, tuple) else (out,)
                for oi, v in enumerate(outs):
                    lenv[(j, oi)] = v
            return tuple(lenv[prod] for (_, prod) in body.carries)

        if len(n.trips) == 1:
            # constant trip count across all traces: unroll (paper's opt.)
            carry = init
            for _ in range(next(iter(n.trips))):
                carry = run_body(carry)
        else:
            slot = self.trip_slot[n.uid]
            trips_v = ctx["trips"][slot]
            carry = jax.lax.fori_loop(
                0, trips_v, lambda i, c: run_body(c), init)
        for k in range(n_car):
            ctx["env"][(n.uid, k)] = carry[k]
        for oi in n.fetch_idxs:
            ctx["fetch_buf"][(n.uid, oi)] = carry[oi]
        for vid, slot_k in body.var_binds.items():
            ctx["var_env"][vid] = carry[slot_k]

    # ------------------------------------------------------------------
    def _aval_of(self, key: Key) -> Aval:
        n = self._node(key[0])
        if n.kind == "loop":
            return n.body.entries[n.body.carries[key[1]][1][0]].out_avals[
                n.body.carries[key[1]][1][1]]
        return n.out_avals[key[1]]

    def switch_spec(self, item: SwitchItem, sp: SegProg) -> Tuple:
        """Phi spec of a switch region: interior fetches (union over
        branches) + vars assigned in any branch + interior values consumed
        OUTSIDE this region (later same-path-only regions or later
        segments) — exported with zeros on non-producing branches, which is
        sound because only the producing path ever consumes them.  Shared
        by segment execution and the structural segment signature."""
        memo_key = (item.fork_uid, sp.index)
        spec = self._switch_specs.get(memo_key)
        if spec is not None:
            return spec
        tg = self.otg
        interior_fetch: List[Key] = []
        interior_vars: List[int] = []
        interior_uids: set = set()
        for b in item.branches:
            uids = set(self.structure.uids_in(b))
            interior_uids |= uids
            for uid in sorted(uids):
                if uid in self._dead:
                    continue
                n = tg.nodes[uid]
                for oi in sorted(n.fetch_idxs):
                    if (uid, oi) not in interior_fetch:
                        interior_fetch.append((uid, oi))
                for vid, _ in n.var_assigns:
                    if vid not in interior_vars:
                        interior_vars.append(vid)
                if n.kind == "loop" and n.body is not None:
                    for vid in n.body.var_binds:
                        if vid not in interior_vars:
                            interior_vars.append(vid)
        exports: List[Key] = []
        for uid in sorted(interior_uids):
            if uid in self._dead:
                continue
            n = tg.nodes[uid]
            for oi in range(self._n_out(n)):
                key = (uid, oi)
                cons = self.consumers.get(key, set())
                if (cons - interior_uids) or key in sp.carries_out:
                    exports.append(key)
        spec = (interior_fetch, interior_vars, exports)
        self._switch_specs[memo_key] = spec
        return spec

    def _exec_switch(self, item: SwitchItem, sp: SegProg, ctx):
        tg = self.otg
        interior_fetch, interior_vars, exports = self.switch_spec(item, sp)

        def mk_branch(bprog):
            def bf(_):
                bctx = dict(ctx)
                bctx["env"] = dict(ctx["env"])
                bctx["var_env"] = dict(ctx["var_env"])
                bctx["fetch_buf"] = dict(ctx["fetch_buf"])
                self._interp(bprog, sp, bctx)
                fouts = []
                for (uid, oi) in interior_fetch:
                    v = bctx["fetch_buf"].get((uid, oi))
                    if v is None:
                        v = _zeros(tg.nodes[uid].out_avals[oi])
                    fouts.append(v)
                vouts = [bctx["var_env"][vid] for vid in interior_vars]
                eouts = []
                for key in exports:
                    v = bctx["env"].get(key)
                    if v is None:
                        v = _zeros(self._aval_of(key))
                    eouts.append(v)
                return tuple(fouts) + tuple(vouts) + tuple(eouts)
            return bf

        slot = self.selector_slot[item.fork_uid]
        idx = ctx["sels"][slot]
        outs = jax.lax.switch(idx, [mk_branch(b) for b in item.branches], 0)
        nf = len(interior_fetch)
        nv = len(interior_vars)
        for k, key in enumerate(interior_fetch):
            ctx["fetch_buf"][key] = outs[k]
        for k, vid in enumerate(interior_vars):
            ctx["var_env"][vid] = outs[nf + k]
        for k, key in enumerate(exports):
            ctx["env"][key] = outs[nf + nv + k]
