"""Trace representation for Terra's tracing phase.

A *trace* is the linear chain of DL operations recorded while the Python
interpreter executes one iteration of an imperative program (paper §4.1).
Each entry records the op type, its attributes, the *program location* where
it was executed (the paper's third equality criterion, Appendix A), the
data-flow references of its inputs, and the abstract values of its outputs.

References
----------
``Ref``      output ``out_idx`` of the trace entry with ordinal ``entry``.
``FeedRef``  an external tensor fed from the Python side (paper: *feed point*
             / *Input Feeding* op).  Identity is structural: the consuming
             (entry, arg position) pair.
``VarRef``   the value of a framework Variable at iteration start (resource
             input slot).  Assignments later in the trace re-bind the
             variable to an ordinary ``Ref``.
"""

from __future__ import annotations

import dataclasses
import os
import sys
from typing import Any, Callable, Optional, Tuple

import numpy as np

_CORE_DIR = os.path.dirname(os.path.abspath(__file__))


# --------------------------------------------------------------------------
# References
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Ref:
    """Output ``out_idx`` of trace entry ``entry`` (ordinal in the trace)."""
    entry: int
    out_idx: int


@dataclasses.dataclass(frozen=True)
class FeedRef:
    """External tensor fed by the Python side at (consumer entry, arg pos)."""
    entry: int
    arg_pos: int


@dataclasses.dataclass(frozen=True)
class VarRef:
    """A Variable's value at iteration start."""
    var_id: int


AnyRef = Any  # Ref | FeedRef | VarRef


# --------------------------------------------------------------------------
# Abstract values
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Aval:
    shape: Tuple[int, ...]
    dtype: str

    @staticmethod
    def of(x) -> "Aval":
        return Aval(tuple(x.shape), str(x.dtype))


# --------------------------------------------------------------------------
# Trace entries
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TraceEntry:
    """One recorded DL operation.

    ``signature`` (op_name, attrs, location) is the paper's node-equality
    key (Appendix A); we additionally compare input refs at merge time (see
    tracegraph.py and DESIGN.md §7 for why this conservative extension is
    sound).
    """
    op_name: str
    attrs: Tuple[Tuple[str, Any], ...]     # sorted, hashable
    location: Tuple[str, int]              # (filename, lineno) of user code
    input_refs: Tuple[AnyRef, ...]
    out_avals: Tuple[Aval, ...]
    feed_avals: Tuple[Tuple[int, Aval], ...] = ()   # (arg_pos, aval) of feeds

    def signature(self) -> Tuple:
        return (self.op_name, self.attrs, self.location)

    def stamp(self) -> Optional[int]:
        """Entry-signature hash for the Walker's steady-state fast path
        (DESIGN.md §4.4): the full recorded identity of the entry —
        signature plus raw ordinal-based input refs and feed avals — folded
        to one integer.  ``merge_trace`` stamps the matched TraceGraph node
        with this value, so a later identical iteration validates the op
        with a single cached-hash comparison instead of resolving every
        input source.  Returns None when a constant input is unhashable
        (the Walker then always takes the structural path)."""
        try:
            return hash((self.op_name, self.attrs, self.location,
                         self.input_refs, self.feed_avals))
        except TypeError:
            return None


@dataclasses.dataclass
class SyncMarker:
    """Materialization event: Python required the value of ``ref`` before
    issuing the next op.  Segment boundaries are derived from these (paper's
    *Output Fetching* points that gate the PythonRunner)."""
    ref: AnyRef


@dataclasses.dataclass
class VarAssign:
    """Variable ``var_id`` re-bound to ``ref`` (Python object mutation that
    the symbolic graph must honor — Figure 1c class of programs)."""
    var_id: int
    ref: AnyRef


@dataclasses.dataclass
class Trace:
    """A single iteration's recording."""
    entries: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)   # in-order ops/markers/assigns
    fetches: list = dataclasses.field(default_factory=list)  # refs materialized
    var_reads: set = dataclasses.field(default_factory=set)
    var_assigns: dict = dataclasses.field(default_factory=dict)  # var_id -> final ref

    def add_entry(self, e: TraceEntry) -> int:
        idx = len(self.entries)
        self.entries.append(e)
        self.events.append(e)
        return idx


# --------------------------------------------------------------------------
# Program-location capture
# --------------------------------------------------------------------------

def user_location(skip_files: Tuple[str, ...] = ()) -> Tuple[str, int]:
    """Innermost stack frame outside repro.core (and ``skip_files``).

    This is the paper's "location of the program" equality criterion: two
    dynamic occurrences of an op are the same *node* only if they were
    executed from the same source location.
    """
    f = sys._getframe(1)
    while f is not None:
        fn = f.f_code.co_filename
        if not fn.startswith(_CORE_DIR) and fn not in skip_files:
            return (fn, f.f_lineno)
        f = f.f_back
    return ("<unknown>", 0)


def is_tensor_like(x) -> bool:
    """External array data (numpy / jax) that should become a feed point."""
    if isinstance(x, np.ndarray):
        return True
    # jax.Array without importing jax at module import time
    return type(x).__module__.startswith("jax") and hasattr(x, "dtype") and hasattr(x, "shape")
