"""The instrumented imperative op namespace (Terra's "DL operations").

Every function here is a *DL operation* in the paper's sense: when executed
under a Terra engine it is recorded into the trace (tracing phase) or
validated against the TraceGraph (co-execution phase); with no engine active
it simply executes eagerly with jax.numpy — that is the plain imperative
baseline the paper compares against.

Argument convention
-------------------
* positional arguments are tensors: TerraTensor | Variable-read | jax/numpy
  array (becomes a *feed point*) | Python scalar (becomes a baked constant —
  exactly TF's constant-capture semantics, so programs that mutate such
  values exhibit the paper's Figure-1c behaviour and are handled by Terra
  through trace branching).
* keyword arguments are op *attributes* (part of node equality, Appendix A).

Autodiff: ``GradientTape`` replays the recorded trace backwards, emitting one
``<op>.vjp`` operation per forward operation — so the backward pass lands in
the TraceGraph exactly like LazyTensor/PyTorch-XLA backward traces.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.tensor import TerraTensor, Variable, current_engine
from repro.core.trace import Aval, Ref, VarRef, user_location


# --------------------------------------------------------------------------
# Op registry
# --------------------------------------------------------------------------

@dataclasses.dataclass
class OpDef:
    name: str
    impl: Callable                 # pure jax fn: (*tensors, **attrs) -> array | tuple


OPS: Dict[str, OpDef] = {}


@dataclasses.dataclass(frozen=True)
class Const:
    """A Python scalar captured as a baked constant input slot."""
    value: Any

    def __hash__(self):
        return hash((type(self.value).__name__, self.value))


def def_op(name: str, impl: Callable) -> Callable:
    """Register ``impl`` and return the user-facing instrumented function."""
    OPS[name] = OpDef(name, impl)

    def op_fn(*tensor_args, **attrs):
        return _call_op(name, tensor_args, attrs)

    op_fn.__name__ = name
    return op_fn


def op_impl(name: str) -> Callable:
    return OPS[name].impl


# --------------------------------------------------------------------------
# Dispatch
# --------------------------------------------------------------------------

def _canon_attrs(attrs: dict) -> Tuple[Tuple[str, Any], ...]:
    def canon(v):
        if isinstance(v, list):
            return tuple(canon(x) for x in v)
        if isinstance(v, np.dtype):
            return str(v)
        return v
    return tuple(sorted((k, canon(v)) for k, v in attrs.items()))


def _classify_arg(a):
    """-> ('tensor', TerraTensor) | ('const', scalar) | ('feed', np/jax array)."""
    if isinstance(a, TerraTensor):
        return ("tensor", a)
    if isinstance(a, Variable):
        # implicit read
        return ("tensor", a.read()) if current_engine() is not None else ("feed", a._value)
    if isinstance(a, (bool, int, float)) or a is None:
        return ("const", a)
    if isinstance(a, (np.ndarray, np.generic)):
        return ("feed", np.asarray(a))
    if type(a).__module__.startswith("jax") or hasattr(a, "__jax_array__"):
        return ("feed", a)
    raise TypeError(f"unsupported op argument of type {type(a)}")


def _call_op(name: str, tensor_args, attrs):
    eng = current_engine()
    attrs_t = _canon_attrs(attrs)
    args = [_classify_arg(a) for a in tensor_args]
    if eng is None:
        # plain imperative execution — unwrap and run
        vals = []
        for kind, a in args:
            if kind == "tensor":
                vals.append(a._eager if a._eager is not None else a.value())
            elif kind == "const":
                vals.append(a.value if isinstance(a, Const) else a)
            else:
                vals.append(a)
        out = OPS[name].impl(*vals, **dict(attrs_t))
        return _wrap_eager(out)
    loc = user_location(skip_files=getattr(eng, "skip_files", ()))
    return eng.record_op(name, args, attrs_t, loc)


def _wrap_eager(out):
    if isinstance(out, tuple):
        return tuple(TerraTensor(None, Aval.of(o), eager=o) for o in out)
    return TerraTensor(None, Aval.of(out), eager=out)


# --------------------------------------------------------------------------
# Generic VJP ops: one `<name>.vjp` op per forward op
# --------------------------------------------------------------------------

def get_vjp_op_name(fwd_name: str) -> str:
    name = fwd_name + ".vjp"
    if name not in OPS:
        fwd_impl = OPS[fwd_name].impl

        def vjp_impl(*args, _n_out: int, _n_in: int, **attrs):
            cts = args[:_n_out]
            inputs = args[_n_out:_n_out + _n_in]

            def primal(*ins):
                return fwd_impl(*ins, **attrs)

            _, vjp_fn = jax.vjp(primal, *inputs)
            ct = cts[0] if _n_out == 1 else tuple(cts)
            outs = vjp_fn(ct)
            return tuple(outs) if len(outs) > 1 else outs[0]

        OPS[name] = OpDef(name, vjp_impl)
    return name


# --------------------------------------------------------------------------
# GradientTape (TF-style; backward ops are recorded as Terra ops)
# --------------------------------------------------------------------------

class GradientTape:
    def __init__(self):
        self._start = None
        self._engine = None

    def __enter__(self):
        eng = current_engine()
        if eng is None:
            raise RuntimeError("GradientTape requires an active Terra engine "
                               "(use terra.imperative()/Terra runtime)")
        self._engine = eng
        self._start = eng.tape_mark()
        return self

    def __exit__(self, *exc):
        return False

    def gradient(self, loss: TerraTensor, sources):
        """Emit the backward trace for ``loss`` w.r.t. ``sources``.

        ``sources`` is a list of Variables or TerraTensors.  Returns a list
        of TerraTensors (cotangents), zeros where unconnected.
        """
        eng = self._engine
        entries, tensors_of = eng.tape_slice(self._start)
        if not isinstance(loss.ref, Ref):
            raise ValueError("loss must be produced by a recorded op")

        source_refs = []
        for s in sources:
            if isinstance(s, Variable):
                source_refs.append(eng.variable_read_ref(s))
            else:
                source_refs.append(s.ref)

        ct: Dict[Any, TerraTensor] = {loss.ref: ones_like(loss)}

        # entries are in execution (topological) order — walk backward
        for idx in range(len(entries) - 1, -1, -1):
            ordinal, entry = entries[idx]
            out_cts = [ct.get(Ref(ordinal, i)) for i in range(len(entry.out_avals))]
            if all(c is None for c in out_cts):
                continue
            if entry.op_name in _NONDIFF_OPS:
                continue
            outs = tensors_of(ordinal)
            filled = [c if c is not None else zeros_like(outs[i])
                      for i, c in enumerate(out_cts)]
            in_tensors = eng.tensors_for_input_slots(ordinal, entry)
            vjp_name = get_vjp_op_name(entry.op_name)
            grads = _call_op(
                vjp_name,
                tuple(filled) + tuple(in_tensors),
                dict(entry.attrs) | {"_n_out": len(entry.out_avals),
                                     "_n_in": len(in_tensors)},
            )
            if not isinstance(grads, tuple):
                grads = (grads,)
            for slot, g in zip(entry.input_refs, grads):
                if isinstance(slot, (Ref, VarRef)) and _is_float(g.aval.dtype):
                    prev = ct.get(slot)
                    ct[slot] = g if prev is None else add(prev, g)

        results = []
        for s, r in zip(sources, source_refs):
            g = ct.get(r)
            if g is None:
                ref_t = s.read() if isinstance(s, Variable) else s
                g = zeros_like(ref_t)
            results.append(g)
        return results


def _is_float(dtype: str) -> bool:
    return np.issubdtype(np.dtype(dtype), np.floating)


_NONDIFF_OPS = {"greater", "less", "greater_equal", "less_equal", "equal",
                "argmax", "argmin", "stop_gradient", "iota", "one_hot_int"}


# --------------------------------------------------------------------------
# Composite ops: register any pure-JAX function as a single DL operation
# --------------------------------------------------------------------------

def terra_op(fn: Callable = None, *, name: str = None, nondiff: bool = False):
    """Decorator: wrap a pure JAX function as one Terra DL operation.

    This is the framework-scale granularity: e.g. a fully fused, pjit-ready
    ``train_step`` becomes a single node in the TraceGraph (see DESIGN.md §2,
    row "TF ops = graph nodes").
    """
    def deco(f):
        opname = name or f"composite.{f.__module__}.{f.__qualname__}"
        op = def_op(opname, f)
        if nondiff:
            _NONDIFF_OPS.add(opname)
        functools.update_wrapper(op, f)
        return op
    return deco(fn) if fn is not None else deco


# --------------------------------------------------------------------------
# RNG plumbing (random ops take a key feed so graphs stay iteration-stable)
# --------------------------------------------------------------------------

_eager_key = [jax.random.PRNGKey(0)]
_eager_key_lock = threading.Lock()


def _next_key():
    eng = current_engine()
    if eng is not None:
        return eng.next_rng_key()
    with _eager_key_lock:
        _eager_key[0], k = jax.random.split(_eager_key[0])
    return k


# --------------------------------------------------------------------------
# The op set
# --------------------------------------------------------------------------

def _idx_encode(idx):
    def enc(i):
        if isinstance(i, slice):
            return ("slice", i.start, i.stop, i.step)
        if i is Ellipsis:
            return ("ellipsis",)
        if i is None:
            return ("newaxis",)
        if isinstance(i, int):
            return ("int", i)
        raise TypeError(f"only static indices supported, got {type(i)}")
    if not isinstance(idx, tuple):
        idx = (idx,)
    return tuple(enc(i) for i in idx)


def _idx_decode(enc):
    out = []
    for e in enc:
        if e[0] == "slice":
            out.append(slice(e[1], e[2], e[3]))
        elif e[0] == "ellipsis":
            out.append(Ellipsis)
        elif e[0] == "newaxis":
            out.append(None)
        else:
            out.append(e[1])
    return tuple(out)


identity      = def_op("identity", lambda a: jnp.asarray(a))
add           = def_op("add", lambda a, b: jnp.add(a, b))
sub           = def_op("sub", lambda a, b: jnp.subtract(a, b))
mul           = def_op("mul", lambda a, b: jnp.multiply(a, b))
div           = def_op("div", lambda a, b: jnp.divide(a, b))
power         = def_op("power", lambda a, b: jnp.power(a, b))
neg           = def_op("neg", lambda a: jnp.negative(a))
exp           = def_op("exp", lambda a: jnp.exp(a))
log           = def_op("log", lambda a: jnp.log(a))
sqrt          = def_op("sqrt", lambda a: jnp.sqrt(a))
rsqrt         = def_op("rsqrt", lambda a: jax.lax.rsqrt(a))
square        = def_op("square", lambda a: jnp.square(a))
tanh          = def_op("tanh", lambda a: jnp.tanh(a))
sigmoid       = def_op("sigmoid", lambda a: jax.nn.sigmoid(a))
relu          = def_op("relu", lambda a: jax.nn.relu(a))
gelu          = def_op("gelu", lambda a: jax.nn.gelu(a))
silu          = def_op("silu", lambda a: jax.nn.silu(a))
softmax       = def_op("softmax", lambda a, *, axis=-1: jax.nn.softmax(a, axis=axis))
log_softmax   = def_op("log_softmax", lambda a, *, axis=-1: jax.nn.log_softmax(a, axis=axis))
matmul        = def_op("matmul", lambda a, b: jnp.matmul(a, b))
einsum        = def_op("einsum", lambda *xs, expr: jnp.einsum(expr, *xs))
reshape       = def_op("reshape", lambda a, *, new_shape: jnp.reshape(a, new_shape))
transpose     = def_op("transpose", lambda a, *, axes=None: jnp.transpose(a, axes))
_getitem_raw  = def_op("getitem", lambda a, *, idx: a[_idx_decode(idx)])
concat        = def_op("concat", lambda *xs, axis=0: jnp.concatenate(xs, axis=axis))
stack_op      = def_op("stack", lambda *xs, axis=0: jnp.stack(xs, axis=axis))
reduce_sum    = def_op("reduce_sum", lambda a, *, axis=None, keepdims=False: jnp.sum(a, axis=axis, keepdims=keepdims))
reduce_mean   = def_op("reduce_mean", lambda a, *, axis=None, keepdims=False: jnp.mean(a, axis=axis, keepdims=keepdims))
reduce_max    = def_op("reduce_max", lambda a, *, axis=None, keepdims=False: jnp.max(a, axis=axis, keepdims=keepdims))
argmax        = def_op("argmax", lambda a, *, axis=-1: jnp.argmax(a, axis=axis))
greater       = def_op("greater", lambda a, b: jnp.greater(a, b))
less          = def_op("less", lambda a, b: jnp.less(a, b))
greater_equal = def_op("greater_equal", lambda a, b: jnp.greater_equal(a, b))
less_equal    = def_op("less_equal", lambda a, b: jnp.less_equal(a, b))
equal         = def_op("equal", lambda a, b: jnp.equal(a, b))
where         = def_op("where", lambda c, a, b: jnp.where(c, a, b))
cast          = def_op("cast", lambda a, *, dtype: a.astype(dtype))
stop_gradient = def_op("stop_gradient", lambda a: jax.lax.stop_gradient(a))
zeros_like    = def_op("zeros_like", lambda a: jnp.zeros_like(a))
ones_like     = def_op("ones_like", lambda a: jnp.ones_like(a))
abs_op        = def_op("abs", lambda a: jnp.abs(a))
maximum       = def_op("maximum", lambda a, b: jnp.maximum(a, b))
minimum       = def_op("minimum", lambda a, b: jnp.minimum(a, b))
clip          = def_op("clip", lambda a, *, lo, hi: jnp.clip(a, lo, hi))
embedding     = def_op("embedding", lambda table, ids: jnp.take(table, ids, axis=0))
one_hot       = def_op("one_hot", lambda ids, *, depth, dtype="float32": jax.nn.one_hot(ids, depth, dtype=dtype))
layer_norm    = def_op(
    "layer_norm",
    lambda x, g, b, *, eps=1e-5: g * (x - jnp.mean(x, -1, keepdims=True))
    * jax.lax.rsqrt(jnp.var(x, -1, keepdims=True) + eps) + b)
rms_norm      = def_op(
    "rms_norm",
    lambda x, g, *, eps=1e-6: g * x * jax.lax.rsqrt(
        jnp.mean(jnp.square(x), -1, keepdims=True) + eps))
conv2d        = def_op(
    "conv2d",
    lambda x, w, *, stride=1, padding="SAME": jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC")))
max_pool2d    = def_op(
    "max_pool2d",
    lambda x, *, window=2, stride=2: jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID"))
avg_pool2d    = def_op(
    "avg_pool2d",
    lambda x, *, window=2, stride=2: jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1),
        (1, stride, stride, 1), "VALID") / (window * window))
resize_nearest = def_op(
    "resize_nearest",
    lambda x, *, factor=2: jnp.repeat(jnp.repeat(x, factor, axis=1), factor, axis=2))

_dropout_raw = def_op(
    "dropout",
    lambda x, key, *, rate: jnp.where(
        jax.random.bernoulli(key, 1.0 - rate, x.shape),
        x / (1.0 - rate), jnp.zeros_like(x)) if rate > 0.0 else x)

_random_normal_raw = def_op(
    "random_normal",
    lambda key, *, shape, dtype="float32": jax.random.normal(key, shape, dtype=dtype))

_random_uniform_raw = def_op(
    "random_uniform",
    lambda key, *, shape, dtype="float32": jax.random.uniform(key, shape, dtype=dtype))

softmax_xent = def_op(
    "softmax_xent",
    lambda logits, labels: -jnp.mean(
        jnp.sum(jax.nn.log_softmax(logits, -1)
                * jax.nn.one_hot(labels, logits.shape[-1]), -1)))


def getitem(a, *, idx):
    return _getitem_raw(a, idx=_idx_encode(idx))


def dropout(x, rate: float):
    """Dropout with the rate captured as a baked constant (TF semantics).

    ``rate`` changing across iterations (e.g. via Python object mutation,
    Figure 1c) produces a trace branch that Terra handles transparently.
    """
    return _dropout_raw(x, _next_key(), rate=float(rate))


def random_normal(shape, dtype="float32"):
    return _random_normal_raw(_next_key(), shape=tuple(shape), dtype=dtype)


def random_uniform(shape, dtype="float32"):
    return _random_uniform_raw(_next_key(), shape=tuple(shape), dtype=dtype)


def mean_squared_error(pred, target):
    return reduce_mean(square(sub(pred, target)))


def sparse_softmax_xent(logits, labels):
    return softmax_xent(logits, labels)
