"""Variable surface of the TerraEngine: reads, assigns, out-of-band
rebinds, RNG and the per-value fence wait.

Split out of coordinator.py as a mixin for the same reason
python_runner.py is one — the phase machine and the variable API are
independently readable, and the coordinator stays within the executor's
module-size budget.  Everything here operates on the engine's own state
(store, mode, bindings, event stream).  Fenced *device-side* variable
updates (the serving prefill path) live in varops.py.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ops as ops_mod
from repro.core.events import emit as ev
from repro.core.tensor import TerraTensor, Variable
from repro.core.trace import Aval, Ref, VarAssign, VarRef

SKELETON = "skeleton"


class VariableOps:
    """Mixin for TerraEngine: the variable-facing API."""

    def _ensure_var(self, var: Variable):
        self.store.ensure(var)

    def read_variable(self, var: Variable) -> TerraTensor:
        self._ensure_var(var)
        bound = self._var_binding.get(var.var_id)
        if bound is not None:
            return bound
        if self.mode == SKELETON:
            return TerraTensor(VarRef(var.var_id), var.aval, engine=self,
                               iter_id=self.iter_id)
        # eager modes read the committed store value
        return TerraTensor(VarRef(var.var_id), var.aval,
                           eager=self.store.get(var.var_id, var._value),
                           engine=self, iter_id=self.iter_id)

    def assign_variable(self, var: Variable, value):
        self._ensure_var(var)
        if not isinstance(value, TerraTensor):
            value = ops_mod.identity(value)
        if not isinstance(value.ref, Ref) or value._iter != self.iter_id:
            value = ops_mod.identity(value)
        self.trace.events.append(VarAssign(var.var_id, value.ref))
        self.trace.var_assigns[var.var_id] = value.ref
        self._var_binding[var.var_id] = value

    def _await_fence(self, seq) -> None:
        """Block on one per-value readiness fence (DESIGN.md §4.4) — a
        GraphRunner sequence number — instead of draining the whole queue;
        the FIFO runner guarantees the fenced writer has committed its
        buffer once the sequence completes.  Lazy mode executes the queued
        work on this thread, as drain() used to."""
        if seq is None or self.runner.done(seq):
            return
        t0 = time.perf_counter()
        self.runner.wait_for(seq)
        self.events.add("py_stall_time", time.perf_counter() - t0)

    def variable_value(self, var: Variable):
        self._ensure_var(var)
        if self._iter_open and self.mode == SKELETON:
            # Python saw device state: poison the steady-state plan (§12)
            if not getattr(self, "_steady_poison", False):
                ev.steady_poison(self.events, self.iter_id)
            self._steady_poison = True
        bound = self._var_binding.get(var.var_id)
        if bound is not None and bound._eager is not None:
            return bound._eager
        # block only on this variable's last pending writer (not the queue)
        self._await_fence(self.store.write_fence(var.var_id))
        val = self.store.buffers[var.var_id]
        if (self._iter_open and self.mode == SKELETON and self.gp is not None
                and var.var_id in self.gp.donatable_var_ids):
            # a later segment of this iteration may donate this buffer;
            # hand the caller a private copy (DESIGN.md §4.2)
            val = jnp.array(val)
        return val

    def variable_read_ref(self, var: Variable):
        return VarRef(var.var_id)

    def reset_variable(self, var: Variable, value):
        """Out-of-band variable (re)binding between iterations — used by
        drivers (e.g. the serving engine rebinding KV-cache variables after
        a prefill) to swap device state without recording a trace event.
        Rebinding to a different shape is legal: the new aval flows into
        the store's shape digest, so the next iteration selects (or traces)
        the matching TraceGraph family (§8) instead of diverging."""
        if self._iter_open and self.mode == SKELETON:
            raise RuntimeError("reset_variable inside an open co-executed "
                               "iteration")
        self._ensure_var(var)
        # wait for the last pending toucher (reader or writer) of this
        # variable only; rebinds between iterations no longer serialize
        # behind the whole previous iteration's queue
        self._await_fence(self.store.use_fence(var.var_id))
        value = jnp.asarray(value)
        self.store.put(var.var_id, value)
        var._value = value
        new_aval = Aval.of(value)
        if new_aval != var.aval:
            var.aval = new_aval
            self.store.invalidate_avals()

    def release_variable(self, var: Variable) -> None:
        """Drop a variable's buffer from the store (driver-retired state)."""
        self._await_fence(self.store.use_fence(var.var_id))
        self.store.remove(var.var_id)

    # ------------------------------------------------------------------
    # RNG
    # ------------------------------------------------------------------
    def next_rng_key(self):
        k = jax.random.fold_in(jax.random.fold_in(self._base_key,
                                                  self.iter_id),
                               self._rng_count)
        self._rng_count += 1
        return k
