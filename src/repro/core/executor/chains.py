"""Path-specialized chain dispatch (DESIGN.md §2).

A gating fetch that is *not* at a top-level segment boundary (e.g. inside a
branch region) cannot cut a segment soundly.  Instead of replaying eagerly,
the coordinator swaps in a :class:`ChainDispatcher`: the exact linear chain
of already-validated ops is jitted — selectors are resolved by construction,
so no switch machinery is needed — and every produced value gets a future.
Chains are cached by their op/src structure in an engine-lifetime cache
(shared across TraceGraph families: jax.jit re-specializes per input avals,
so sibling shape classes reuse the same chain callables).

Split out of dispatch.py, which keeps the Dispatcher protocol and the
segment dispatcher; ``repro.core.executor.dispatch`` re-exports
ChainDispatcher so historical import paths keep working.
"""

from __future__ import annotations

import time
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core import ops as ops_mod
from repro.core.events import emit as ev
from repro.core.trace import FeedRef, Ref, VarRef
from repro.core.executor.dispatch import Dispatcher, SegmentDispatcher
from repro.core.executor.walker import ReplayRequired

class ChainDispatcher(Dispatcher):
    kind = "chain"

    def __init__(self, parent: SegmentDispatcher, feed_log: Dict,
                 chain_cache: Dict[Tuple, Any]):
        self.parent = parent
        self.walker = parent.walker
        self.tg = parent.gp.tg
        self.trace = parent.trace
        self.runner = parent.runner
        self.store = parent.store
        self.events = parent.events
        self.stats = parent.stats
        self.iter_id = parent.iter_id
        self.feed_log = feed_log
        self.chain_cache = chain_cache          # engine-lifetime jit cache
        self.chain_env: Dict[Tuple[int, int], Any] = {}
        self.futures: Dict[Tuple[int, int], Future] = {}
        # the chain picks up after whatever segments already dispatched
        self.start = parent.ordinal_at_dispatch

    # ------------------------------------------------------------------
    def on_boundary(self, seg_idx: int) -> None:
        pass        # chains ignore segment boundaries

    def finish(self) -> None:
        self.flush()                            # trailing chain (side effects)

    def future_for(self, ref: Ref) -> Optional[Future]:
        fut = self.futures.get((ref.entry, ref.out_idx))
        if fut is not None:
            return fut
        try:
            return self.parent.future_for(ref)  # dispatched-segment values
        except ReplayRequired:
            return None

    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Jit + submit the chain of ops recorded since the last flush."""
        start, end = self.start, len(self.trace.entries)
        if end <= start:
            return
        entries = self.trace.entries[start:end]

        key_parts = []
        ext_plan: List[Tuple] = []   # ('chain', e, oi) | ('seg', uid, oi)
        ext_index: Dict[Tuple, int] = {}
        feeds = []
        var_ids: List[int] = []
        var_index: Dict[int, int] = {}
        arg_plans = []
        for local, e in enumerate(entries):
            plan = []
            for pos, r in enumerate(e.input_refs):
                if isinstance(r, Ref) and r.entry >= start:
                    plan.append(("i", r.entry - start, r.out_idx))
                elif isinstance(r, Ref):
                    k = ("r", r.entry, r.out_idx)
                    if k not in ext_index:
                        ext_index[k] = len(ext_plan)
                        uid = self.walker.ord_to_uid.get(r.entry)
                        # values produced by an earlier chain flush are keyed
                        # by futures (updated synchronously on this thread);
                        # chain_env is runner-thread state and may lag
                        if (r.entry, r.out_idx) in self.futures or uid is None:
                            ext_plan.append(("chain", r.entry, r.out_idx))
                        else:
                            n = self.tg.nodes[uid]
                            oi = (n.body.out_slot_for(r, ())
                                  if n.kind == "loop" else r.out_idx)
                            key = (uid, oi)
                            if key in self.parent.fetch_futures:
                                # a fetched-but-not-carried value: read it
                                # off the completed segment future (FIFO ⇒
                                # the producer ran before this closure)
                                ext_plan.append(("fetch", uid, oi))
                            elif key in self.parent.gp.published:
                                ext_plan.append(("seg", uid, oi))
                            else:
                                # the optimized segments no longer publish
                                # this value (e.g. its node was DCE'd);
                                # the caller recovers via eager replay
                                raise ReplayRequired()
                    plan.append(("x", ext_index[k]))
                elif isinstance(r, FeedRef):
                    plan.append(("f", len(feeds)))
                    feeds.append(self.feed_log[(start + local, pos)])
                elif isinstance(r, VarRef):
                    if r.var_id not in var_index:
                        var_index[r.var_id] = len(var_ids)
                        var_ids.append(r.var_id)
                    plan.append(("v", var_index[r.var_id]))
                else:
                    plan.append(("c", r.value))
            arg_plans.append(tuple(plan))
            key_parts.append((e.op_name, e.attrs, e.location,
                              tuple((p[0],) + tuple(p[1:]) for p in plan)))
        key = (start == 0, tuple(key_parts))

        fn = self.chain_cache.get(key)
        if fn is None:
            fn = _build_chain_fn(entries, arg_plans)
            self.chain_cache[key] = fn

        # futures for every produced value
        produced = []
        futures = {}
        for j, e in enumerate(entries):
            for oi in range(len(e.out_avals)):
                futures[(start + j, oi)] = Future()
                produced.append((start + j, oi))
        self.futures.update(futures)

        assigns = {vid: ref for vid, ref in self.trace.var_assigns.items()
                   if isinstance(ref, Ref) and start <= ref.entry < end}
        buffers = self.store.buffers
        iter_env = self.parent.iter_env
        chain_env = self.chain_env

        fetch_futures = self.parent.fetch_futures

        def run(fn=fn, var_ids=tuple(var_ids), feeds=tuple(feeds),
                ext_plan=tuple(ext_plan), futures=futures, assigns=assigns,
                produced=tuple(produced), start=start,
                profile=self.parent.profile):
            var_vals = tuple(buffers[v] for v in var_ids)
            exts = tuple(
                chain_env[(p[1], p[2])] if p[0] == "chain"
                else fetch_futures[(p[1], p[2])].result() if p[0] == "fetch"
                else iter_env[(p[1], p[2])] for p in ext_plan)
            if profile:
                pt0 = time.perf_counter()
            try:
                outs = fn(var_vals, feeds, exts)
            except Exception as exc:        # noqa: BLE001
                for f in futures.values():
                    if not f.done():
                        f.set_exception(exc)
                raise
            if profile:
                # sampled device-time attribution (DESIGN.md §15); the
                # chain index is its trace-ordinal start, matching the
                # SegmentDispatch "chain" event
                pt1 = time.perf_counter()
                jax.block_until_ready(outs)
                ev.segment_profile(self.events, self.iter_id, "chain",
                                   start, pt1 - pt0,
                                   time.perf_counter() - pt0)
            for (ordv, v) in zip(produced, outs):
                chain_env[ordv] = v
                futures[ordv].set_result(v)
            for vid, ref in assigns.items():
                buffers[vid] = chain_env[(ref.entry, ref.out_idx)]

        seq = self.runner.submit(run)
        self.store.fence(var_ids, assigns, seq)
        self.stats["segments_dispatched"] += 1
        ev.segment_dispatch(self.events, self.iter_id, "chain", start, seq,
                            len(feeds))
        self.start = end


def _build_chain_fn(entries, arg_plans):
    """Jit the linear op chain: (var_vals, feed_vals, ext_vals) -> flat outs."""
    impls = [ops_mod.OPS[e.op_name].impl for e in entries]
    attrs = [dict(e.attrs) for e in entries]
    plans = list(arg_plans)

    def chain_fn(var_vals, feed_vals, ext_vals):
        env: Dict[Tuple[int, int], Any] = {}
        flat_out = []
        for j, impl in enumerate(impls):
            vals = []
            for p in plans[j]:
                if p[0] == "i":
                    vals.append(env[(p[1], p[2])])
                elif p[0] == "x":
                    vals.append(ext_vals[p[1]])
                elif p[0] == "f":
                    vals.append(feed_vals[p[1]])
                elif p[0] == "v":
                    vals.append(var_vals[p[1]])
                else:
                    vals.append(p[1])
            out = impl(*vals, **attrs[j])
            outs = out if isinstance(out, tuple) else (out,)
            for oi, v in enumerate(outs):
                env[(j, oi)] = v
            flat_out.extend(outs)
        return tuple(flat_out)

    return jax.jit(chain_fn)
