"""Fenced out-of-band variable updates (DESIGN.md §12).

``reset_variable`` is the pre-existing out-of-band write: it *fetches
nothing* but stalls the Python thread on the variable's use fence and
ships a host value.  Drivers that want to run device-resident work over
engine Variables *between* iterations — the serving scheduler's prefill
consuming and rewriting the KV-cache variables in place — need the
opposite: submit a closure into the engine's FIFO GraphRunner that reads
the current buffers, computes on device, and writes results back, fenced
exactly like a dispatched segment so iteration snapshots and later
readers order correctly behind it.  The Python thread never blocks and
no buffer crosses the host boundary.

Contract: the closure's writes must preserve each variable's aval (the
store's shape digest is not refreshed here; an aval change would demand
a family switch, which only ``reset_variable`` performs).  Requires a
closed iteration — the snapshot taken at the next ``start_iteration`` is
submitted FIFO-after this update, so divergence rollback semantics are
unchanged.
"""

from __future__ import annotations

from concurrent.futures import Future
from typing import Callable, List, Sequence

from repro.core.executor.coordinator import SKELETON
from repro.core.tensor import Variable


def submit_variable_update(eng, reads: Sequence[Variable],
                           writes: Sequence[Variable],
                           fn: Callable, n_results: int = 0) -> List[Future]:
    """Queue ``fn(list_of_read_buffers) -> outputs`` on the GraphRunner.

    ``outputs[:len(writes)]`` become the new buffers of ``writes`` (same
    avals required); ``outputs[len(writes):]`` resolve the returned
    ``n_results`` futures.  Reads and writes are fenced, so this composes
    with in-flight dispatched segments and the next iteration's snapshot.
    """
    if eng._iter_open and eng.mode == SKELETON:
        raise RuntimeError("submit_variable_update inside an open "
                           "co-executed iteration")
    for var in tuple(reads) + tuple(writes):
        eng._ensure_var(var)
    store = eng.store
    read_ids = tuple(v.var_id for v in reads)
    write_ids = tuple(v.var_id for v in writes)
    futs = [Future() for _ in range(n_results)]

    def run():
        bufs = [store.read(i) for i in read_ids]
        try:
            outs = fn(bufs)
        except Exception as e:
            for f in futs:
                if not f.done():
                    f.set_exception(e)
            raise
        for vid, v in zip(write_ids, outs):
            store.buffers[vid] = v
        for f, v in zip(futs, outs[len(write_ids):]):
            f.set_result(v)

    seq = eng.runner.submit(run)
    store.fence(read_ids, write_ids, seq)
    return futs
