"""Engine counter registry: every stat the TerraEngine exports, in one
place so the coordinator stays a phase machine and the benchmarks
(fig6_breakdown, bench_hotpath) have a single source of truth for what
exists.  Groups follow the perf layers they instrument (DESIGN.md §4, §8,
§10)."""

from __future__ import annotations

from typing import Any, Dict


def init_stats() -> Dict[str, Any]:
    return {
        # paper Fig. 6 breakdown / App. F transitions
        "iterations": 0, "traced_iterations": 0, "transitions": 0,
        "replays": 0, "replayed_entries": 0, "py_stall_time": 0.0,
        "py_total_time": 0.0,       # wall time inside TerraFunction calls
        "graph_versions": 0, "segments_dispatched": 0,
        "segments_recompiled": 0, "segment_cache_hits": 0,
        "donated_bytes": 0,
        # hot-path counters (DESIGN.md §4.4, benchmarks/bench_hotpath)
        "dispatch_time": 0.0,       # Python-thread time in dispatch
        "feeds_defaulted": 0,       # zeros substituted for missing feeds
        "walker_fast_hits": 0,      # ops validated via the stamp path
        # zero-walker steady state (DESIGN.md §12)
        "steady_iters": 0,          # iterations dispatched without a walker
        "steady_entries": 0,        # steady plans built (entries into mode)
        "steady_exits": 0,          # plans dropped (divergence/rebuild)
        # GraphRunner occupancy, mirrored from the runner thread
        "runner_exec_time": 0.0, "runner_stall_time": 0.0,
        # shape-keyed TraceGraph families (DESIGN.md §8)
        "retraces": 0,          # tracing entered: new shape / divergence
        "family_switches": 0,   # flips to an already-traced shape class
        "families_evicted": 0, "families": 0,
        # symbolic optimization pipeline (core/passes/, DESIGN.md §10)
        "nodes_eliminated": 0,      # DCE: ops skipped at compile time
        "cse_hits": 0,              # duplicate subexpressions merged
        "feeds_folded": 0,          # Input Feeds demoted to constants
        "segments_coalesced": 0,    # gating boundaries removed
        "kernels_substituted": 0,   # subgraphs fused to Pallas kernels
        "fold_divergences": 0,      # folded feed changed → re-trace
        # persistent artifact store / warm boot (core/persist/, §14)
        "artifact_hits": 0,         # records/executables loaded from disk
        "artifact_misses": 0,       # consults that fell through
        "artifacts_stored": 0,      # records/executables written
        "warm_families": 0,         # families hydrated instead of traced
        "aot_loads": 0,             # segments deserialized (no recompile)
        "checkpoint_saves": 0, "checkpoint_restores": 0,
    }
