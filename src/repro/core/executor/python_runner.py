"""The PythonRunner surface: op recording and Output Fetching.

This mixin is the side of the engine the instrumented op layer talks to
(paper §4.1's PythonRunner): ``record_op`` is called for every DL op the
Python interpreter executes — eagerly executed and recorded while tracing,
validated through the Walker and turned into placeholder tensors while
co-executing — and ``materialize`` resolves a placeholder at a fetch point
against the active dispatcher's futures, escalating to path-specialized
chain dispatch or the divergence fallback when the graph does not already
output the value.

It is a mixin rather than a standalone object because it *is* the engine's
public op-facing API — separated from coordinator.py only so the phase
machine and the recording surface stay independently readable.
"""

from __future__ import annotations

import time
from typing import Any, Dict

from repro.core import ops as ops_mod
from repro.core.ops import Const
from repro.core.tensor import TerraTensor
from repro.core.trace import Aval, FeedRef, Ref, SyncMarker, TraceEntry, VarRef
from repro.core.executor.dispatch import ChainDispatcher
from repro.core.executor.walker import DivergenceError, ReplayRequired

SKELETON = "skeleton"


class PythonRunnerOps:
    """Mixin for TerraEngine: the op-recording / fetching surface."""

    # ------------------------------------------------------------------
    # op recording (called from ops._call_op)
    # ------------------------------------------------------------------
    def record_op(self, name: str, args, attrs_t, loc):
        refs, vals = [], []
        feed_avals: list = []
        feed_values: Dict[int, Any] = {}
        ordinal = len(self.trace.entries)
        for pos, (kind, a) in enumerate(args):
            if kind == "tensor":
                t = a
                if t.ref is None or t._iter != self.iter_id:
                    # value from outside this iteration — becomes a feed
                    v = t._eager if t._eager is not None else t.value()
                    refs.append(FeedRef(ordinal, pos))
                    feed_avals.append((pos, Aval.of(v)))
                    feed_values[pos] = v
                    self._feed_log[(ordinal, pos)] = v
                    vals.append(v)
                else:
                    refs.append(t.ref)
                    vals.append(t._eager)
            elif kind == "const":
                refs.append(Const(a))
                vals.append(a)
            else:  # feed
                refs.append(FeedRef(ordinal, pos))
                feed_avals.append((pos, Aval.of(a)))
                feed_values[pos] = a
                self._feed_log[(ordinal, pos)] = a
                vals.append(a)

        entry = TraceEntry(op_name=name, attrs=attrs_t, location=loc,
                           input_refs=tuple(refs), out_avals=(),
                           feed_avals=tuple(feed_avals))

        if self.mode == SKELETON:
            try:
                avals, uid = self.walker.advance(entry, ordinal, feed_values)
            except DivergenceError as e:
                self._fallback_replay(str(e))
                # placeholders now hold concrete values — rebuild the args
                vals = self._vals_for_entry(entry, ordinal)
                return self._exec_eager(entry, ordinal, vals)
            entry.out_avals = avals
            self.trace.add_entry(entry)
            outs = tuple(
                TerraTensor(Ref(ordinal, oi), avals[oi], engine=self,
                            iter_id=self.iter_id)
                for oi in range(len(avals)))
            for oi, t in enumerate(outs):
                self._tensors[(ordinal, oi)] = t
            if self.walker.boundary_reached is not None:
                seg = self.walker.boundary_reached
                self.walker.boundary_reached = None
                self.walker.seg_idx = seg + 1
                self.dispatcher.on_boundary(seg)
            return outs if len(outs) > 1 else outs[0]

        return self._exec_eager(entry, ordinal, vals)

    def _vals_for_entry(self, entry: TraceEntry, ordinal: int):
        vals = []
        for pos, r in enumerate(entry.input_refs):
            if isinstance(r, Ref):
                vals.append(self._vals[(r.entry, r.out_idx)])
            elif isinstance(r, FeedRef):
                vals.append(self._feed_log[(ordinal, pos)])
            elif isinstance(r, VarRef):
                # read_initial: a divergence rollback may have removed the
                # seed buffer of a variable first registered this iteration
                vals.append(self.store.read_initial(r.var_id))
            elif isinstance(r, Const):
                vals.append(r.value)
        return vals

    def _exec_eager(self, entry: TraceEntry, ordinal: int, vals):
        out = ops_mod.OPS[entry.op_name].impl(*vals, **dict(entry.attrs))
        outs = out if isinstance(out, tuple) else (out,)
        entry.out_avals = tuple(Aval.of(o) for o in outs)
        self.trace.add_entry(entry)
        ts = tuple(TerraTensor(Ref(ordinal, oi), entry.out_avals[oi],
                               eager=o, engine=self, iter_id=self.iter_id)
                   for oi, o in enumerate(outs))
        for oi, t in enumerate(ts):
            self._tensors[(ordinal, oi)] = t
            self._vals[(ordinal, oi)] = outs[oi]
        return ts if len(ts) > 1 else ts[0]

    # ------------------------------------------------------------------
    # tape support (GradientTape reads the recorded trace back out)
    # ------------------------------------------------------------------
    def tape_mark(self) -> int:
        return len(self.trace.entries)

    def tape_slice(self, start: int):
        entries = [(i, e) for i, e in enumerate(self.trace.entries[start:],
                                                start=start)]

        def tensors_of(ordinal):
            e = self.trace.entries[ordinal]
            return [self._tensors[(ordinal, oi)]
                    for oi in range(len(e.out_avals))]
        return entries, tensors_of

    def tensors_for_input_slots(self, ordinal: int, entry: TraceEntry):
        out = []
        for pos, r in enumerate(entry.input_refs):
            if isinstance(r, Ref):
                out.append(self._tensors[(r.entry, r.out_idx)])
            elif isinstance(r, FeedRef):
                out.append(self._feed_log[(ordinal, pos)])
            elif isinstance(r, VarRef):
                var = self.vars[r.var_id]
                t = TerraTensor(VarRef(r.var_id), var.aval, engine=self,
                                iter_id=self.iter_id)
                if self.mode != SKELETON:
                    t._eager = self.store.get(r.var_id, var._value)
                out.append(t)
            elif isinstance(r, Const):
                out.append(r.value)
        return out

    # ------------------------------------------------------------------
    # materialization (Output Fetching)
    # ------------------------------------------------------------------
    def materialize(self, t: TerraTensor):
        if t._eager is not None:
            return t._eager
        if t._future is not None:
            # a fetch future was attached when the producing iteration
            # closed: the value is awaitable even after later iterations
            # started (lag-harvest; steady-state outputs carry only this)
            return self._await(t, t._future)
        ref = t.ref
        if isinstance(ref, VarRef):
            return self.variable_value(self.vars[ref.var_id])
        if t._iter != self.iter_id or self.mode != SKELETON:
            # stale placeholder from an earlier iteration
            raise RuntimeError("placeholder escaped its iteration without "
                               "being fetch-marked")
        if self._iter_open:
            self.trace.events.append(SyncMarker(ref))
        self.trace.fetches.append(ref)
        try:
            uid, oi = self.walker.uid_of(ref)
        except ReplayRequired:
            self._recover_value()
            return t._eager
        node = self.tg.nodes[uid]
        if self.dispatcher.kind == "chain":
            # chains output every produced value — no replay needed even
            # for never-before-seen fetches (annotate for future graphs)
            node.fetch_idxs.add(oi)
            fut = self.dispatcher.future_for(ref)
            if fut is None and self._iter_open:
                try:
                    self.dispatcher.flush()
                except ReplayRequired:
                    # the chain needed a value the optimized segments no
                    # longer publish (DCE'd): recover via eager replay
                    self._recover_value()
                    return t._eager
                fut = self.dispatcher.future_for(ref)
            if fut is not None:
                return self._await(t, fut)
            self._recover_value()
            return t._eager
        if oi not in node.fetch_idxs:
            # never-before-seen fetch: annotate & recover via replay
            node.fetch_idxs.add(oi)
            if self._iter_open:
                node.sync_after = True
            self.tg.version += 1
            self._recover_value()
            return t._eager
        fut = self.dispatcher.future_for(ref)
        if fut is None and self._iter_open:
            # fetch gates Python mid-segment (e.g. inside a branch region):
            # switch to path-specialized dispatch — jit the exact walked
            # chain instead of replaying eagerly (DESIGN.md §2)
            self.dispatcher = ChainDispatcher(self.dispatcher,
                                              self._feed_log,
                                              self._chain_cache)
            try:
                self.dispatcher.flush()
            except ReplayRequired:
                self._recover_value()
                return t._eager
            fut = self.dispatcher.future_for(ref)
        if fut is None:
            self._recover_value()
            return t._eager
        return self._await(t, fut)

    def _await(self, t: TerraTensor, fut):
        t0 = time.perf_counter()
        if self.runner.lazy:
            self.runner.run_pending_now()
        v = fut.result()
        self.events.add("py_stall_time", time.perf_counter() - t0)
        t._eager = v
        return v

    def note_fetch(self, t: TerraTensor):
        """Record a fetch point observed while the value was already eager
        (tracing phase, or post-replay).  Paper §4.2: fetch points are
        captured during tracing and annotated in the TraceGraph."""
        ref = t.ref
        if not isinstance(ref, Ref):
            return
        if t._iter == self.iter_id and self._iter_open:
            self.trace.events.append(SyncMarker(ref))
            self.trace.fetches.append(ref)
        elif t._iter == self.iter_id and not self._iter_open:
            # materialized after the iteration closed (e.g. the returned
            # loss): annotate the merged node as a non-gating fetch
            ord_map = getattr(self.tg, "last_ord_to_uid", None)
            if ord_map and ref.entry in ord_map:
                n = self.tg.nodes[ord_map[ref.entry]]
                oi = (n.body.out_slot_for(ref, ()) if n.kind == "loop"
                      else ref.out_idx)
                if oi not in n.fetch_idxs:
                    n.fetch_idxs.add(oi)
                    self.tg.version += 1
