"""Dispatchers: how validated work reaches the GraphRunner.

One :class:`Dispatcher` protocol covers the two dispatch strategies that
used to be duplicated inside the runner god-module:

* :class:`SegmentDispatcher` — the normal co-execution path: at every
  segment boundary (a top-level gating fetch, DESIGN.md §2) the
  pre-compiled ``SegProg.fn`` is submitted to the GraphRunner with its
  Input Feeding values, Case Select / Loop Cond arrays, carried values and
  variable buffers.  Donation-eligible variable buffers (computed statically
  per segment by graphgen, DESIGN.md §4.2) are passed through the donated
  argument so XLA can reuse them in place for ``var_out``.

* :class:`ChainDispatcher` — path-specialized dispatch for gating fetches
  that are *not* at a top-level segment boundary (e.g. inside a branch
  region): the exact linear chain of already-validated ops is jitted —
  selectors are resolved by construction, so no switch machinery is needed —
  and every produced value gets a future, replacing the old eager-replay
  fallback for structurally awkward programs.

An iteration starts with a SegmentDispatcher; the coordinator swaps in a
ChainDispatcher (which keeps a handle on its parent so segment futures stay
fetchable) the first time a mid-segment fetch gates Python.  Neither
dispatcher blocks on device readiness: results travel through futures and
XLA's async queue, and Python stalls only at actual fetch points.
"""

from __future__ import annotations

import time
import warnings
from concurrent.futures import Future
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax

_EMPTY_I32 = np.zeros(0, np.int32)      # shared: no Case Select / Loop Cond

from repro.core import ops as ops_mod
from repro.core.ops import Const
from repro.core.trace import FeedRef, Ref, Trace, VarRef
from repro.core.executor.walker import ReplayRequired, Walker

# Donation is best-effort: when an output cannot alias a donated input the
# backend copies and warns; the suppression is scoped to the run closure so
# user code keeps its own donation warnings.


class Dispatcher:
    """Protocol for per-iteration dispatch strategies.

    ``kind``                   — "segments" | "chain" (coordinator branches
                                 on it at fetch points).
    ``on_boundary(seg_idx)``   — a top-level gating fetch point was walked.
    ``finish()``               — iteration validated to END: flush trailing
                                 work (side effects included).
    ``future_for(ref)``        — Future for a produced value, or None if
                                 this dispatcher will not produce it.  May
                                 raise ReplayRequired for unknown producers.
    """

    kind = "abstract"

    def on_boundary(self, seg_idx: int) -> None:
        raise NotImplementedError

    def finish(self) -> None:
        raise NotImplementedError

    def future_for(self, ref: Ref) -> Optional[Future]:
        raise NotImplementedError


# ==========================================================================
# Segment dispatch
# ==========================================================================

class SegmentDispatcher(Dispatcher):
    kind = "segments"

    def __init__(self, gp, walker: Walker, trace: Trace, runner, store,
                 stats):
        self.gp = gp
        self.walker = walker
        self.trace = trace
        self.runner = runner
        self.store = store
        self.stats = stats
        self.fetch_futures: Dict[Tuple[int, int], Future] = {}
        self.iter_env: Dict[Tuple[int, int], Any] = {}  # runner-thread env
        self._through = -1
        # ordinal boundary a chain continuation picks up from
        self.ordinal_at_dispatch = 0

    # ------------------------------------------------------------------
    def on_boundary(self, seg_idx: int) -> None:
        self.dispatch_through(seg_idx)

    def finish(self) -> None:
        self.dispatch_through(len(self.gp.seg_progs) - 1)

    def future_for(self, ref: Ref) -> Optional[Future]:
        uid, oi = self.walker.uid_of(ref)       # ReplayRequired propagates
        return self.fetch_futures.get((uid, oi))

    # ------------------------------------------------------------------
    def dispatch_through(self, seg_idx: int) -> None:
        """Submit every not-yet-dispatched segment up to ``seg_idx`` as
        straight array fills against the precomputed DispatchPlan
        (graphgen.py, DESIGN.md §4.4) — no sorting, no per-op dict probing.
        Case Select / Loop Cond arrays are built once per call: the Walker
        cannot add entries between two segments of the same call."""
        start = self._through + 1
        if seg_idx < start:
            self.ordinal_at_dispatch = len(self.trace.entries)
            return
        t0 = time.perf_counter()
        gp, walker, store, stats = self.gp, self.walker, self.store, self.stats
        buffers, iter_env = store.buffers, self.iter_env
        feed_vals = walker.feed_vals
        plan0 = gp.seg_progs[start].plan
        sels = trips = _EMPTY_I32
        if plan0.sel_uids:
            g = walker.sels.get
            sels = np.fromiter((g(u, 0) for u in plan0.sel_uids),
                               np.int32, len(plan0.sel_uids))
        if plan0.trip_uids:
            g = walker.trips.get
            trips = np.fromiter((g(u, 0) for u in plan0.trip_uids),
                                np.int32, len(plan0.trip_uids))
        for si in range(start, seg_idx + 1):
            sp = gp.seg_progs[si]
            plan = sp.plan
            feeds = []
            for (uid, pos, aval) in plan.feed_keys:
                v = feed_vals.get((uid, pos))
                if v is None:
                    # a feed slot of an untaken region was never collected
                    v = np.zeros(aval.shape, aval.dtype)
                    stats["feeds_defaulted"] += 1
                feeds.append(v)
            if plan.fetch_keys:
                futures = {k: Future() for k in plan.fetch_keys}
                self.fetch_futures.update(futures)
            else:
                futures = {}

            def run(sp=sp, plan=plan, feeds=tuple(feeds), sels=sels,
                    trips=trips, futures=futures):
                don_in = tuple(store.read(v) for v in plan.don_var_ids)
                keep_in = tuple(store.read(v) for v in plan.keep_var_ids)
                if don_in:
                    stats["donated_bytes"] += sum(
                        int(getattr(b, "nbytes", 0)) for b in don_in)
                carries = tuple(iter_env[k] for k in plan.carries_in)
                try:
                    with warnings.catch_warnings():
                        warnings.filterwarnings(
                            "ignore",
                            message="Some donated buffers were not usable")
                        var_out, fetches, carries_out = sp.fn(
                            don_in, keep_in, feeds, sels, trips, carries)
                except Exception as e:      # propagate into futures
                    for f in futures.values():
                        if not f.done():
                            f.set_exception(e)
                    raise
                for vid, v in zip(plan.var_writes, var_out):
                    buffers[vid] = v
                for k, v in zip(plan.carries_out, carries_out):
                    iter_env[k] = v
                for k, v in zip(plan.fetch_keys, fetches):
                    futures[k].set_result(v)

            # the fence is the submit sequence itself: even if the closure
            # raises, the runner completes the sequence, so fences release
            seq = self.runner.submit(run)
            store.fence(plan.don_var_ids, plan.var_writes, seq)
            store.fence(plan.keep_var_ids, (), seq)
            stats["segments_dispatched"] += 1
            self._through = si
        self.ordinal_at_dispatch = len(self.trace.entries)
        stats["dispatch_time"] += time.perf_counter() - t0


# ==========================================================================
# Path-specialized chain dispatch
# ==========================================================================

class ChainDispatcher(Dispatcher):
    kind = "chain"

    def __init__(self, parent: SegmentDispatcher, feed_log: Dict,
                 chain_cache: Dict[Tuple, Any]):
        self.parent = parent
        self.walker = parent.walker
        self.tg = parent.gp.tg
        self.trace = parent.trace
        self.runner = parent.runner
        self.store = parent.store
        self.stats = parent.stats
        self.feed_log = feed_log
        self.chain_cache = chain_cache          # engine-lifetime jit cache
        self.chain_env: Dict[Tuple[int, int], Any] = {}
        self.futures: Dict[Tuple[int, int], Future] = {}
        # the chain picks up after whatever segments already dispatched
        self.start = parent.ordinal_at_dispatch

    # ------------------------------------------------------------------
    def on_boundary(self, seg_idx: int) -> None:
        pass        # chains ignore segment boundaries

    def finish(self) -> None:
        self.flush()                            # trailing chain (side effects)

    def future_for(self, ref: Ref) -> Optional[Future]:
        fut = self.futures.get((ref.entry, ref.out_idx))
        if fut is not None:
            return fut
        try:
            return self.parent.future_for(ref)  # dispatched-segment values
        except ReplayRequired:
            return None

    # ------------------------------------------------------------------
    def flush(self) -> None:
        """Jit + submit the chain of ops recorded since the last flush."""
        start, end = self.start, len(self.trace.entries)
        if end <= start:
            return
        entries = self.trace.entries[start:end]

        key_parts = []
        ext_plan: List[Tuple] = []   # ('chain', e, oi) | ('seg', uid, oi)
        ext_index: Dict[Tuple, int] = {}
        feeds = []
        var_ids: List[int] = []
        var_index: Dict[int, int] = {}
        arg_plans = []
        for local, e in enumerate(entries):
            plan = []
            for pos, r in enumerate(e.input_refs):
                if isinstance(r, Ref) and r.entry >= start:
                    plan.append(("i", r.entry - start, r.out_idx))
                elif isinstance(r, Ref):
                    k = ("r", r.entry, r.out_idx)
                    if k not in ext_index:
                        ext_index[k] = len(ext_plan)
                        uid = self.walker.ord_to_uid.get(r.entry)
                        # values produced by an earlier chain flush are keyed
                        # by futures (updated synchronously on this thread);
                        # chain_env is runner-thread state and may lag
                        if (r.entry, r.out_idx) in self.futures or uid is None:
                            ext_plan.append(("chain", r.entry, r.out_idx))
                        else:
                            n = self.tg.nodes[uid]
                            oi = (n.body.out_slot_for(r, ())
                                  if n.kind == "loop" else r.out_idx)
                            ext_plan.append(("seg", uid, oi))
                    plan.append(("x", ext_index[k]))
                elif isinstance(r, FeedRef):
                    plan.append(("f", len(feeds)))
                    feeds.append(self.feed_log[(start + local, pos)])
                elif isinstance(r, VarRef):
                    if r.var_id not in var_index:
                        var_index[r.var_id] = len(var_ids)
                        var_ids.append(r.var_id)
                    plan.append(("v", var_index[r.var_id]))
                else:
                    plan.append(("c", r.value))
            arg_plans.append(tuple(plan))
            key_parts.append((e.op_name, e.attrs, e.location,
                              tuple((p[0],) + tuple(p[1:]) for p in plan)))
        key = (start == 0, tuple(key_parts))

        fn = self.chain_cache.get(key)
        if fn is None:
            fn = _build_chain_fn(entries, arg_plans)
            self.chain_cache[key] = fn

        # futures for every produced value
        produced = []
        futures = {}
        for j, e in enumerate(entries):
            for oi in range(len(e.out_avals)):
                futures[(start + j, oi)] = Future()
                produced.append((start + j, oi))
        self.futures.update(futures)

        assigns = {vid: ref for vid, ref in self.trace.var_assigns.items()
                   if isinstance(ref, Ref) and start <= ref.entry < end}
        buffers = self.store.buffers
        iter_env = self.parent.iter_env
        chain_env = self.chain_env

        def run(fn=fn, var_ids=tuple(var_ids), feeds=tuple(feeds),
                ext_plan=tuple(ext_plan), futures=futures, assigns=assigns,
                produced=tuple(produced)):
            var_vals = tuple(buffers[v] for v in var_ids)
            exts = tuple(chain_env[(p[1], p[2])] if p[0] == "chain"
                         else iter_env[(p[1], p[2])] for p in ext_plan)
            try:
                outs = fn(var_vals, feeds, exts)
            except Exception as exc:        # noqa: BLE001
                for f in futures.values():
                    if not f.done():
                        f.set_exception(exc)
                raise
            for (ordv, v) in zip(produced, outs):
                chain_env[ordv] = v
                futures[ordv].set_result(v)
            for vid, ref in assigns.items():
                buffers[vid] = chain_env[(ref.entry, ref.out_idx)]

        seq = self.runner.submit(run)
        self.store.fence(var_ids, assigns, seq)
        self.stats["segments_dispatched"] += 1
        self.start = end


def _build_chain_fn(entries, arg_plans):
    """Jit the linear op chain: (var_vals, feed_vals, ext_vals) -> flat outs."""
    impls = [ops_mod.OPS[e.op_name].impl for e in entries]
    attrs = [dict(e.attrs) for e in entries]
    plans = list(arg_plans)

    def chain_fn(var_vals, feed_vals, ext_vals):
        env: Dict[Tuple[int, int], Any] = {}
        flat_out = []
        for j, impl in enumerate(impls):
            vals = []
            for p in plans[j]:
                if p[0] == "i":
                    vals.append(env[(p[1], p[2])])
                elif p[0] == "x":
                    vals.append(ext_vals[p[1]])
                elif p[0] == "f":
                    vals.append(feed_vals[p[1]])
                elif p[0] == "v":
                    vals.append(var_vals[p[1]])
                else:
                    vals.append(p[1])
            out = impl(*vals, **attrs[j])
            outs = out if isinstance(out, tuple) else (out,)
            for oi, v in enumerate(outs):
                env[(j, oi)] = v
            flat_out.extend(outs)
        return tuple(flat_out)

    return jax.jit(chain_fn)
