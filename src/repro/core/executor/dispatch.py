"""Dispatchers: how validated work reaches the GraphRunner.

One :class:`Dispatcher` protocol covers the two dispatch strategies that
used to be duplicated inside the runner god-module:

* :class:`SegmentDispatcher` — the normal co-execution path: at every
  segment boundary (a top-level gating fetch, DESIGN.md §2) the
  pre-compiled ``SegProg.fn`` is submitted to the GraphRunner with its
  Input Feeding values, Case Select / Loop Cond arrays, carried values and
  variable buffers.  Donation-eligible variable buffers (computed statically
  per segment by graphgen, DESIGN.md §4.2) are passed through the donated
  argument so XLA can reuse them in place for ``var_out``.

* :class:`ChainDispatcher` — path-specialized dispatch for gating fetches
  that are *not* at a top-level segment boundary (e.g. inside a branch
  region): the exact linear chain of already-validated ops is jitted —
  selectors are resolved by construction, so no switch machinery is needed —
  and every produced value gets a future, replacing the old eager-replay
  fallback for structurally awkward programs.

An iteration starts with a SegmentDispatcher; the coordinator swaps in a
ChainDispatcher (which keeps a handle on its parent so segment futures stay
fetchable) the first time a mid-segment fetch gates Python.  Neither
dispatcher blocks on device readiness: results travel through futures and
XLA's async queue, and Python stalls only at actual fetch points.
"""

from __future__ import annotations

import time
import warnings
from concurrent.futures import Future
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

_EMPTY_I32 = np.zeros(0, np.int32)      # shared: no Case Select / Loop Cond

from repro.core.events import emit as ev
from repro.core.trace import Ref, Trace
from repro.core.executor.walker import Walker

# Donation is best-effort: when an output cannot alias a donated input the
# backend copies and warns; the suppression is scoped to the run closure so
# user code keeps its own donation warnings.


class Dispatcher:
    """Protocol for per-iteration dispatch strategies.

    ``kind``                   — "segments" | "chain" (coordinator branches
                                 on it at fetch points).
    ``on_boundary(seg_idx)``   — a top-level gating fetch point was walked.
    ``finish()``               — iteration validated to END: flush trailing
                                 work (side effects included).
    ``future_for(ref)``        — Future for a produced value, or None if
                                 this dispatcher will not produce it.  May
                                 raise ReplayRequired for unknown producers.
    """

    kind = "abstract"

    def on_boundary(self, seg_idx: int) -> None:
        raise NotImplementedError

    def finish(self) -> None:
        raise NotImplementedError

    def future_for(self, ref: Ref) -> Optional[Future]:
        raise NotImplementedError


# ==========================================================================
# Segment dispatch
# ==========================================================================

class SegmentDispatcher(Dispatcher):
    kind = "segments"

    def __init__(self, gp, walker: Walker, trace: Trace, runner, store,
                 events, strict_feeds: bool = True, warn_latch=None,
                 iter_id: int = -1, profile: bool = False):
        self.gp = gp
        # sampled device-time attribution (DESIGN.md §15): decided once
        # per iteration by the coordinator; captured by run closures
        self.profile = profile
        self.walker = walker
        self.trace = trace
        self.runner = runner
        self.store = store
        self.events = events
        self.stats = events.counters
        self.iter_id = iter_id
        self.strict_feeds = strict_feeds
        # engine-lifetime warn-once latch for strict_feeds=False (a list
        # owned by the coordinator: dispatchers are per-iteration)
        self.warn_latch = warn_latch if warn_latch is not None else []
        self.fetch_futures: Dict[Tuple[int, int], Future] = {}
        self.iter_env: Dict[Tuple[int, int], Any] = {}  # runner-thread env
        self._through = -1
        # ordinal boundary a chain continuation picks up from
        self.ordinal_at_dispatch = 0

    # ------------------------------------------------------------------
    def on_boundary(self, seg_idx: int) -> None:
        self.dispatch_through(seg_idx)

    def finish(self) -> None:
        self.dispatch_through(len(self.gp.seg_progs) - 1)

    def future_for(self, ref: Ref) -> Optional[Future]:
        uid, oi = self.walker.uid_of(ref)       # ReplayRequired propagates
        return self.fetch_futures.get((uid, oi))

    # ------------------------------------------------------------------
    def dispatch_through(self, seg_idx: int) -> None:
        """Submit every not-yet-dispatched segment up to ``seg_idx`` as
        straight array fills against the precomputed DispatchPlan
        (graphgen.py, DESIGN.md §4.4) — no sorting, no per-op dict probing.
        Case Select / Loop Cond arrays are built once per call: the Walker
        cannot add entries between two segments of the same call."""
        start = self._through + 1
        if seg_idx < start:
            self.ordinal_at_dispatch = len(self.trace.entries)
            return
        t0 = time.perf_counter()
        gp, walker, store, stats = self.gp, self.walker, self.store, self.stats
        buffers, iter_env = store.buffers, self.iter_env
        feed_vals = walker.feed_vals
        plan0 = gp.seg_progs[start].plan
        sels = trips = _EMPTY_I32
        if plan0.sel_uids:
            g = walker.sels.get
            sels = np.fromiter((g(u, 0) for u in plan0.sel_uids),
                               np.int32, len(plan0.sel_uids))
        if plan0.trip_uids:
            g = walker.trips.get
            trips = np.fromiter((g(u, 0) for u in plan0.trip_uids),
                                np.int32, len(plan0.trip_uids))
        taken = None
        for si in range(start, seg_idx + 1):
            sp = gp.seg_progs[si]
            plan = sp.plan
            feeds = []
            for (uid, pos, aval) in plan.feed_keys:
                v = feed_vals.get((uid, pos))
                if v is None:
                    # zeros substitution is legitimate ONLY for feed slots
                    # of an untaken branch region; a missing feed on a node
                    # the Walker actually validated means the segment would
                    # silently compute on zeros — raise at dispatch time
                    # (warn once when the engine opted out, DESIGN.md §4.4)
                    if taken is None:          # built lazily: defaults are
                        taken = walker.taken_uids()        # the rare path
                    if uid in taken:
                        msg = (f"Input Feeding value for TraceGraph node "
                               f"{uid} arg {pos} was never collected on "
                               f"the taken path; segment {si} would "
                               f"compute on zeros")
                        if self.strict_feeds:
                            raise RuntimeError(msg)
                        if not self.warn_latch:
                            self.warn_latch.append(True)
                            warnings.warn(msg + " (strict_feeds disabled)",
                                          RuntimeWarning, stacklevel=2)
                    v = np.zeros(aval.shape, aval.dtype)
                    stats["feeds_defaulted"] += 1
                feeds.append(v)
            if plan.fetch_keys:
                futures = {k: Future() for k in plan.fetch_keys}
                self.fetch_futures.update(futures)
            else:
                futures = {}

            def run(sp=sp, plan=plan, feeds=tuple(feeds), sels=sels,
                    trips=trips, futures=futures, si=si,
                    profile=self.profile):
                don_in = tuple(store.read(v) for v in plan.don_var_ids)
                keep_in = tuple(store.read(v) for v in plan.keep_var_ids)
                if don_in:
                    stats["donated_bytes"] += sum(
                        int(getattr(b, "nbytes", 0)) for b in don_in)
                carries = tuple(iter_env[k] for k in plan.carries_in)
                if profile:
                    pt0 = time.perf_counter()
                try:
                    with warnings.catch_warnings():
                        warnings.filterwarnings(
                            "ignore",
                            message="Some donated buffers were not usable")
                        var_out, fetches, carries_out = sp.fn(
                            don_in, keep_in, feeds, sels, trips, carries)
                except Exception as e:      # propagate into futures
                    for f in futures.values():
                        if not f.done():
                            f.set_exception(e)
                    raise
                if profile:
                    # sampled device-time attribution (DESIGN.md §15):
                    # the dispatch call returns as soon as XLA enqueues;
                    # blocking on the outputs here — on the runner thread,
                    # off the imperative thread — exposes device time
                    pt1 = time.perf_counter()
                    jax.block_until_ready((var_out, fetches, carries_out))
                    ev.segment_profile(
                        self.events, self.iter_id, "segment", si,
                        pt1 - pt0, time.perf_counter() - pt0,
                        plan.kernel_ops)
                for vid, v in zip(plan.var_writes, var_out):
                    buffers[vid] = v
                for k, v in zip(plan.carries_out, carries_out):
                    iter_env[k] = v
                for k, v in zip(plan.fetch_keys, fetches):
                    futures[k].set_result(v)

            # the fence is the submit sequence itself: even if the closure
            # raises, the runner completes the sequence, so fences release
            seq = self.runner.submit(run)
            store.fence(plan.don_var_ids, plan.var_writes, seq)
            store.fence(plan.keep_var_ids, (), seq)
            stats["segments_dispatched"] += 1
            ev.segment_dispatch(self.events, self.iter_id, "segment", si,
                                seq, len(feeds))
            self._through = si
        self.ordinal_at_dispatch = len(self.trace.entries)
        stats["dispatch_time"] += time.perf_counter() - t0



# Path-specialized chain dispatch lives in chains.py; re-exported here so
# historical import paths (and the runner.py shim) keep working.  The
# import sits at module end: chains.py imports Dispatcher/SegmentDispatcher
# from this module, which are defined by the time this line runs.
from repro.core.executor.chains import ChainDispatcher  # noqa: E402,F401
