"""The Terra executor package: runtime split along its natural seams.

    coordinator.py   — TerraEngine, the phase-machine coordinator
    graph_runner.py  — GraphRunner, the ordered async executor thread
    walker.py        — Walker, TraceGraph validation / Case Select & Loop Cond
    dispatch.py      — Dispatcher protocol; segment + path-chain dispatchers
    fallback.py      — divergence cancellation + validated-prefix replay
    variables.py     — VariableStore, the device-resident variable buffers
    segment_cache.py — cross-version/cross-family compiled-segment cache
    families.py      — shape-keyed TraceGraph families + LRU (DESIGN.md §8)

See DESIGN.md §3 for the layering contract.  ``repro.core.runner`` remains
as a compatibility shim re-exporting this surface.
"""

from repro.core.executor.coordinator import (IMPERATIVE, SKELETON, TRACING,
                                             TerraEngine)
from repro.core.executor.dispatch import (ChainDispatcher, Dispatcher,
                                          SegmentDispatcher)
from repro.core.executor.fallback import DivergenceHandler
from repro.core.executor.families import (FamilyManager, TraceFamily,
                                          bucket_pow2, feed_signature)
from repro.core.executor.graph_runner import GraphRunner
from repro.core.executor.segment_cache import SegmentCache, segment_signature
from repro.core.executor.variables import VariableStore
from repro.core.executor.walker import (DivergenceError, ReplayRequired,
                                        Walker)

__all__ = [
    "TerraEngine", "GraphRunner", "Walker", "VariableStore",
    "Dispatcher", "SegmentDispatcher", "ChainDispatcher",
    "DivergenceHandler", "SegmentCache", "segment_signature",
    "FamilyManager", "TraceFamily", "bucket_pow2", "feed_signature",
    "DivergenceError", "ReplayRequired",
    "IMPERATIVE", "TRACING", "SKELETON",
]
