"""Walker: the PythonRunner's TraceGraph cursor (paper §4.1).

As the skeleton program executes, every DL op is *validated* against the
TraceGraph ("continuously compares the trace with the TraceGraph"): the
Walker advances a cursor through the merged DAG, resolving Case Select
values at forks, Loop Cond trip counts at rolled loops, and collecting
Input Feeding values.  A mismatch raises :class:`DivergenceError`, which the
coordinator turns into the divergence fallback (executor/fallback.py).

The Walker is (almost) a pure consumer of the TraceGraph — fetch
annotation stays in the coordinator, and it holds only per-iteration
cursor state, so a fresh Walker is built at every skeleton iteration
start.  The one exception is warm boot (core/persist/, DESIGN.md §14):
nodes hydrated from the artifact store carry ``entry_stamp=None``
(process-salted hashes don't persist), and the Walker re-stamps them as
it structurally validates each one on the first iteration.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax

from repro.core.ops import Const
from repro.core.trace import Aval, FeedRef, Ref, TraceEntry, VarRef


def _feed_stager():
    """How collected Input Feeding values are staged (DESIGN.md §4.4).

    On accelerator backends every feed is ``jax.device_put`` the moment the
    Walker collects it, so the host→device transfer overlaps the rest of
    skeleton execution instead of serializing into dispatch.  On CPU there
    is no transfer to overlap — device_put is a synchronous copy that only
    adds latency — so values pass through untouched."""
    global _STAGE_FEED
    if jax.default_backend() == "cpu":
        _STAGE_FEED = lambda v: v
    else:
        _STAGE_FEED = jax.device_put
    return _STAGE_FEED


_STAGE_FEED = None


class DivergenceError(Exception):
    """Raised by the Walker when the current trace escapes the TraceGraph."""


class ReplayRequired(Exception):
    """Materialization needs a value the symbolic graph does not output."""


class _LoopState:
    def __init__(self, node):
        self.node = node
        self.body = node.body
        self.pos = 0
        self.trips = 0
        self.prev_prod: Dict[Tuple[int, int], int] = {}  # local (j,oi) -> ordinal
        self.cur_prod: Dict[Tuple[int, int], int] = {}
        self.entry_ordinals: List[int] = []


class Walker:
    """Advances through the TraceGraph as the skeleton executes, recording
    Case Select / Loop Cond / Input Feeding values and detecting new
    traces."""

    def __init__(self, gp):
        self.gp = gp
        self.tg = gp.tg             # validation runs on the ORIGINAL graph
        self.cursor = self.tg.start.uid
        self.region_stack: List[int] = []      # join uids
        self.seg_idx = 0
        self.sels: Dict[int, int] = {}
        self.trips: Dict[int, int] = {}
        self.feed_vals: Dict[Tuple[int, int], Any] = {}
        # raw (unstaged) feed objects, for identity checks by the steady-
        # state planner: (uid, pos) -> the exact value the skeleton passed
        self.feed_raw: Dict[Tuple[int, int], Any] = {}
        self.ord_to_uid: Dict[int, int] = {}
        self.loop: Optional[_LoopState] = None
        self.boundary_reached: Optional[int] = None
        self.fast_hits = 0          # ops validated via the stamp fast path
        self.fold_misses = 0        # folded-feed value mismatches (→ diverge)
        # segment boundaries follow the OPTIMIZED graph (coalescing may
        # have cleared gating flags); identical to the sync_after set when
        # optimization is off
        self._boundaries = gp.boundary_uids
        self._folded = gp.folded_feeds
        self._stage = _STAGE_FEED or _feed_stager()

    # -- src resolution (must mirror TraceGraph.merge_trace) --------------
    def _src_of(self, ref, pos, entry):
        if isinstance(ref, Ref):
            uid = self.ord_to_uid.get(ref.entry)
            if uid is None:
                raise DivergenceError("ref to unknown producer")
            n = self.tg.nodes[uid]
            if n.kind == "loop":
                return ("node", uid, n.body.out_slot_for(ref, ()))
            return ("node", uid, ref.out_idx)
        if isinstance(ref, FeedRef):
            return ("feed", dict(entry.feed_avals).get(pos))
        if isinstance(ref, VarRef):
            return ("var", ref.var_id)
        if isinstance(ref, Const):
            return ("const", ref.value)
        raise DivergenceError(f"unknown ref {ref!r}")

    def _entry_sig(self, entry: TraceEntry):
        srcs = tuple(self._src_of(r, i, entry)
                     for i, r in enumerate(entry.input_refs))
        return (entry.op_name, entry.attrs, entry.location, srcs)

    # -- loop-body matching -------------------------------------------------
    def _match_body_entry(self, ls: _LoopState, entry: TraceEntry) -> bool:
        body, j = ls.body, ls.pos
        if j >= len(body.entries):
            return False
        be = body.entries[j]
        if (entry.op_name, entry.attrs, entry.location) != (
                be.op_name, be.attrs, be.location):
            return False
        n_car = len(body.carries)
        for pos, (ref, s) in enumerate(zip(entry.input_refs, be.srcs_local)):
            kind = s[0]
            if kind == "node":
                if not (isinstance(ref, Ref)
                        and ls.cur_prod.get((s[1], s[2])) == ref.entry):
                    return False
            elif kind == "carry":
                init_src, prod = body.carries[s[1]]
                if ls.trips == 0:
                    want = self.gp.tg.nodes[ls.node.uid].srcs[s[1]]
                    if self._src_of(ref, pos, entry) != want:
                        return False
                else:
                    if not (isinstance(ref, Ref)
                            and ls.prev_prod.get(prod) == ref.entry):
                        return False
            elif kind == "inv":
                want = self.gp.tg.nodes[ls.node.uid].srcs[n_car + s[1]]
                if self._src_of(ref, pos, entry) != want:
                    return False
            elif kind == "const":
                if not (isinstance(ref, Const) and ref.value == s[1]):
                    return False
            elif kind == "var":
                if not (isinstance(ref, VarRef) and ref.var_id == s[1]):
                    return False
            else:
                return False
        return True

    def _loop_step(self, ls: _LoopState, entry: TraceEntry, ordinal: int):
        j = ls.pos
        for oi in range(len(ls.body.entries[j].out_avals)):
            ls.cur_prod[(j, oi)] = ordinal
        ls.cur_prod.setdefault((j, -1), ordinal)
        ls.entry_ordinals.append(ordinal)
        ls.pos += 1
        if ls.pos == len(ls.body.entries):
            ls.trips += 1
            ls.pos = 0
            ls.prev_prod = ls.cur_prod
            ls.cur_prod = {}
        return ls.body.entries[j].out_avals

    def _exit_loop(self):
        ls = self.loop
        n = ls.node
        if ls.pos != 0:
            raise DivergenceError("loop exited mid-body")
        if len(n.trips) == 1:
            if ls.trips != next(iter(n.trips)):
                raise DivergenceError("unrolled loop trip-count changed")
        else:
            self.trips[n.uid] = ls.trips
        for o in ls.entry_ordinals:
            self.ord_to_uid[o] = n.uid
        n._last_ordinals = tuple(ls.entry_ordinals)
        self.loop = None
        self.cursor = n.uid

    # -- main advance ---------------------------------------------------------
    def advance(self, entry: TraceEntry, ordinal: int,
                feed_values: Dict[int, Any]) -> Tuple[Tuple[Aval, ...], int]:
        """Validate one op; returns (out_avals, node_uid or body marker).

        Steady-state fast path (DESIGN.md §4.4): every merged node carries
        the hash of the trace entry that last matched it; when the current
        entry's stamp equals a child's stamp the op is accepted with that
        single comparison.  A stamp mismatch falls back to the full
        structural source comparison below — never straight to divergence.
        """
        if self.loop is not None:
            ls = self.loop
            if self._match_body_entry(ls, entry):
                avals = self._loop_step(ls, entry, ordinal)
                return avals, ls.node.uid
            if ls.pos == 0:
                self._exit_loop()       # try to continue after the loop
            else:
                raise DivergenceError("loop body mismatch")

        nodes = self.tg.nodes
        children = nodes[self.cursor].uniq_children()
        if not children:
            raise DivergenceError("walk past end of TraceGraph")

        stamp = entry.stamp()
        if stamp is not None:
            hit = None
            for i, cuid in enumerate(children):
                n = nodes[cuid]
                if n.kind == "loop":
                    # a loop child takes precedence over op siblings in
                    # the structural scan (the entry may open a rolled
                    # body) — abandon the fast path so precedence is
                    # decided structurally, exactly as before
                    hit = None
                    break
                if n.kind == "op" and n.entry_stamp == stamp:
                    if hit is not None:
                        # ambiguous stamp among siblings: two per-path
                        # nodes after a branch re-merge carry identical
                        # raw trace entries (the stamp omits resolved
                        # srcs, which is the only thing telling them
                        # apart) — accepting the first would record the
                        # wrong Case Select and silently compute the
                        # other branch's dataflow.  Resolve structurally.
                        hit = None
                        break
                    hit = (n, i)
            if hit is not None:
                self.fast_hits += 1
                return self._accept(hit[0], hit[1], len(children), ordinal,
                                    feed_values)

        sig = self._entry_sig(entry)
        matched_idx = None
        for i, cuid in enumerate(children):
            n = nodes[cuid]
            if n.kind == "op" and n.sig() == sig:
                matched_idx = i
                break
            if n.kind == "loop":
                ls = _LoopState(n)
                if (entry.op_name, entry.attrs, entry.location) == (
                        n.body.entries[0].op_name, n.body.entries[0].attrs,
                        n.body.entries[0].location):
                    self.loop = ls
                    if self._match_body_entry(ls, entry):
                        matched_idx = i
                        break
                    self.loop = None
        if matched_idx is None:
            raise DivergenceError(
                f"no TraceGraph node matches {entry.op_name} at "
                f"{entry.location}")
        cuid = children[matched_idx]
        node = nodes[cuid]
        if node.kind == "op" and node.entry_stamp is None and \
                stamp is not None:
            # hydrated graphs arrive without stamps — hash() is salted
            # per process, so persisted stamps could never match
            # (persist/codec.py).  Re-stamp on the first structural
            # acceptance so iteration 2 regains the fast path.
            node.entry_stamp = stamp
        if node.kind == "loop":
            if len(children) > 1:
                self.sels[self.cursor] = matched_idx
                join = self.gp.structure.ipdom.get(self.cursor)
                if join is not None:
                    self.region_stack.append(join)
            stage = self._stage
            for pos, v in feed_values.items():
                self.feed_vals[(cuid, pos)] = stage(v)
                self.feed_raw[(cuid, pos)] = v
            avals = self._loop_step(self.loop, entry, ordinal)
            # cursor stays; region bookkeeping on exit
            return avals, cuid
        return self._accept(node, matched_idx, len(children), ordinal,
                            feed_values)

    def _accept(self, node, matched_idx: int, n_children: int, ordinal: int,
                feed_values: Dict[int, Any]) -> Tuple[Tuple[Aval, ...], int]:
        """Commit one validated op node: selector / region bookkeeping,
        Input Feeding collection (values go device-side immediately so the
        host→device transfer overlaps skeleton execution), cursor move and
        segment-boundary detection."""
        cuid = node.uid
        if n_children > 1:
            self.sels[self.cursor] = matched_idx
            join = self.gp.structure.ipdom.get(self.cursor)
            if join is not None:
                self.region_stack.append(join)
        if feed_values:
            stage = self._stage
            folded = self._folded
            for pos, v in feed_values.items():
                if folded:
                    fc = folded.get((cuid, pos))
                    if fc is not None:
                        # constant-folded Input Feed (passes/feed_fold.py):
                        # the baked value must still match — a mismatch is
                        # a divergence, which re-enters tracing, marks the
                        # slot varying and restores the feed at the next
                        # regeneration
                        if not fc.equals(v):
                            self.fold_misses += 1
                            raise DivergenceError(
                                f"folded Input Feed ({cuid}, {pos}) "
                                f"changed value")
                        continue
                self.feed_vals[(cuid, pos)] = stage(v)
                self.feed_raw[(cuid, pos)] = v
        self.ord_to_uid[ordinal] = cuid
        self.cursor = cuid
        rs = self.region_stack
        while rs and rs[-1] == cuid:
            rs.pop()
        if cuid in self._boundaries and not rs:
            self.boundary_reached = self.seg_idx
        return node.out_avals, cuid

    def taken_uids(self) -> set:
        """Uids of every TraceGraph node validated (taken) so far this
        iteration — used by the dispatcher to tell a legitimately-defaulted
        feed (untaken branch region) from a collection bug on the walked
        path (DESIGN.md §4.4 strict-feeds check)."""
        taken = set(self.ord_to_uid.values())
        if self.loop is not None:
            taken.add(self.loop.node.uid)
        return taken

    # -- finishing -------------------------------------------------------------
    def at_end(self) -> bool:
        if self.loop is not None:
            if self.loop.pos != 0:
                return False
            self._exit_loop()
        return self.tg.end.uid in self.tg.nodes[self.cursor].children

    def uid_of(self, ref: Ref) -> Tuple[int, int]:
        uid = self.ord_to_uid.get(ref.entry)
        if uid is None:
            raise ReplayRequired()
        n = self.tg.nodes[uid]
        if n.kind == "loop":
            return uid, n.body.out_slot_for(ref, ())
        return uid, ref.out_idx
