"""TerraEngine: the phase-machine coordinator of the executor package.

One engine per TerraFunction.  The engine owns the long-lived pieces — the
TraceGraph, the GraphRunner thread, the VariableStore, the cross-version
SegmentCache and the chain jit cache — and wires the per-iteration pieces
(Walker, Dispatcher, snapshot) together:

* **tracing phase** — ``record_op`` (python_runner.py) executes eagerly and
  records a Trace; ``_finish_traced_iteration`` merges it and, once
  covered, builds a GraphProgram (segments compiled through the
  SegmentCache so version bumps only recompile what changed).
* **co-execution phase** — ``record_op`` validates through the Walker and
  returns placeholder tensors; the active Dispatcher ships segments (or
  path-specialized chains) to the GraphRunner; ``materialize`` resolves
  Output Fetching against dispatcher futures.
* **divergence fallback** — delegated to fallback.DivergenceHandler; the
  engine then finishes the iteration imperatively and re-enters tracing.

Everything heavier than coordination lives in the sibling modules; see
DESIGN.md §3 for the package map.
"""

from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ops as ops_mod
from repro.core.graphgen import GraphProgram
from repro.core.passes import observe_iteration, resolve_pipeline, run_passes
from repro.core.tensor import TerraTensor, Variable
from repro.core.trace import Aval, Ref, Trace, VarAssign, VarRef
from repro.core.tracegraph import TraceGraph, roll_loops
from repro.core.executor.dispatch import SegmentDispatcher
from repro.core.executor.fallback import DivergenceHandler
from repro.core.executor.families import FamilyManager
from repro.core.executor.graph_runner import GraphRunner
from repro.core.executor.python_runner import PythonRunnerOps
from repro.core.executor.segment_cache import SegmentCache
from repro.core.executor.stats import init_stats
from repro.core.executor.variables import VariableStore
from repro.core.executor.walker import (DivergenceError, ReplayRequired,
                                        Walker)

IMPERATIVE, TRACING, SKELETON = "imperative", "tracing", "skeleton"


class TerraEngine(PythonRunnerOps):
    """Owns the TraceGraph, the phase state machine and the executor parts."""

    def __init__(self, lazy: bool = False, seed: int = 0,
                 min_covered: int = 1, max_families: int = 8,
                 strict_feeds: bool = True, optimize=None):
        self.tg = TraceGraph()
        self.mode = TRACING
        self.runner = GraphRunner(lazy=lazy)
        self.store = VariableStore()
        self.seg_cache = SegmentCache()
        self.gp: Optional[GraphProgram] = None
        self.min_covered = min_covered
        self.strict_feeds = strict_feeds
        # optimization pipeline (§10); None defers to $TERRA_OPTIMIZE
        self.pipeline = resolve_pipeline(optimize)
        self._feed_warned: list = []    # engine-lifetime warn-once latch
        self._covered_streak = 0
        self.skip_files: Tuple[str, ...] = ()
        self._base_key = jax.random.PRNGKey(seed)
        self._chain_cache: Dict[Tuple, Any] = {}

        # stats (benchmarks: Fig. 6 breakdown, App. F transitions); the
        # full counter registry lives in executor/stats.py
        self.stats = init_stats()
        self._fallback = DivergenceHandler(self.runner, self.store,
                                           self.stats)
        self.fm = FamilyManager(max_families, self.stats, self.seg_cache)
        self.family = None

        # per-iteration state
        self.iter_id = -1
        self.trace: Optional[Trace] = None
        self._vals: Dict[Tuple[int, int], Any] = {}
        self._tensors: Dict[Tuple[int, int], TerraTensor] = {}
        self._feed_log: Dict[Tuple[int, int], Any] = {}
        self._var_binding: Dict[int, TerraTensor] = {}
        self._rng_count = 0
        self.walker: Optional[Walker] = None
        self.dispatcher = None
        self._iter_open = False
        self._snapshot_slot: Dict[int, Any] = {}

    @property
    def vars(self) -> Dict[int, Variable]:
        return self.store.vars

    # ------------------------------------------------------------------
    # iteration lifecycle
    # ------------------------------------------------------------------
    def start_iteration(self, feed_sig: Tuple = ()):
        # load this shape class's TraceGraph/GraphProgram/phase (§8)
        self.fm.switch(self, (feed_sig, self.store.avals_digest()))
        self.iter_id += 1
        self.trace = Trace()
        self._vals.clear()
        self._tensors = {}
        self._feed_log = {}
        self._var_binding = {}
        self._rng_count = 0
        self._iter_open = True
        self.dispatcher = None
        if self.mode == SKELETON:
            self.walker = Walker(self.gp)
            self.dispatcher = SegmentDispatcher(
                self.gp, self.walker, self.trace, self.runner, self.store,
                self.stats, self.strict_feeds, self._feed_warned)
            snap: Dict[int, Any] = {}
            self._snapshot_slot = snap
            store = self.store
            seq = self.runner.submit(lambda: store.snapshot_into(snap))
            # the snapshot reads every live buffer: fence it so a driver
            # rebind/release (reset_variable / release_variable) cannot
            # swap a buffer out from under the pending snapshot
            store.fence(store.buffers, (), seq)
            self.runner.open_iteration()

    def end_iteration(self):
        self.stats["iterations"] += 1
        self._iter_open = False
        self.stats["runner_exec_time"] = self.runner.exec_time
        self.stats["runner_stall_time"] = self.runner.stall_time
        if self.mode == SKELETON:
            try:
                if not self.walker.at_end():
                    raise DivergenceError("iteration ended mid-TraceGraph")
                # finish() may raise ReplayRequired: a trailing chain
                # flush needed a value the optimized segments no longer
                # publish (DCE'd) — recover by eager prefix replay
                self.dispatcher.finish()
            except (DivergenceError, ReplayRequired):
                self._fallback_replay()
                self._finish_traced_iteration()
                return
            self.stats["walker_fast_hits"] += self.walker.fast_hits
            self.runner.close_iteration()
            return
        self._finish_traced_iteration()

    def _finish_traced_iteration(self):
        self.stats["traced_iterations"] += 1
        # commit final variable bindings to the store (direct buffer access:
        # a variable commit is not a user-visible fetch point)
        for vid, t in self._var_binding.items():
            self.store.put(vid, t._eager if t._eager is not None
                           else t.value())
        rolled = roll_loops(self.trace)
        covered = self.tg.merge_trace(self.trace, rolled)
        fam = self.family
        if self.pipeline:
            # feed-stability / fetch-timing observations for the passes
            observe_iteration(self.trace, self._feed_log, self.tg,
                              fam.feed_obs, fam.fetch_obs)
        self._covered_streak = self._covered_streak + 1 if covered else 0
        if self._covered_streak >= self.min_covered:
            # pass results are cached with the GraphProgram: regenerate on
            # graph growth OR an observation change (e.g. fold unfolded)
            token = (self.pipeline, fam.feed_obs.version,
                     fam.fetch_obs.version)
            if (self.gp is None or self.gp.version != self.tg.version
                    or self.gp.opt_token != token):
                var_avals = {vid: v.aval for vid, v in self.vars.items()}
                opt = run_passes(self.tg, var_avals, self.pipeline,
                                 fam.feed_obs, fam.fetch_obs)
                self.gp = GraphProgram(self.tg, var_avals,
                                       seg_cache=self.seg_cache,
                                       family_key=self.family.key,
                                       opt=opt)
                self.gp.opt_token = token
                if opt is not None:
                    for k, v in opt.counters.items():
                        self.stats[k] += v
                self.family.gp = self.gp
                self.fm.retain_live()   # union over ALL live families
                self.stats["graph_versions"] += 1
                self.stats["segment_cache_hits"] = self.seg_cache.hits
                self.stats["segments_recompiled"] = self.seg_cache.misses
            if self.mode != SKELETON:
                self.stats["transitions"] += 1
            self.mode = SKELETON
        else:
            self.mode = TRACING
        self.fm.save(self)
        # vars register lazily during the first trace: refresh the key
        self.fm.rekey(self.family,
                      (self.family.key[0], self.store.avals_digest()))

    # ------------------------------------------------------------------
    # divergence fallback (paper: cancel GraphRunner, back to tracing)
    # ------------------------------------------------------------------
    def _fallback_replay(self):
        if self.walker is not None:
            self.stats["walker_fast_hits"] += self.walker.fast_hits
            self.stats["fold_divergences"] += self.walker.fold_misses
        self._fallback.cancel_and_replay(self.trace, self._feed_log,
                                         self._snapshot_slot, self._vals,
                                         self._tensors)
        self.mode = TRACING
        self.stats["retraces"] += 1
        self._covered_streak = 0
        self.walker = None
        self.dispatcher = None
        self.fm.save(self)

    def abort_iteration(self):
        """Abandon an iteration after an escaping exception (a user error
        or a strict-feeds dispatch error): cancel pending symbolic work,
        roll the store back to the iteration-start snapshot, and re-enter
        tracing — the next call starts clean instead of inheriting a
        half-open iteration (stale walker, open runner window)."""
        was_skeleton = self.mode == SKELETON and self.walker is not None
        self._iter_open = False
        self.walker = None
        self.dispatcher = None
        if was_skeleton:
            self.runner.cancel()
            self.store.restore(self._snapshot_slot)
            self.mode = TRACING
            self.stats["retraces"] += 1
            self._covered_streak = 0
            self.fm.save(self)

    def _recover_value(self):
        """Replay to materialize values the graph did not output.  Inside an
        open iteration this is the divergence fallback; after end_iteration
        it replays and re-commits the final variable bindings."""
        self._fallback_replay()
        if not self._iter_open:
            for vid, ref in self.trace.var_assigns.items():
                self.store.put(vid, self._vals[(ref.entry, ref.out_idx)])

    # ------------------------------------------------------------------
    # variables
    # ------------------------------------------------------------------
    def _ensure_var(self, var: Variable):
        self.store.ensure(var)

    def read_variable(self, var: Variable) -> TerraTensor:
        self._ensure_var(var)
        bound = self._var_binding.get(var.var_id)
        if bound is not None:
            return bound
        if self.mode == SKELETON:
            return TerraTensor(VarRef(var.var_id), var.aval, engine=self,
                               iter_id=self.iter_id)
        # eager modes read the committed store value
        return TerraTensor(VarRef(var.var_id), var.aval,
                           eager=self.store.get(var.var_id, var._value),
                           engine=self, iter_id=self.iter_id)

    def assign_variable(self, var: Variable, value):
        self._ensure_var(var)
        if not isinstance(value, TerraTensor):
            value = ops_mod.identity(value)
        if not isinstance(value.ref, Ref) or value._iter != self.iter_id:
            value = ops_mod.identity(value)
        self.trace.events.append(VarAssign(var.var_id, value.ref))
        self.trace.var_assigns[var.var_id] = value.ref
        self._var_binding[var.var_id] = value

    def _await_fence(self, seq) -> None:
        """Block on one per-value readiness fence (DESIGN.md §4.4) — a
        GraphRunner sequence number — instead of draining the whole queue;
        the FIFO runner guarantees the fenced writer has committed its
        buffer once the sequence completes.  Lazy mode executes the queued
        work on this thread, as drain() used to."""
        if seq is None or self.runner.done(seq):
            return
        t0 = time.perf_counter()
        self.runner.wait_for(seq)
        self.stats["py_stall_time"] += time.perf_counter() - t0

    def variable_value(self, var: Variable):
        self._ensure_var(var)
        if self._iter_open and self.mode == SKELETON:
            self._steady_poison = True  # Python saw device state (§12)
        bound = self._var_binding.get(var.var_id)
        if bound is not None and bound._eager is not None:
            return bound._eager
        # block only on this variable's last pending writer (not the queue)
        self._await_fence(self.store.write_fence(var.var_id))
        val = self.store.buffers[var.var_id]
        if (self._iter_open and self.mode == SKELETON and self.gp is not None
                and var.var_id in self.gp.donatable_var_ids):
            # a later segment of this iteration may donate this buffer;
            # hand the caller a private copy (DESIGN.md §4.2)
            val = jnp.array(val)
        return val

    def variable_read_ref(self, var: Variable):
        return VarRef(var.var_id)

    def reset_variable(self, var: Variable, value):
        """Out-of-band variable (re)binding between iterations — used by
        drivers (e.g. the serving engine rebinding KV-cache variables after
        a prefill) to swap device state without recording a trace event.
        Rebinding to a different shape is legal: the new aval flows into
        the store's shape digest, so the next iteration selects (or traces)
        the matching TraceGraph family (§8) instead of diverging."""
        if self._iter_open and self.mode == SKELETON:
            raise RuntimeError("reset_variable inside an open co-executed "
                               "iteration")
        self._ensure_var(var)
        # wait for the last pending toucher (reader or writer) of this
        # variable only; rebinds between iterations no longer serialize
        # behind the whole previous iteration's queue
        self._await_fence(self.store.use_fence(var.var_id))
        value = jnp.asarray(value)
        self.store.put(var.var_id, value)
        var._value = value
        new_aval = Aval.of(value)
        if new_aval != var.aval:
            var.aval = new_aval
            self.store.invalidate_avals()

    # ------------------------------------------------------------------
    # RNG
    # ------------------------------------------------------------------
    def next_rng_key(self):
        k = jax.random.fold_in(jax.random.fold_in(self._base_key,
                                                  self.iter_id),
                               self._rng_count)
        self._rng_count += 1
        return k

    # ------------------------------------------------------------------
    def release_variable(self, var: Variable) -> None:
        """Drop a variable's buffer from the store (driver-retired state)."""
        self._await_fence(self.store.use_fence(var.var_id))
        self.store.remove(var.var_id)

    def sync(self):
        """Drain dispatch AND block until device work has completed — the
        one remaining full barrier (per-value fences cover everything
        else, DESIGN.md §4.4).  Deferred async device errors surface here
        (the per-segment barrier is gone, so this is the first guaranteed
        sync point)."""
        self.runner.drain()
        self.stats["runner_exec_time"] = self.runner.exec_time
        self.stats["runner_stall_time"] = self.runner.stall_time
        self.stats["segment_cache_hits"] = self.seg_cache.hits
        self.stats["segments_recompiled"] = self.seg_cache.misses
        err = self.runner.take_error()
        if err is not None:                 # fetchless closure failure
            raise err
        jax.block_until_ready(list(self.store.buffers.values()))

    def close(self):
        self.runner.drain()
        self.runner.stop()
