"""TerraEngine: the phase-machine coordinator of the executor package.

One engine per TerraFunction.  The engine owns the long-lived pieces — the
TraceGraph, the GraphRunner thread, the VariableStore, the cross-version
SegmentCache, the chain jit cache and the EventStream — and wires the
per-iteration pieces (Walker, Dispatcher, snapshot) together:

* **tracing phase** — ``record_op`` (python_runner.py) executes eagerly and
  records a Trace; ``_finish_traced_iteration`` merges it and, once
  covered, builds a GraphProgram (segments compiled through the
  SegmentCache so version bumps only recompile what changed).
* **co-execution phase** — ``record_op`` validates through the Walker and
  returns placeholder tensors; the active Dispatcher ships segments (or
  path-specialized chains) to the GraphRunner; ``materialize`` resolves
  Output Fetching against dispatcher futures.
* **divergence fallback** — delegated to fallback.DivergenceHandler; the
  engine then finishes the iteration imperatively and re-enters tracing.

All instrumentation flows through ``self.events`` (core/events/,
DESIGN.md §13): ``self.stats`` *is* the stream's counter dict, and the
structured lifecycle events (iteration open/close, divergence → rollback
→ replay chains, pass-pipeline runs) are emitted only when a structured
processor is attached.  Everything heavier than coordination lives in the
sibling modules; see DESIGN.md §3 for the package map.
"""

from __future__ import annotations

import os
from typing import Any, Dict, Optional, Tuple

import jax

from repro.core.events import EventStream
from repro.core.events import emit as ev
from repro.core.graphgen import GraphProgram
from repro.core.passes import observe_iteration, resolve_pipeline, run_passes
from repro.core.passes.analysis import FeedObservations, FetchObservations
from repro.core.tensor import TerraTensor, Variable
from repro.core.trace import Trace
from repro.core.tracegraph import TraceGraph, roll_loops
from repro.core.executor.dispatch import SegmentDispatcher
from repro.core.executor.fallback import DivergenceHandler
from repro.core.executor.families import FamilyManager
from repro.core.executor.graph_runner import GraphRunner
from repro.core.executor.python_runner import PythonRunnerOps
from repro.core.executor.segment_cache import SegmentCache
from repro.core.executor.stats import init_stats
from repro.core.executor.varapi import VariableOps
from repro.core.executor.variables import VariableStore
from repro.core.executor.walker import (DivergenceError, ReplayRequired,
                                        Walker)

IMPERATIVE, TRACING, SKELETON = "imperative", "tracing", "skeleton"


class TerraEngine(PythonRunnerOps, VariableOps):
    """Owns the TraceGraph, the phase state machine and the executor parts."""

    def __init__(self, lazy: bool = False, seed: int = 0,
                 min_covered: int = 1, max_families: int = 8,
                 strict_feeds: bool = True, optimize=None,
                 cache_dir: Optional[str] = None, cache_scope: str = ""):
        # the instrumentation substrate: counters + structured events
        # (benchmarks: Fig. 6 breakdown, App. F transitions); the full
        # counter registry lives in executor/stats.py
        self.events = EventStream(counters=init_stats())
        self.stats = self.events.counters
        self.tg = TraceGraph()
        self.mode = TRACING
        self.runner = GraphRunner(lazy=lazy, events=self.events)
        self.store = VariableStore()
        self.seg_cache = SegmentCache()
        self.gp: Optional[GraphProgram] = None
        self.min_covered = min_covered
        self.strict_feeds = strict_feeds
        # optimization pipeline (§10); None defers to $TERRA_OPTIMIZE
        self.pipeline = resolve_pipeline(optimize)
        self._feed_warned: list = []    # engine-lifetime warn-once latch
        self._covered_streak = 0
        self.skip_files: Tuple[str, ...] = ()
        self._base_key = jax.random.PRNGKey(seed)
        self._chain_cache: Dict[Tuple, Any] = {}
        # sampled device-time profiling cadence (DESIGN.md §15); 0 = off
        self.profile_every = 0

        self._fallback = DivergenceHandler(self.runner, self.store,
                                           self.events)
        # persistent artifact store (core/persist/, DESIGN.md §14):
        # enabled by an explicit cache_dir or $TERRA_CACHE_DIR; passing
        # cache_dir="" disables caching even with the env var set
        root = (os.environ.get("TERRA_CACHE_DIR") if cache_dir is None
                else cache_dir)
        self.persist = None
        if root:
            from repro.core.persist import PersistLayer
            self.persist = PersistLayer(root, self.events,
                                        scope=cache_scope, engine=self)
        self.seg_cache.persist = self.persist
        self.fm = FamilyManager(max_families, self.events, self.seg_cache,
                                persist=self.persist)
        self.family = None

        # per-iteration state
        self.iter_id = -1
        self.trace: Optional[Trace] = None
        self._vals: Dict[Tuple[int, int], Any] = {}
        self._tensors: Dict[Tuple[int, int], TerraTensor] = {}
        self._feed_log: Dict[Tuple[int, int], Any] = {}
        self._var_binding: Dict[int, TerraTensor] = {}
        self._rng_count = 0
        self.walker: Optional[Walker] = None
        self.dispatcher = None
        self._iter_open = False
        self._snapshot_slot: Dict[int, Any] = {}

    @property
    def vars(self) -> Dict[int, Variable]:
        return self.store.vars

    # ------------------------------------------------------------------
    # iteration lifecycle
    # ------------------------------------------------------------------
    def start_iteration(self, feed_sig: Tuple = ()):
        # load this shape class's TraceGraph/GraphProgram/phase (§8)
        self.fm.switch(self, (feed_sig, self.store.avals_digest()))
        self.iter_id += 1
        ev.iteration_start(self.events, self.iter_id, self.mode,
                           self.family.key)
        self.trace = Trace()
        self._vals.clear()
        self._tensors = {}
        self._feed_log = {}
        self._var_binding = {}
        self._rng_count = 0
        self._iter_open = True
        self.dispatcher = None
        if self.mode == SKELETON:
            self.walker = Walker(self.gp)
            pe = self.profile_every
            self.dispatcher = SegmentDispatcher(
                self.gp, self.walker, self.trace, self.runner, self.store,
                self.events, self.strict_feeds, self._feed_warned,
                iter_id=self.iter_id,
                profile=bool(pe and self.events.on
                             and self.iter_id % pe == 0))
            snap: Dict[int, Any] = {}
            self._snapshot_slot = snap
            store = self.store
            seq = self.runner.submit(lambda: store.snapshot_into(snap))
            # the snapshot reads every live buffer: fence it so a driver
            # rebind/release (reset_variable / release_variable) cannot
            # swap a buffer out from under the pending snapshot
            store.fence(store.buffers, (), seq)
            self.runner.open_iteration()

    def end_iteration(self):
        es = self.events
        es.inc("iterations")
        self._iter_open = False
        es.put("runner_exec_time", self.runner.exec_time)
        es.put("runner_stall_time", self.runner.stall_time)
        if self.mode == SKELETON:
            try:
                if not self.walker.at_end():
                    raise DivergenceError("iteration ended mid-TraceGraph")
                # finish() may raise ReplayRequired: a trailing chain
                # flush needed a value the optimized segments no longer
                # publish (DCE'd) — recover by eager prefix replay
                self.dispatcher.finish()
            except (DivergenceError, ReplayRequired) as e:
                self._fallback_replay(str(e) or type(e).__name__)
                self._finish_traced_iteration()
                return
            es.inc("walker_fast_hits", self.walker.fast_hits)
            ev.iteration_end(es, self.iter_id, SKELETON, False,
                             ops=len(self.trace.entries),
                             fast=self.walker.fast_hits)
            fam = self.family
            if self.walker.sels:
                # fork observation (JANUS speculation groundwork, §15);
                # fork-free iterations pay one empty-dict truthiness check
                dist = fam.sel_dist
                for fork, case in self.walker.sels.items():
                    d = dist.setdefault(fork, {})
                    d[case] = d.get(case, 0) + 1
                    ev.fork_observed(es, fam.key, fork, case)
            self.runner.close_iteration()
            if fam.hydrated:
                # first fully validated pass over a hydrated graph: the
                # warm boot is confirmed; refresh the key with the vars
                # that registered lazily during this iteration (§8/§14)
                fam.hydrated = False
                self.fm.save(self)
                self.fm.rekey(fam,
                              (fam.key[0], self.store.avals_digest()))
            return
        self._finish_traced_iteration()

    def _finish_traced_iteration(self):
        es = self.events
        es.inc("traced_iterations")
        # commit final variable bindings to the store (direct buffer access:
        # a variable commit is not a user-visible fetch point)
        for vid, t in self._var_binding.items():
            self.store.put(vid, t._eager if t._eager is not None
                           else t.value())
        rolled = roll_loops(self.trace)
        covered = self.tg.merge_trace(self.trace, rolled)
        fam = self.family
        if self.pipeline:
            # feed-stability / fetch-timing observations for the passes
            observe_iteration(self.trace, self._feed_log, self.tg,
                              fam.feed_obs, fam.fetch_obs)
        self._covered_streak = self._covered_streak + 1 if covered else 0
        if self._covered_streak >= self.min_covered:
            # pass results are cached with the GraphProgram: regenerate on
            # graph growth OR an observation change (e.g. fold unfolded)
            token = (self.pipeline, fam.feed_obs.version,
                     fam.fetch_obs.version)
            if (self.gp is None or self.gp.version != self.tg.version
                    or self.gp.opt_token != token):
                var_avals = {vid: v.aval for vid, v in self.vars.items()}
                opt = run_passes(self.tg, var_avals, self.pipeline,
                                 fam.feed_obs, fam.fetch_obs)
                self.gp = GraphProgram(self.tg, var_avals,
                                       seg_cache=self.seg_cache,
                                       family_key=self.family.key,
                                       opt=opt)
                self.gp.opt_token = token
                if opt is not None:
                    for k, v in opt.counters.items():
                        self.stats[k] += v
                    ev.pass_run(es, self.iter_id, self.family.key,
                                opt.pipeline, opt.per_pass)
                self.family.gp = self.gp
                self.fm.retain_live()   # union over ALL live families
                es.inc("graph_versions")
                es.put("segment_cache_hits", self.seg_cache.hits)
                es.put("segments_recompiled", self.seg_cache.misses)
                if self.persist is not None:
                    self.persist.save_family(self.family)
            if self.mode != SKELETON:
                es.inc("transitions")
                ev.transition(es, self.iter_id)
            self.mode = SKELETON
        else:
            self.mode = TRACING
        ev.iteration_end(es, self.iter_id, TRACING, True,
                         ops=len(self.trace.entries))
        self.fm.save(self)
        # vars register lazily during the first trace: refresh the key
        self.fm.rekey(self.family,
                      (self.family.key[0], self.store.avals_digest()))

    # ------------------------------------------------------------------
    # divergence fallback (paper: cancel GraphRunner, back to tracing)
    # ------------------------------------------------------------------
    def _fallback_replay(self, reason: str = "replay-required"):
        es = self.events
        ev.divergence(es, self.iter_id, reason)
        if self.walker is not None:
            es.inc("walker_fast_hits", self.walker.fast_hits)
            es.inc("fold_divergences", self.walker.fold_misses)
        self._fallback.cancel_and_replay(self.trace, self._feed_log,
                                         self._snapshot_slot, self._vals,
                                         self._tensors,
                                         iter_id=self.iter_id)
        self.mode = TRACING
        es.inc("retraces")
        self._covered_streak = 0
        self.walker = None
        self.dispatcher = None
        self._discard_hydrated()
        self.fm.save(self)

    def _discard_hydrated(self):
        """A hydrated family diverged before its first validated pass: the
        stored graph does not match this program, so drop the disk record
        and reset the family to an empty graph — the retrace starts clean
        ("slower never wrong") and overwrites the artifact (§14)."""
        fam = self.family
        if fam is None or not fam.hydrated:
            return
        fam.hydrated = False
        if self.persist is not None:
            self.persist.on_hydrated_divergence(fam)
        self.tg = TraceGraph(family_key=fam.key)
        self.gp = None
        fam.tg, fam.gp = self.tg, None
        fam.feed_obs = FeedObservations()
        fam.fetch_obs = FetchObservations()
        fam.steady = None
        fam.steady_streak = 0

    def abort_iteration(self):
        """Abandon an iteration after an escaping exception (a user error
        or a strict-feeds dispatch error): cancel pending symbolic work,
        roll the store back to the iteration-start snapshot, and re-enter
        tracing — the next call starts clean instead of inheriting a
        half-open iteration (stale walker, open runner window)."""
        was_skeleton = self.mode == SKELETON and self.walker is not None
        self._iter_open = False
        self.walker = None
        self.dispatcher = None
        if was_skeleton:
            es = self.events
            self.runner.cancel()
            self.store.restore(self._snapshot_slot)
            ev.rollback(es, self.iter_id, len(self._snapshot_slot))
            ev.retrace(es, self.iter_id, "abort")
            self.mode = TRACING
            es.inc("retraces")
            self._covered_streak = 0
            self._discard_hydrated()
            self.fm.save(self)

    def _recover_value(self):
        """Replay to materialize values the graph did not output.  Inside an
        open iteration this is the divergence fallback; after end_iteration
        it replays and re-commits the final variable bindings."""
        self._fallback_replay()
        if not self._iter_open:
            for vid, ref in self.trace.var_assigns.items():
                self.store.put(vid, self._vals[(ref.entry, ref.out_idx)])

    # ------------------------------------------------------------------
    def save_checkpoint(self, path: str) -> None:
        """Snapshot VariableStore buffers + iteration state to a directory
        (core/persist/checkpoint.py); a fresh process restores with
        :meth:`restore_checkpoint` and continues where this one stopped."""
        from repro.core.persist import save_engine
        save_engine(self, path)

    def restore_checkpoint(self, path: str) -> None:
        from repro.core.persist import restore_engine
        restore_engine(self, path)

    # ------------------------------------------------------------------
    def sync(self):
        """Drain dispatch AND block until device work has completed — the
        one remaining full barrier (per-value fences cover everything
        else, DESIGN.md §4.4).  Deferred async device errors surface here
        (the per-segment barrier is gone, so this is the first guaranteed
        sync point)."""
        self.runner.drain()
        es = self.events
        es.put("runner_exec_time", self.runner.exec_time)
        es.put("runner_stall_time", self.runner.stall_time)
        es.put("segment_cache_hits", self.seg_cache.hits)
        es.put("segments_recompiled", self.seg_cache.misses)
        err = self.runner.take_error()
        if err is not None:                 # fetchless closure failure
            raise err
        jax.block_until_ready(list(self.store.buffers.values()))

    def close(self):
        self.runner.drain()
        self.runner.stop()
        self.events.close()
