"""Divergence fallback: cancel the GraphRunner, replay the validated prefix.

Paper §4.1: when validation fails (the program followed a trace the
TraceGraph does not cover), Terra (1) cancels the symbolic work of the
current iteration — drain the GraphRunner and restore the variable store
from the iteration-start snapshot — then (2) *replays* the already-validated
prefix of DL ops eagerly to rematerialize every live placeholder tensor, and
(3) finishes the iteration imperatively.  Python side effects are never
re-executed: only the recorded DL ops run again, against the recorded feed
values and the restored variable buffers.

The prefix is replayed exactly once per divergence (asserted by
tests/test_executor.py via ``stats["replayed_entries"]``).
"""

from __future__ import annotations

from typing import Any, Dict

from repro.core import ops as ops_mod
from repro.core.events import emit as ev
from repro.core.ops import Const
from repro.core.trace import FeedRef, Ref, Trace, VarRef


class DivergenceHandler:
    """Owns cancel + replay; stateless across iterations."""

    def __init__(self, runner, store, events):
        self.runner = runner
        self.store = store
        self.events = events
        self.stats = events.counters

    def cancel_and_replay(self, trace: Trace, feed_log: Dict,
                          snapshot: Dict[int, Any], vals: Dict,
                          tensors: Dict, iter_id: int = -1) -> None:
        """Drain pending graph work, roll back variables, replay the prefix.

        ``vals`` is refilled with every replayed output and ``tensors``'
        live placeholders get their ``_eager`` slots filled in place, after
        which the iteration can continue imperatively.  The Rollback and
        Replay events carry ``iter_id`` so the trace links them causally to
        the Divergence the coordinator emitted (DESIGN.md §13).
        """
        self.stats["replays"] += 1
        self.stats["transitions"] += 1
        # cancel the iteration atomically: drain pending closures, close
        # the iteration window, and discard any stashed closure error (the
        # cancelled iteration's effects are rolled back, so its errors are
        # moot) — one public call, no reaching into runner internals
        self.runner.cancel()
        # cancel this iteration's effects: restore the variable snapshot
        # UNCONDITIONALLY.  An empty snapshot is a real pre-iteration
        # state (the store held no buffers), not a missing one — skipping
        # the restore would leak buffers first written by the cancelled
        # iteration (e.g. a Variable created inside it).
        self.store.restore(snapshot)
        ev.rollback(self.events, iter_id, len(snapshot))
        # eager replay of the validated prefix (DL ops only — Python side
        # effects are NOT re-run)
        vals.clear()
        store = self.store
        for ordinal, entry in enumerate(trace.entries):
            ins = []
            for pos, r in enumerate(entry.input_refs):
                if isinstance(r, Ref):
                    ins.append(vals[(r.entry, r.out_idx)])
                elif isinstance(r, FeedRef):
                    ins.append(feed_log[(ordinal, pos)])
                elif isinstance(r, VarRef):
                    # read_initial: the rollback may have removed the seed
                    # buffer of a variable first registered this iteration
                    ins.append(store.read_initial(r.var_id))
                elif isinstance(r, Const):
                    ins.append(r.value)
            out = ops_mod.OPS[entry.op_name].impl(*ins, **dict(entry.attrs))
            outs = out if isinstance(out, tuple) else (out,)
            for oi, v in enumerate(outs):
                vals[(ordinal, oi)] = v
                t = tensors.get((ordinal, oi))
                if t is not None:
                    t._eager = v
        self.stats["replayed_entries"] += len(trace.entries)
        ev.replay(self.events, iter_id, len(trace.entries))
