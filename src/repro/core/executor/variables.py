"""Device-resident variable store (paper: resource inputs/outputs).

The authoritative buffer of every framework Variable lives here, not on the
Variable object: segments read ``var_in`` slices from the store and their
``var_out`` results are written back by the dispatcher, so variable state
flows GraphRunner-thread to GraphRunner-thread without ever bouncing
through Python.

Snapshot/restore implements the divergence-cancellation contract
(paper §4.1): at skeleton-iteration start the coordinator queues
``snapshot_into`` *on the runner thread* — after any still-pending work from
the previous iteration, so the snapshot sees committed state — and on
divergence the whole store is rolled back to that snapshot after a drain.
Snapshots hold buffer *references*, not copies; this is what makes
iteration-start buffers ineligible for donation (DESIGN.md §4.2) — donating
one would delete the only rollback copy.

Per-value readiness (DESIGN.md §4.4): dispatchers register, per variable,
the GraphRunner sequence number of the last submitted closure that reads or
writes it (``fence``).  A variable read then blocks only on its own last
writer — `runner.wait_for(seq)` — not on the whole queue, and a driver-side
rebind/release blocks only on its own last toucher.  The GraphRunner is a
FIFO, so a fence sequence completing implies every earlier closure
(including the writer the fence tracks) has also run; fences are plain
integers, allocated nowhere.
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, Optional

import numpy as np


class VariableStore:
    """var_id -> device buffer, plus the Variable registry."""

    def __init__(self):
        self.buffers: Dict[int, Any] = {}
        self.vars: Dict[int, Any] = {}          # var_id -> Variable
        # released vars leave a (shape, dtype) tombstone: TraceGraph nodes
        # that read them survive as dead switch branches, and compiling
        # those branches still needs a placeholder input of the right aval
        self.tombstones: Dict[int, Any] = {}
        # per-variable readiness fences: var_id -> runner sequence number
        # (an already-completed sequence simply means "no pending work")
        self._write_fence: Dict[int, int] = {}
        self._use_fence: Dict[int, int] = {}
        # cached shape-class digest of the registry (families.py): rebuilt
        # lazily after any registration / release / aval rebind
        self._avals_digest: Optional[int] = None

    # -- per-value readiness (DESIGN.md §4.4) ------------------------------
    def fence(self, reads: Iterable[int], writes: Iterable[int],
              seq: int) -> None:
        """Register ``seq`` as the newest pending closure touching the
        given variables (called at submit time, on the Python thread)."""
        uf, wf = self._use_fence, self._write_fence
        for v in reads:
            uf[v] = seq
        for v in writes:
            wf[v] = seq
            uf[v] = seq

    def write_fence(self, var_id: int) -> Optional[int]:
        """Sequence of the last pending closure that writes ``var_id``."""
        return self._write_fence.get(var_id)

    def use_fence(self, var_id: int) -> Optional[int]:
        """Sequence of the last pending closure that reads or writes it."""
        return self._use_fence.get(var_id)

    # -- shape-class digest (families.py) ----------------------------------
    def avals_digest(self) -> int:
        """Hash of (var_id, aval) over the registry — the variable part of
        the family key.  A collision only merges two shape classes into one
        family, which the Walker then tells apart structurally (feed avals
        are part of node identity): cost is a divergence, never corruption."""
        d = self._avals_digest
        if d is None:
            d = hash(tuple(sorted((vid, v.aval)
                                  for vid, v in self.vars.items())))
            self._avals_digest = d
        return d

    def invalidate_avals(self) -> None:
        self._avals_digest = None

    # -- registry ----------------------------------------------------------
    def ensure(self, var) -> None:
        """Register ``var`` and seed its buffer from the initial value.  A
        registered variable whose buffer is missing (its first-ever write
        was rolled back by a divergence cancellation) is re-seeded: the
        initial value *is* its pre-iteration state."""
        if var.var_id not in self.vars:
            self.vars[var.var_id] = var
            self.tombstones.pop(var.var_id, None)
            self._avals_digest = None
        if var.var_id not in self.buffers:
            self.buffers[var.var_id] = var._value

    def __contains__(self, var_id: int) -> bool:
        return var_id in self.buffers

    def get(self, var_id: int, default=None):
        return self.buffers.get(var_id, default)

    def put(self, var_id: int, value) -> None:
        self.buffers[var_id] = value

    def remove(self, var_id: int) -> None:
        """Unregister a variable and release its device buffer (drivers
        retiring state, e.g. serving caches whose shapes changed)."""
        buf = self.buffers.pop(var_id, None)
        self.vars.pop(var_id, None)
        self._avals_digest = None
        if buf is not None:
            self.tombstones[var_id] = (tuple(buf.shape), buf.dtype)

    def read(self, var_id: int):
        """Dispatch-time read: live buffer, or a zeros placeholder for a
        released var (reachable only from never-taken dead branches)."""
        buf = self.buffers.get(var_id)
        if buf is None:
            shape, dtype = self.tombstones[var_id]
            return np.zeros(shape, dtype)
        return buf

    def read_initial(self, var_id: int):
        """Replay-time read: live buffer, else the variable's initial value
        (a fresh variable whose seed buffer was removed by rollback), else
        the released-var zeros placeholder."""
        buf = self.buffers.get(var_id)
        if buf is not None:
            return buf
        var = self.vars.get(var_id)
        if var is not None:
            return var._value
        shape, dtype = self.tombstones[var_id]
        return np.zeros(shape, dtype)

    # -- snapshot / rollback ----------------------------------------------
    def snapshot_into(self, snap: Dict[int, Any]) -> None:
        """Copy current buffer refs into ``snap`` (runner-thread closure)."""
        snap.update(self.buffers)

    def restore(self, snap: Dict[int, Any]) -> None:
        """Roll the store back to a snapshot (divergence cancellation)."""
        self.buffers.clear()
        self.buffers.update(snap)
