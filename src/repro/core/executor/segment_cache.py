"""Cross-version compiled-segment cache.

Every TraceGraph version bump used to recompile *every* segment: a
divergence that adds one branch forced ``GraphProgram.__init__`` to build
fresh ``jax.jit`` wrappers for all segments, and first dispatch re-traced
and re-lowered each of them.  Most bumps are local — the paper's programs
diverge on one branch or one new fetch — so the unchanged segments' jitted
callables (and their XLA executables) are perfectly reusable.

``segment_signature`` captures everything a compiled segment's behaviour
depends on:

* the structured item list (nodes, switch regions with their phi specs,
  loop bodies with unroll/dynamic trip handling),
* per-node state read at trace time (op, attrs, srcs, out avals, fetch
  annotations, variable assignments),
* the segment's IO contract (variable read/write/donation split, carries,
  feed and fetch slot layouts),
* the global Case Select / Loop Cond slot indices the segment indexes into.

Two segments with equal signatures lower to the same XLA computation with
the same calling convention, so the cached callable — which closes over the
*shared, in-place-merged* TraceGraph nodes of an older GraphProgram — is
exchangeable.  Node uids are stable across merges (merge_trace mutates the
graph in place and only ever appends nodes), which is what makes signature
equality across versions common in practice.

The cache is engine-lifetime; after every regeneration the coordinator
calls :meth:`SegmentCache.retain` with the new program's signatures, which
evicts stale entries (each cached fn closes over its originating
GraphProgram, so unbounded retention would pin old programs) while keeping
every reusable callable (DESIGN.md §4.1).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

from repro.core.casing import NodeItem, SwitchItem


def _remap_srcs(srcs, R) -> Tuple:
    return tuple(("node", R(s[1]), s[2]) if s[0] == "node" else s
                 for s in srcs)


def _node_sig(gp, uid: int, R) -> Tuple:
    # signatures are computed over the POST-pass graph (gp.otg): rewritten
    # sources, folded constants and cleared gating flags are all part of
    # the compiled function's identity, and dead/alias execution state is
    # appended explicitly (a skipped node lowers to nothing; an alias
    # node lowers to rebinding its representative's outputs)
    n = gp.otg.nodes[uid]
    if uid in gp._dead:
        return (R(uid), "dead")
    alias = gp._alias.get(uid)
    if alias is not None:
        return (R(uid), "alias", tuple((R(u), oi) for u, oi in alias),
                n.out_avals, tuple(sorted(n.fetch_idxs)),
                tuple(n.var_assigns))
    base = (R(uid), n.kind, n.op_name, n.attrs, n.location,
            _remap_srcs(n.srcs, R), n.out_avals,
            tuple(sorted(n.fetch_idxs)),
            tuple(n.var_assigns), n.sync_after)
    if n.kind == "loop":
        trips = (("unroll", next(iter(n.trips))) if len(n.trips) == 1
                 else ("dyn", gp.trip_slot[uid]))
        return base + (n.body.sig(), trips,
                       tuple(sorted(n.body.var_binds.items())))
    return base


def _items_sig(gp, sp, items, R) -> Tuple:
    out = []
    for item in items:
        if isinstance(item, NodeItem):
            out.append(("node",) + _node_sig(gp, item.uid, R))
        elif isinstance(item, SwitchItem):
            fetches, vars_, exports = gp.switch_spec(item, sp)
            out.append(("switch", R(item.fork_uid),
                        gp.selector_slot[item.fork_uid], R(item.join_uid),
                        tuple(R(c) for c in item.child_order),
                        tuple((R(u), oi) for u, oi in fetches),
                        tuple(vars_),
                        tuple((R(u), oi) for u, oi in exports),
                        tuple(_items_sig(gp, sp, b, R)
                              for b in item.branches)))
        else:
            raise TypeError(f"unknown item {item!r}")
    return tuple(out)


def segment_signature(gp, sp) -> Tuple:
    """Structural identity of one segment's compiled function.

    Node uids are **canonicalized** to dense segment-local ids assigned in
    deterministic traversal order (items first, then the IO lists), so two
    structurally identical segments match even when their graphs numbered
    the nodes differently — notably across *family members* (sibling
    shape-class TraceGraphs, DESIGN.md §8) whose uid spaces are disjoint
    histories.  Safety: the remap is a bijection applied uniformly, every
    ordering the compiled function's calling convention depends on (carry
    and feed positions, var-id lists, global selector/trip slot indices)
    is kept in raw form, and everything shape-dependent (out avals, feed
    avals) stays in the key — equal canonical signatures therefore imply
    the same XLA computation with the same calling convention."""
    remap: Dict[int, int] = {}

    def R(uid: int) -> int:
        r = remap.get(uid)
        if r is None:
            r = remap[uid] = len(remap)
        return r

    return (
        _items_sig(gp, sp, sp.items, R),
        tuple(sp.var_reads), tuple(sp.var_writes),
        tuple(sp.don_var_ids), tuple(sp.keep_var_ids),
        tuple((R(u), oi) for u, oi in sp.carries_in),
        tuple((R(u), oi) for u, oi in sp.carries_out),
        tuple((R(u), pos, aval) for u, pos, aval in sp.feed_keys),
        tuple((R(u), oi) for u, oi in sp.fetch_keys),
    )


class SegmentCache:
    """signature -> compiled segment callable, with hit/miss counters.

    ``hits``/``misses`` are cumulative over the engine's lifetime; the
    coordinator mirrors them into ``engine.stats`` as
    ``segment_cache_hits`` / ``segments_recompiled`` after every
    GraphProgram (re)generation.
    """

    def __init__(self):
        self._fns: Dict[Tuple, Any] = {}
        self.hits = 0
        self.misses = 0
        self.persist = None         # PersistLayer, set by the coordinator

    def get_or_build(self, key: Tuple, builder: Callable[[], Any],
                     loader: Callable[[], Any] = None) -> Any:
        """In-memory probe, then the optional ``loader`` (the persist
        layer's on-disk AOT executable — counted as a HIT: nothing is
        recompiled), then ``builder`` (a real recompile, counted as a
        miss)."""
        fn = self._fns.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        if loader is not None:
            fn = loader()
            if fn is not None:
                self._fns[key] = fn
                self.hits += 1
                return fn
        fn = builder()
        self._fns[key] = fn
        self.misses += 1
        return fn

    def retain(self, keys) -> None:
        """Evict every entry whose signature is not in ``keys`` — the
        union of segment signatures over every *live family's* current
        GraphProgram (families.live_signatures), not just the newest
        program: per-program retention would evict sibling shape classes'
        callables on every regeneration.  Each cached fn closes over its
        originating GraphProgram, so without eviction every version bump
        would pin a full old program; and because each family's TraceGraph
        only grows (nodes, fetch annotations, trip sets are append-only),
        a signature absent from every live program can only recur through
        a re-created evicted family — eviction bounds memory to the live
        segment set at the cost of that rare recompile.  The persist
        layer is notified of the drop: its on-disk AOT executables
        survive, so a re-created family reloads instead of recompiling
        (DESIGN.md §14)."""
        dropped = [k for k in self._fns if k not in keys]
        if dropped and self.persist is not None:
            self.persist.on_segments_evicted(dropped)
        for k in dropped:
            del self._fns[k]

    def __len__(self) -> int:
        return len(self._fns)
