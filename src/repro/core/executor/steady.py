"""Zero-walker steady-state dispatch (DESIGN.md §12).

Co-execution's per-iteration Python cost is the skeleton program itself:
even with the stamp fast path, every op re-executes Python-side to be
validated through the Walker.  For serving decode — one straight-line
segment repeated thousands of times with identical arg *structure* — that
cost is the whole gap to a hand-written jit dispatch loop.

The steady-state planner closes it: after ``steady_state`` consecutive
clean walker-validated iterations of one family whose shape is provably
replayable (single segment, no selects / loop conds / sync markers / rng /
folded feeds, every Input Feed identity-mapped to a call-arg leaf, every
output a graph-published fetch), the engine captures a :class:`SteadyPlan`
and subsequent calls dispatch the compiled segment straight from the
DispatchPlan — the user fn is **not executed** and no per-op validation
runs.  Outputs come back as placeholder tensors carrying only a fetch
future.

"Slower never wrong" is kept by construction where possible and by
probing where not: any structural miss (arg treedef / shape / dtype /
baked-constant change, variable-aval digest change, GraphProgram
regeneration, a ``_steady_poison`` mark from Python reading device state)
falls back to the full walker path, and every ``steady_probe``-th call is
forced through it so silent divergence cannot persist.  The one honest
caveat — documented, and why this is opt-in (``steady_state=0`` default):
Python side effects inside ``fn`` do not run on steady iterations, and a
*value*-dependent change of feed wiring inside ``fn`` is only caught at
the next probe.
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from concurrent.futures import Future
from typing import Any, List, Optional, Tuple

import jax

from repro.core.events import emit as ev
from repro.core.tensor import TerraTensor
from repro.core.trace import Ref, SyncMarker, is_tensor_like
from repro.core.executor.dispatch import _EMPTY_I32
from repro.core.executor.walker import ReplayRequired
from repro.core.executor import walker as _walker_mod

SKELETON = "skeleton"
MISS = object()        # sentinel: run the full walker path
_ABSENT = object()


@dataclasses.dataclass
class SteadyPlan:
    """Everything needed to dispatch one family's single segment without
    executing the skeleton: the feed wiring (arg-leaf index per DispatchPlan
    feed key), the argument validity signature, and the output spec."""
    gp: Any                         # GraphProgram identity guard
    sp: Any                         # its single SegProg
    feed_slots: Tuple[int, ...]     # leaf index per plan.feed_keys entry
    in_treedef: Any
    leaf_sigs: Tuple                # ("t", shape, dtype) | ("c", baked value)
    avals_digest: Any
    out_treedef: Any
    out_specs: Tuple                # ((uid, oi), aval) per output leaf
    last_leaves: Optional[List[Any]] = None    # identity fast path
    count: int = 0                  # steady calls, drives probe cadence


# ---------------------------------------------------------------------------
# observation (after each successful walker iteration)
# ---------------------------------------------------------------------------

def _build(eng, args, kwargs, out) -> Optional[SteadyPlan]:
    """Return a SteadyPlan if this just-finished walker iteration proves the
    family steady-eligible, else None.  Conservative on every axis: any
    structure the zero-walker replay could not reproduce exactly rejects."""
    if eng.mode != SKELETON or eng.walker is None or eng.dispatcher is None:
        return None
    if eng.dispatcher.kind != "segments":
        return None
    gp = eng.gp
    if gp is None or len(gp.seg_progs) != 1 or gp.folded_feeds:
        return None
    w = eng.walker
    if w.loop is not None or w.sels or w.trips:
        return None
    if eng._rng_count or getattr(eng, "_steady_poison", False):
        return None
    if any(isinstance(ev, SyncMarker) for ev in eng.trace.events):
        return None
    plan = gp.seg_progs[0].plan
    if plan.sel_uids or plan.trip_uids or plan.carries_in:
        return None
    try:
        leaves, in_treedef = jax.tree_util.tree_flatten((args, kwargs))
        out_leaves, out_treedef = jax.tree_util.tree_flatten(out)
    except Exception:
        return None
    sigs, by_id = [], {}
    for i, leaf in enumerate(leaves):
        if isinstance(leaf, TerraTensor):
            return None             # cross-iteration placeholder args
        if is_tensor_like(leaf):
            sigs.append(("t", tuple(leaf.shape), str(leaf.dtype)))
        else:
            sigs.append(("c", leaf))
        by_id[id(leaf)] = i
    # every Input Feed must be the exact object of a call-arg leaf: a feed
    # derived in Python (mask.astype(...), a sliced frame) would be silently
    # stale under replay, so identity is the safety condition, not a cache
    feed_slots = []
    for (uid, pos, _aval) in plan.feed_keys:
        raw = w.feed_raw.get((uid, pos), _ABSENT)
        li = by_id.get(id(raw)) if raw is not _ABSENT else None
        if li is None:
            return None
        feed_slots.append(li)
    fetch_set = set(plan.fetch_keys)
    specs = []
    for t in out_leaves:
        if not isinstance(t, TerraTensor) or t._eager is not None:
            return None
        if t._iter != eng.iter_id or not isinstance(t.ref, Ref):
            return None
        try:
            key = w.uid_of(t.ref)
        except ReplayRequired:
            return None
        if key not in fetch_set:
            return None
        specs.append((key, t.aval))
    return SteadyPlan(gp=gp, sp=gp.seg_progs[0], feed_slots=tuple(feed_slots),
                      in_treedef=in_treedef, leaf_sigs=tuple(sigs),
                      avals_digest=eng.store.avals_digest(),
                      out_treedef=out_treedef, out_specs=tuple(specs),
                      last_leaves=leaves)


def observe(eng, args, kwargs, out) -> None:
    """Called after every successful walker-path iteration: advance or reset
    the family's clean-iteration streak, enter steady at the threshold."""
    fam = eng.family
    if fam is None:
        return
    threshold = getattr(eng, "steady_state", 0)
    if threshold <= 0:
        return
    plan = _build(eng, args, kwargs, out)
    if plan is None:
        fam.steady_streak = 0
        if fam.steady is not None:
            fam.steady = None
            eng.stats["steady_exits"] += 1
            ev.steady_exit(eng.events, eng.iter_id, "ineligible")
        return
    fam.steady_streak += 1
    if fam.steady is not None and fam.steady.gp is eng.gp:
        # live plan survived a probe: refresh the identity fast path
        fam.steady.last_leaves = plan.last_leaves
        return
    if fam.steady_streak >= threshold:
        fam.steady = plan
        eng.stats["steady_entries"] += 1
        ev.steady_enter(eng.events, eng.iter_id, fam.key)


def attach_futures(eng, out) -> None:
    """After a walker iteration closes, pin each returned placeholder to its
    dispatcher fetch future so it stays awaitable once later iterations
    start (the scheduler's lag-harvest window; tensor.py ``_future``)."""
    if eng.mode != SKELETON or eng.walker is None or eng.dispatcher is None:
        return
    for t in jax.tree_util.tree_leaves(out):
        if (isinstance(t, TerraTensor) and t._eager is None
                and t._future is None and isinstance(t.ref, Ref)):
            try:
                fut = eng.dispatcher.future_for(t.ref)
            except ReplayRequired:
                continue
            if fut is not None:
                t._future = fut


# ---------------------------------------------------------------------------
# the zero-walker call path
# ---------------------------------------------------------------------------

def try_steady(eng, args, kwargs):
    """Dispatch this call straight from the family's SteadyPlan, or return
    :data:`MISS` to run the full walker path."""
    fam = eng.family
    plan = fam.steady if fam is not None else None
    if plan is None:
        return MISS
    if plan.gp is not eng.gp:
        # graph regenerated since capture (growth, pass-token change):
        # the cached DispatchPlan is stale — drop and re-earn the streak
        fam.steady = None
        fam.steady_streak = 0
        eng.stats["steady_exits"] += 1
        ev.steady_exit(eng.events, eng.iter_id, "gp-regenerated")
        return MISS
    probe = getattr(eng, "steady_probe", 64)
    plan.count += 1
    if probe and plan.count % probe == 0:
        ev.steady_probe(eng.events, eng.iter_id)
        return MISS                 # forced validation iteration
    try:
        leaves, treedef = jax.tree_util.tree_flatten((args, kwargs))
    except Exception:
        return MISS
    if len(leaves) != len(plan.leaf_sigs) or treedef != plan.in_treedef:
        return MISS
    if eng.store.avals_digest() != plan.avals_digest:
        return MISS                 # a variable was rebound out-of-band
    last = plan.last_leaves
    if not (last is not None and all(a is b for a, b in zip(leaves, last))):
        for leaf, sig in zip(leaves, plan.leaf_sigs):
            if sig[0] == "t":
                if isinstance(leaf, TerraTensor) or not is_tensor_like(leaf):
                    return MISS
                if tuple(leaf.shape) != sig[1] or str(leaf.dtype) != sig[2]:
                    return MISS
            else:
                # non-tensor leaves can steer Python control flow: only a
                # value-equal leaf is safe to replay against the baked plan
                try:
                    if leaf is not sig[1] and not bool(leaf == sig[1]):
                        return MISS
                except Exception:
                    return MISS
        plan.last_leaves = leaves
    return _dispatch(eng, plan, leaves)


def _dispatch(eng, plan: SteadyPlan, leaves):
    """Mirror of SegmentDispatcher.dispatch_through for one pre-validated
    segment: array fills from the DispatchPlan, fenced submit, no walker."""
    t0 = time.perf_counter()
    store, stats = eng.store, eng.stats
    buffers = store.buffers
    sp = plan.sp
    dp = sp.plan
    stage = _walker_mod._STAGE_FEED or _walker_mod._feed_stager()
    feeds = tuple(stage(leaves[li]) for li in plan.feed_slots)
    futures = {k: Future() for k in dp.fetch_keys}
    # sampled device-time attribution (DESIGN.md §15): steady iterations
    # stay eligible — the block-on-done runs on the runner thread, so the
    # imperative thread keeps its zero-walker dispatch cost; sampling
    # keeps the runner's pipelining intact on the other N-1 iterations
    pe = eng.profile_every
    profile = bool(pe and eng.events.on and (eng.iter_id + 1) % pe == 0)
    events, iter_id = eng.events, eng.iter_id + 1

    def run():
        don_in = tuple(store.read(v) for v in dp.don_var_ids)
        keep_in = tuple(store.read(v) for v in dp.keep_var_ids)
        if don_in:
            stats["donated_bytes"] += sum(
                int(getattr(b, "nbytes", 0)) for b in don_in)
        if profile:
            pt0 = time.perf_counter()
        try:
            with warnings.catch_warnings():
                warnings.filterwarnings(
                    "ignore",
                    message="Some donated buffers were not usable")
                var_out, fetches, _ = sp.fn(don_in, keep_in, feeds,
                                            _EMPTY_I32, _EMPTY_I32, ())
        except Exception as e:          # propagate into futures
            for f in futures.values():
                if not f.done():
                    f.set_exception(e)
            raise
        if profile:
            pt1 = time.perf_counter()
            jax.block_until_ready((var_out, fetches))
            ev.segment_profile(events, iter_id, "steady", 0,
                               pt1 - pt0, time.perf_counter() - pt0,
                               dp.kernel_ops)
        for vid, v in zip(dp.var_writes, var_out):
            buffers[vid] = v
        for k, v in zip(dp.fetch_keys, fetches):
            futures[k].set_result(v)

    seq = eng.runner.submit(run)
    store.fence(dp.don_var_ids, dp.var_writes, seq)
    store.fence(dp.keep_var_ids, (), seq)
    # advance the engine's iteration clock so tensors of the *previous*
    # iteration read as stale (their values arrive through ``_future``) and
    # a later walker iteration starts from a clean binding map
    eng.iter_id += 1
    eng._var_binding = {}
    stats["iterations"] += 1
    stats["steady_iters"] += 1
    stats["segments_dispatched"] += 1
    ev.segment_dispatch(eng.events, eng.iter_id, "steady", 0, seq,
                        len(plan.feed_slots))
    out_leaves = []
    for key, aval in plan.out_specs:
        t = TerraTensor(None, aval, engine=eng, iter_id=eng.iter_id)
        t._future = futures[key]
        out_leaves.append(t)
    stats["dispatch_time"] += time.perf_counter() - t0
    return jax.tree_util.tree_unflatten(plan.out_treedef, out_leaves)
