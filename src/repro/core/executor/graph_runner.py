"""GraphRunner: the ordered asynchronous executor thread (paper §4.1).

The GraphRunner drains a FIFO of dispatch closures on a dedicated thread so
the PythonRunner (the user's Python thread executing the skeleton program)
never blocks on graph execution except at explicit Output Fetching points.
Closures are opaque here — segment dispatch, chain dispatch and variable
snapshots are all just queued work — which keeps this module free of any
TraceGraph/GraphProgram knowledge.

In ``lazy`` mode (the Table-2 LazyTensor-style ablation) no thread is
started; queued work is executed on the *calling* thread by
``run_pending_now()`` the moment a fetch needs it, which serializes Python
and graph execution exactly like a lazy-evaluation runtime.

Dispatch closures no longer block until device results are ready (the old
per-segment ``jax.block_until_ready`` barrier): XLA execution stays async
behind the fetch futures, and blocking happens only when a future's value is
actually converted/read on the Python side.  ``exec_time`` therefore measures
enqueue-to-enqueue runner occupancy, and wall-clock device sync is visible
only in ``py_stall_time`` at fetch points (see DESIGN.md §4).
"""

from __future__ import annotations

import queue
import threading
import time


class GraphRunner:
    """FIFO executor with stall accounting, threaded unless ``lazy``."""

    def __init__(self, lazy: bool = False):
        self.lazy = lazy
        self._q: "queue.Queue" = queue.Queue()
        self._pending = 0
        self._cv = threading.Condition()
        self.exec_time = 0.0
        self.stall_time = 0.0
        self._last_done = time.perf_counter()
        self._open = False                     # an iteration is in flight
        if not lazy:
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="terra-graphrunner")
            self._worker.start()

    # ------------------------------------------------------------------
    def submit(self, closure) -> None:
        with self._cv:
            self._pending += 1
        self._q.put(closure)

    def _run_one(self, closure):
        t0 = time.perf_counter()
        if self._open:
            self.stall_time += max(0.0, t0 - self._last_done)
        try:
            closure()
        finally:
            t1 = time.perf_counter()
            self.exec_time += t1 - t0
            self._last_done = t1
            with self._cv:
                self._pending -= 1
                self._cv.notify_all()

    def _run(self):
        while True:
            closure = self._q.get()
            if closure is None:
                return
            self._run_one(closure)

    # ------------------------------------------------------------------
    def run_pending_now(self):
        """Lazy mode: execute queued work on the calling thread (this is
        the LazyTensor-style serialized evaluation of Table 2)."""
        while True:
            try:
                closure = self._q.get_nowait()
            except queue.Empty:
                return
            if closure is not None:
                self._run_one(closure)

    def drain(self):
        """Block until every submitted closure has run (dispatch-complete;
        device work may still be in flight — see module docstring)."""
        if self.lazy:
            self.run_pending_now()
            return
        with self._cv:
            while self._pending > 0:
                self._cv.wait()

    def stop(self):
        if not self.lazy:
            self._q.put(None)
