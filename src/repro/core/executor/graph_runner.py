"""GraphRunner: the ordered asynchronous executor thread (paper §4.1).

The GraphRunner drains a FIFO of dispatch closures on a dedicated thread so
the PythonRunner (the user's Python thread executing the skeleton program)
never blocks on graph execution except at explicit Output Fetching points.
Closures are opaque here — segment dispatch, chain dispatch and variable
snapshots are all just queued work — which keeps this module free of any
TraceGraph/GraphProgram knowledge.

Because the queue is strictly FIFO, completion is a *monotone sequence
number*: ``submit`` returns the closure's 1-based sequence index, and a
consumer that needs closure *n*'s effects waits with ``wait_for(n)``.  The
per-variable readiness fences (variables.py, DESIGN.md §4.4) are just these
integers — no per-closure Future objects, and a single condition variable
covers enqueue, completion and drain.

In ``lazy`` mode (the Table-2 LazyTensor-style ablation) no thread is
started; queued work is executed on the *calling* thread by
``run_pending_now()`` the moment a fetch needs it, which serializes Python
and graph execution exactly like a lazy-evaluation runtime.

Dispatch closures do not block until device results are ready (no
per-segment ``jax.block_until_ready`` barrier): XLA execution stays async
behind the fetch futures, and blocking happens only when a future's value is
actually converted/read on the Python side.  ``exec_time`` therefore measures
enqueue-to-enqueue runner occupancy, and wall-clock device sync is visible
only in ``py_stall_time`` at fetch points (see DESIGN.md §4).
"""

from __future__ import annotations

import threading
import time
from collections import deque

from repro.core.events import types as _T


class GraphRunner:
    """FIFO executor with stall accounting, threaded unless ``lazy``."""

    def __init__(self, lazy: bool = False, events=None):
        self.lazy = lazy
        # optional EventStream: completion events (seq + wall/stall) are
        # emitted from the runner thread; the stream serializes delivery
        self.events = events
        self._dq: deque = deque()
        self._cv = threading.Condition()
        self._submitted = 0
        self._completed = 0
        self.exec_time = 0.0
        self.stall_time = 0.0
        self._last_done = time.perf_counter()
        self._open = False                     # an iteration is in flight
        # first closure exception since the last sync/cancellation: the
        # worker thread survives (a dead thread would hang every later
        # fence wait and drain), errors reach fetchers through their
        # futures, and engine.sync() re-raises this for fetchless failures
        self.pending_error = None
        if not lazy:
            self._worker = threading.Thread(target=self._run, daemon=True,
                                            name="terra-graphrunner")
            self._worker.start()

    # ------------------------------------------------------------------
    def submit(self, closure) -> int:
        """Enqueue; returns the closure's 1-based completion sequence."""
        with self._cv:
            self._dq.append(closure)
            self._submitted += 1
            seq = self._submitted
            self._cv.notify()
        return seq

    def done(self, seq: int) -> bool:
        """True once the seq-th submitted closure has finished (lock-free:
        a stale read only under-reports, which at worst waits once more)."""
        return self._completed >= seq

    def _run_one(self, closure):
        t0 = time.perf_counter()
        stalled = max(0.0, t0 - self._last_done) if self._open else 0.0
        self.stall_time += stalled
        err = None
        try:
            closure()
        except Exception as e:                  # noqa: BLE001 — keep alive
            err = e
        finally:
            t1 = time.perf_counter()
            self.exec_time += t1 - t0
            self._last_done = t1
            # the error is stashed in the same critical section that
            # completes the sequence, so any thread observing completion
            # (drain / cancel / a fence wait) also observes the error
            with self._cv:
                if err is not None and self.pending_error is None:
                    self.pending_error = err
                self._completed += 1
                seq = self._completed
                self._cv.notify_all()
            es = self.events
            if es is not None and es.on:
                es.emit(_T.RunnerComplete(seq, t1 - t0, stalled))

    def _run(self):
        dq, cv = self._dq, self._cv
        while True:
            with cv:
                while not dq:
                    cv.wait()
                closure = dq.popleft()
            if closure is None:
                return
            self._run_one(closure)

    # ------------------------------------------------------------------
    # iteration window (stall accounting) + cancellation
    # ------------------------------------------------------------------
    def open_iteration(self) -> None:
        """Mark an iteration in flight: queue-empty time now counts as
        runner stall (the Python thread is the bottleneck)."""
        self._open = True

    def close_iteration(self) -> None:
        """Close the iteration window opened by :meth:`open_iteration`."""
        self._open = False

    def cancel(self) -> None:
        """Divergence cancellation: drain every submitted closure, close
        the iteration window and discard any stashed closure error — in
        one critical section, so no concurrently-completing closure can
        stash an error between the drain and the clear.  Errors raised by
        a cancelled iteration's closures are moot: its effects are rolled
        back and the validated prefix replays eagerly."""
        if self.lazy:
            try:
                self.run_pending_now()
            except Exception:           # noqa: BLE001 — cancelled anyway
                pass
            self._open = False
            self.pending_error = None
            return
        with self._cv:
            while self._completed < self._submitted:
                self._cv.wait()
            self._open = False
            self.pending_error = None

    def take_error(self) -> Exception:
        """Return and clear the first stashed closure error (the fetchless
        failure surfaced at ``engine.sync()``), or None."""
        err, self.pending_error = self.pending_error, None
        return err

    # ------------------------------------------------------------------
    def run_pending_now(self):
        """Lazy mode: execute queued work on the calling thread (this is
        the LazyTensor-style serialized evaluation of Table 2).  Every
        queued closure completes its sequence (fences stay monotone),
        then the first stashed error re-raises HERE — on the calling
        thread at the fetch/fence point, as serialized lazy evaluation
        must — rather than waiting silently for an explicit sync()."""
        dq = self._dq
        while True:
            try:
                closure = dq.popleft()
            except IndexError:
                break
            if closure is not None:
                self._run_one(closure)
        err = self.pending_error
        if err is not None:
            self.pending_error = None
            raise err

    def wait_for(self, seq: int):
        """Block until the seq-th submitted closure has run — the
        per-value fence wait (DESIGN.md §4.4).  FIFO order guarantees every
        earlier closure has also run."""
        if self.lazy:
            self.run_pending_now()
            return
        with self._cv:
            while self._completed < seq:
                self._cv.wait()

    def drain(self):
        """Block until every submitted closure has run (dispatch-complete;
        device work may still be in flight — see module docstring).

        This is the *full* barrier, reserved for ``engine.sync()`` /
        ``close()`` and divergence cancellation — variable reads and Output
        Fetching wait on their own producer's fence/future instead."""
        if self.lazy:
            self.run_pending_now()
            return
        with self._cv:
            while self._completed < self._submitted:
                self._cv.wait()

    def stop(self):
        if not self.lazy:
            with self._cv:
                self._dq.append(None)       # sentinel: not a counted closure
                self._cv.notify()
