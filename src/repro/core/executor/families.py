"""Shape-keyed TraceGraph families (DESIGN.md §8).

One TraceGraph can only describe one shape class: every op node records the
concrete out avals of the trace that created it, so a batch-size or
sequence-bucket change used to be indistinguishable from real control-flow
divergence — the engine cancelled the iteration, re-traced, and threw away
every compiled segment.  JANUS-style profile specialization applied to
shapes fixes this: the engine keys TraceGraphs (with their GraphPrograms
and walker state) by a **shape-class signature** of the iteration, keeps a
bounded LRU of live families, and switches between them at iteration start
with a dictionary lookup.  Each shape class traces and compiles exactly
once; flipping back to a previously seen shape is zero retraces and zero
recompiles.

The signature has two parts, combined into the family key at
``TerraEngine.start_iteration``:

* the **feed part** — (shape, dtype) of every tensor-like leaf of the
  call arguments (computed by ``feed_signature``, called from
  ``TerraFunction.__call__``), and
* the **variable part** — a digest of (var_id, aval) over every variable
  registered in the store (``VariableStore.avals_digest``), so an
  out-of-band rebind to a different shape (serving: KV cache after a
  prefill of a new batch size) selects the right sibling graph.

Variables are registered lazily during the first traced iteration, so a
family's key is **re-keyed** after every traced iteration with the then-
current variable digest; the feed part is fixed at iteration start.

Eviction: families are LRU-ordered by activation; creating one past
``max_families`` evicts the least recently used non-active family and
drops its compiled segments from the shared SegmentCache — except those
whose structural signatures are also reachable from a surviving family
(cross-family sharing, segment_cache.py).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Tuple

import jax

from repro.core.events import emit as ev
from repro.core.passes.analysis import FeedObservations, FetchObservations
from repro.core.tensor import TerraTensor
from repro.core.trace import is_tensor_like
from repro.core.tracegraph import TraceGraph

TRACING = "tracing"


def feed_signature(args, kwargs) -> Tuple:
    """Shape-class signature of one call's arguments: (shape, dtype) of
    every tensor-like leaf, in tree order.  Non-tensor leaves (Python
    scalars, None, config objects) are control-flow inputs, not shape
    inputs — a change in them either validates against the same graph or
    diverges into a sibling branch of the same family."""
    out = []
    for leaf in jax.tree_util.tree_leaves((args, kwargs)):
        if isinstance(leaf, TerraTensor) or is_tensor_like(leaf):
            out.append((tuple(leaf.shape), str(leaf.dtype)))
    return tuple(out)


def bucket_pow2(n: int, floor: int = 1) -> int:
    """Round ``n`` up to the next power-of-two cell (DESIGN.md §5/§8): the
    optional bucketing policy drivers apply to batch/sequence sizes before
    they reach the engine, bounding family cardinality to O(log n)."""
    cell = max(1, floor)
    while cell < n:
        cell <<= 1
    return cell


@dataclasses.dataclass
class TraceFamily:
    """Per-shape-class engine state: the TraceGraph, its compiled program,
    the phase-machine fields the coordinator swaps at iteration start, and
    the observation records the optimization passes consume (DESIGN.md
    §10) — per family, because feed stability and fetch timing are
    properties of one shape class's traces."""
    key: Tuple
    tg: TraceGraph
    gp: Any = None                  # GraphProgram, once covered
    mode: str = TRACING
    covered_streak: int = 0
    feed_obs: FeedObservations = dataclasses.field(
        default_factory=FeedObservations)
    fetch_obs: FetchObservations = dataclasses.field(
        default_factory=FetchObservations)
    # zero-walker steady state (executor/steady.py, DESIGN.md §12)
    steady: Any = None              # SteadyPlan, once eligible
    steady_streak: int = 0          # consecutive clean eligible iterations
    # warm boot (core/persist/, DESIGN.md §14): True between hydration
    # from the artifact store and the first fully validated iteration
    hydrated: bool = False
    _persist_rec: Any = None        # relpath of the on-disk record
    # fork observation (DESIGN.md §15, JANUS speculation groundwork):
    # {fork uid: {case index: count}} over validated skeleton iterations
    sel_dist: dict = dataclasses.field(default_factory=dict)


class FamilyManager:
    """Owns the key -> TraceFamily LRU and the shared-cache retention set."""

    def __init__(self, max_families: int, events, seg_cache, persist=None):
        self.max_families = max(1, int(max_families))
        self.events = events
        self.stats = events.counters
        self.seg_cache = seg_cache
        self.persist = persist
        self.families: "OrderedDict[Tuple, TraceFamily]" = OrderedDict()

    def __len__(self) -> int:
        return len(self.families)

    # ------------------------------------------------------------------
    # coordinator surface: swap the engine's phase state per shape class
    # ------------------------------------------------------------------
    def save(self, engine) -> None:
        """Write the engine's live phase state back into its family."""
        fam = engine.family
        fam.tg, fam.gp, fam.mode = engine.tg, engine.gp, engine.mode
        fam.covered_streak = engine._covered_streak

    def switch(self, engine, key: Tuple) -> None:
        """Iteration-start family selection: adopt the engine's boot state
        as the first family, stay put on a key match, or save the active
        family and load (or create) the sibling for ``key``.  A new shape
        class must trace (counted as a retrace); flipping back to a known
        one is a dictionary lookup — no retrace, no recompile."""
        fam = engine.family
        if fam is None:
            if self.persist is not None:
                fam = self.persist.hydrate_family(key, engine)
            if fam is None:
                engine.tg.family_key = key
                fam = TraceFamily(key, engine.tg, engine.gp, engine.mode,
                                  engine._covered_streak)
            self.families[key] = fam
            engine.family = fam
            engine.tg, engine.gp, engine.mode = fam.tg, fam.gp, fam.mode
            engine._covered_streak = fam.covered_streak
        elif key != fam.key:
            self.save(engine)
            fam, created = self.activate(key, engine)
            self.stats["retraces" if created else "family_switches"] += 1
            ev.family_switch(self.events, key, created)
            engine.family = fam
            engine.tg, engine.gp, engine.mode = fam.tg, fam.gp, fam.mode
            engine._covered_streak = fam.covered_streak
        self.stats["families"] = len(self.families)

    def activate(self, key: Tuple, engine=None) -> Tuple[TraceFamily, bool]:
        """Look up (LRU-touch) or create the family for ``key``; returns
        (family, created).  A miss consults the artifact store first (an
        evicted-then-reactivated family warm-boots from disk instead of
        retracing).  Creation past the cap evicts the least recently used
        other family — notifying the persist layer, which saves its graph
        so the eviction is reversible — and drops its compiled segments
        from the shared cache (minus any shared with a surviving
        family)."""
        fam = self.families.get(key)
        if fam is not None:
            self.families.move_to_end(key)
            return fam, False
        if self.persist is not None and engine is not None:
            fam = self.persist.hydrate_family(key, engine)
        created = fam is None
        if fam is None:
            fam = TraceFamily(key, TraceGraph(family_key=key))
        self.families[key] = fam
        while len(self.families) > self.max_families:
            vkey = next(k for k, f in self.families.items()
                        if f is not fam)
            victim = self.families.pop(vkey)
            self.stats["families_evicted"] += 1
            if self.persist is not None:
                self.persist.on_family_evicted(victim)
            self.retain_live()
        return fam, created

    def rekey(self, fam: TraceFamily, new_key: Tuple) -> None:
        """Move a family to the key observed at the end of a traced
        iteration (variables register lazily during the first trace).  A
        collision with an existing family keeps both as-is — the
        provisional key simply goes cold and ages out of the LRU."""
        if new_key == fam.key or new_key in self.families:
            return
        del self.families[fam.key]
        fam.key = new_key
        fam.tg.family_key = new_key
        self.families[new_key] = fam

    # ------------------------------------------------------------------
    def live_signatures(self) -> set:
        """Union of compiled-segment signatures over every live family —
        the SegmentCache retention set.  Per-family retention (the pre-
        family behaviour) would evict sibling families' callables on every
        regeneration and destroy exactly the reuse families exist for."""
        keys = set()
        for fam in self.families.values():
            if fam.gp is not None:
                keys.update(sp.signature for sp in fam.gp.seg_progs)
        return keys

    def retain_live(self) -> None:
        self.seg_cache.retain(self.live_signatures())
