"""Case assignment: structuring the TraceGraph into switch regions.

This is the paper's *case assignment algorithm* (§4.2 / Appendix B): given
the TraceGraph DAG, find the *Switch-Case* regions so that the generated
symbolic graph executes exactly the operations of whichever trace the
PythonRunner follows, with a *Case Select* input per fork.

We structure the DAG with immediate post-dominators: for a fork node F, the
region spans F's children up to ipostdom(F) (the join).  Because every trace
terminates at the unique END node, ipostdom is total, and because node
equality includes input sources (tracegraph.py), any node after the join
consumes only path-independent values — the only per-path state is variable
bindings and interior fetches, which become the switch outputs (phi slots).

The result is a structured program:
    Program = [Item ...]
    Item    = NodeItem(uid) | SwitchItem(fork_uid, branches=[Program...],
              join_uid) | (loop nodes are NodeItems — their body is handled
              by graphgen)
plus the *segments* partition: the top-level program is cut after every node
whose fetch gates the PythonRunner (sync_after), giving the co-execution
segment boundaries (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import networkx as nx

from repro.core.tracegraph import TraceGraph, TGNode


@dataclasses.dataclass
class NodeItem:
    uid: int


@dataclasses.dataclass
class SwitchItem:
    fork_uid: int
    branches: List[list]
    join_uid: int
    # child uid order defining the Case Select index — the PythonRunner
    # selects the branch whose first node matches the op it executes
    child_order: Tuple[int, ...] = ()


def _dedup(seq):
    seen, out = set(), []
    for x in seq:
        if x not in seen:
            seen.add(x)
            out.append(x)
    return out


class Structure:
    """Structured program + segmentation for one TraceGraph version."""

    def __init__(self, tg: TraceGraph):
        self.tg = tg
        g = nx.DiGraph()
        for uid, n in tg.nodes.items():
            g.add_node(uid)
            for c in n.children:
                g.add_edge(uid, c)
        if not nx.is_directed_acyclic_graph(g):
            raise ValueError("TraceGraph must be a DAG")
        # post-dominators = dominators of the reversed graph rooted at END
        self.ipdom: Dict[int, int] = nx.immediate_dominators(
            g.reverse(copy=True), tg.end.uid)
        self.program = self._build(tg.start.uid, tg.end.uid)
        self.segments = self._segment(self.program)

    # -- region construction -------------------------------------------------
    def _build(self, cur: int, stop: int) -> list:
        tg = self.tg
        seq: List = []
        while cur != stop:
            children = _dedup(tg.nodes[cur].children)
            if not children:
                break
            if len(children) == 1:
                nxt = children[0]
                if nxt == stop:
                    break
                seq.append(NodeItem(nxt))
                cur = nxt
            else:
                join = self.ipdom[cur]
                branches = []
                for c in children:
                    if c == join:
                        branches.append([])
                    else:
                        branches.append([NodeItem(c)] + self._build(c, join))
                seq.append(SwitchItem(cur, branches, join,
                                      child_order=tuple(children)))
                if join == stop:
                    break
                if tg.nodes[join].kind not in ("end",):
                    seq.append(NodeItem(join))
                cur = join
        return seq

    # -- segmentation ---------------------------------------------------------
    def _segment(self, program: list) -> List[list]:
        segments, cur = [], []
        for item in program:
            cur.append(item)
            if (isinstance(item, NodeItem)
                    and self.tg.nodes[item.uid].sync_after):
                segments.append(cur)
                cur = []
        segments.append(cur)
        return segments

    # -- helpers used by graphgen and the runner ------------------------------
    def iter_items(self, program=None):
        for item in (self.program if program is None else program):
            yield item
            if isinstance(item, SwitchItem):
                for b in item.branches:
                    yield from self.iter_items(b)

    def uids_in(self, program) -> List[int]:
        """All op/loop node uids contained in a (sub)program, including
        switch-branch interiors.  Fork uids are NodeItems of their own and
        are therefore not double-counted."""
        return [item.uid for item in self.iter_items(program)
                if isinstance(item, NodeItem)]
