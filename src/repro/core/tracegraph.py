"""TraceGraph: merging iteration traces into a DAG (paper §4.2, Fig. 3).

Node equality follows Appendix A — (op type, attributes, program location) —
extended with *input-source identity*: two dynamic ops merge into one node
only if they also consumed the same producers.  This conservative extension
(DESIGN.md §7.1) removes the need for path-dependent phi resolution
everywhere except variable bindings and makes the generated switch regions
provably consistent: a post-join node can never consume a branch-interior
value (if it did, its input sources would differ per path and it would not
have merged).

Loop rolling (paper: "the GraphGenerator merges the nodes that are executed
in the same loop ... because it compares the program location"): tandem
repeats of identical signature blocks in a trace are rolled into a LoopEntry
with an explicit carried-state analysis; rolled loops merge into LoopNodes
whose trip counts are tracked per trace.  Constant trip counts are unrolled
at generation time (the paper's unrolling optimization); varying trip counts
become a dynamic `fori_loop` with the trip count fed by the PythonRunner
(the paper's *Loop Cond* mechanism).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.trace import (Aval, FeedRef, Ref, SyncMarker, Trace,
                              TraceEntry, VarAssign, VarRef)
from repro.core.ops import Const

START, END = "start", "end"


# --------------------------------------------------------------------------
# Sources: path-independent input identities in the merged graph
# --------------------------------------------------------------------------
# ('node', uid, out_idx) | ('feed', Aval) | ('var', var_id) | ('const', v)
# | ('carry', k)  (inside rolled loop bodies: k-th loop-carried slot)
# | ('inv', src)  (inside rolled loop bodies: loop-invariant outer source)

Src = Tuple


@dataclasses.dataclass
class TGNode:
    uid: int
    kind: str                           # 'op' | 'start' | 'end' | 'loop'
    op_name: str = ""
    attrs: Tuple = ()
    location: Tuple[str, int] = ("", 0)
    srcs: Tuple[Src, ...] = ()
    out_avals: Tuple[Aval, ...] = ()
    children: List[int] = dataclasses.field(default_factory=list)
    fetch_idxs: set = dataclasses.field(default_factory=set)  # materialized out_idxs
    sync_after: bool = False            # gating fetch => segment boundary
    var_assigns: Tuple[Tuple[int, int], ...] = ()   # (var_id, out_idx)
    # loop-node fields
    body: Optional["LoopBody"] = None
    trips: set = dataclasses.field(default_factory=set)
    # Walker fast path (DESIGN.md §4.4): hash of the last merged TraceEntry
    # that matched this node (op/attrs/location + raw input refs + feed
    # avals).  A steady-state iteration revalidates the op with one hash
    # comparison against this stamp; any mismatch falls back to the full
    # structural comparison below — never straight to divergence.
    entry_stamp: Optional[int] = None
    _sig_cache: Optional[Tuple] = dataclasses.field(default=None, repr=False)
    _uchildren: Tuple = dataclasses.field(default=(-1, ()), repr=False)

    def sig(self) -> Tuple:
        # srcs/attrs/body are fixed at node creation, so the signature (and
        # its hash, used by merge matching) is computed exactly once
        s = self._sig_cache
        if s is None:
            if self.kind == "loop":
                s = ("loop", self.location, self.body.sig(), self.srcs)
            else:
                s = (self.op_name, self.attrs, self.location, self.srcs)
            self._sig_cache = s
        return s

    def uniq_children(self) -> Tuple[int, ...]:
        """Order-preserving deduped children, memoized until an edge is
        appended (the Walker calls this once per validated op)."""
        n, cached = self._uchildren
        if n == len(self.children):
            return cached
        seen: set = set()
        out = []
        for c in self.children:
            if c not in seen:
                seen.add(c)
                out.append(c)
        cached = tuple(out)
        self._uchildren = (len(self.children), cached)
        return cached


def clone_node(n: TGNode) -> TGNode:
    """Copy one node for rewrite (see TraceGraph.clone_for_rewrite):
    mutable containers are duplicated, caches reset, loop bodies shared
    (passes never rewrite inside rolled bodies)."""
    c = TGNode(n.uid, n.kind, op_name=n.op_name, attrs=n.attrs,
               location=n.location, srcs=n.srcs, out_avals=n.out_avals,
               children=list(n.children), fetch_idxs=set(n.fetch_idxs),
               sync_after=n.sync_after, var_assigns=n.var_assigns,
               body=n.body, trips=set(n.trips))
    if hasattr(n, "_last_ordinals"):
        c._last_ordinals = n._last_ordinals
    return c


@dataclasses.dataclass
class LoopBody:
    """Linear body of a rolled loop.

    entries[i].srcs_local use ('carry', k) / ('inv', m) / ('const', v) /
    ('var', var_id) / ('node', local_idx, out_idx) encodings local to the
    body.  carries: list of (init_outer_src, (local_producer_idx, out_idx)):
    slot k is initialized from the outer source and re-bound each trip to the
    local producer's output.  invariants: outer srcs (pre-merge encoding)
    read unchanged every trip.  var_binds: var_id -> carry slot (variables
    re-assigned every trip; their final value is the loop output).
    """
    entries: List[TraceEntry] = dataclasses.field(default_factory=list)
    carries: List[Tuple[Src, Tuple[int, int]]] = dataclasses.field(default_factory=list)
    invariants: List[Src] = dataclasses.field(default_factory=list)
    var_binds: Dict[int, int] = dataclasses.field(default_factory=dict)

    def sig(self) -> Tuple:
        return (tuple(e.signature() + (e.srcs_local,) for e in self.entries),
                tuple((c[1],) for c in self.carries),
                len(self.invariants),
                tuple(sorted(self.var_binds.items())))


class TraceGraph:
    """The merged DAG of all collected traces — of ONE shape class.

    The engine keeps a *family* of TraceGraphs keyed by the iteration's
    shape-class signature (executor/families.py, DESIGN.md §8); versioning
    is per family: ``version`` only advances when this graph itself merges
    something new, never when a sibling shape class traces.  ``family_key``
    records which shape class this graph describes (None for graphs built
    outside the family machinery, e.g. in tests)."""

    def __init__(self, family_key=None):
        self.family_key = family_key
        self.nodes: Dict[int, TGNode] = {}
        self._next_uid = 0
        self.start = self._new(TGNode(0, START))
        self.end = self._new(TGNode(0, END))
        self.version = 0
        # final variable binding per trace path is resolved at walk time; the
        # graph records which vars are ever assigned (for output slots)
        self.assigned_vars: set = set()
        self.read_vars: set = set()

    # -- construction ------------------------------------------------------
    def _new(self, node: TGNode) -> TGNode:
        node.uid = self._next_uid
        self._next_uid += 1
        self.nodes[node.uid] = node
        return node

    def children_of(self, uid: int) -> List[TGNode]:
        return [self.nodes[c] for c in self.nodes[uid].children]

    # -- merge (paper Fig. 3) ------------------------------------------------
    def merge_trace(self, trace: Trace, rolled_events: List[Any]) -> bool:
        """Merge one (rolled) trace.  Returns True iff the trace was already
        fully covered (no new nodes/edges/annotations) — the paper's tracing
        phase termination condition."""
        changed = False
        cursor = self.start
        ord_to_uid: Dict[int, int] = {}

        for ev in rolled_events:
            if isinstance(ev, SyncMarker):
                uid = self._resolve_ref_uid(ev.ref, ord_to_uid)
                if uid is not None:
                    n = self.nodes[uid]
                    if n.kind == "loop":
                        oi = n.body.out_slot_for(
                            ev.ref, getattr(n, "_last_ordinals", ()))
                    else:
                        oi = ev.ref.out_idx
                    if oi not in n.fetch_idxs or not n.sync_after:
                        changed = True
                    n.fetch_idxs.add(oi)
                    n.sync_after = True
                continue
            if isinstance(ev, VarAssign):
                # annotate on the producing node
                self.assigned_vars.add(ev.var_id)
                uid = self._resolve_ref_uid(ev.ref, ord_to_uid)
                if uid is not None:
                    n = self.nodes[uid]
                    if n.kind == "loop":
                        # rolled loops encode assignments in body.var_binds
                        continue
                    oi = ev.ref.out_idx
                    if (ev.var_id, oi) not in n.var_assigns:
                        n.var_assigns = n.var_assigns + ((ev.var_id, oi),)
                        changed = True
                continue

            if isinstance(ev, LoopEntry):
                srcs = tuple(self._resolve_src(s, ord_to_uid) for s in ev.outer_srcs)
                sig = ("loop", ev.location, ev.body.sig(), srcs)
                nxt = self._match_or_create(cursor, sig, lambda: TGNode(
                    0, "loop", location=ev.location, srcs=srcs,
                    out_avals=ev.out_avals, body=ev.body))
                node, created = nxt
                if created:
                    changed = True
                if ev.trips not in node.trips:
                    node.trips.add(ev.trips)
                    changed = True
                ord_to_uid.update({o: node.uid for o in ev.ordinals})
                node._last_ordinals = ev.ordinals  # for ref resolution
                cursor = node
                continue

            # plain TraceEntry
            e: TraceEntry = ev
            srcs = tuple(self._resolve_src_ref(r, i, e, ord_to_uid)
                         for i, r in enumerate(e.input_refs))
            for r in e.input_refs:
                if isinstance(r, VarRef):
                    self.read_vars.add(r.var_id)
            sig = (e.op_name, e.attrs, e.location, srcs)
            node, created = self._match_or_create(cursor, sig, lambda: TGNode(
                0, "op", op_name=e.op_name, attrs=e.attrs, location=e.location,
                srcs=srcs, out_avals=e.out_avals))
            if created:
                changed = True
            node.entry_stamp = e.stamp()    # Walker fast path (§4.4)
            ord_to_uid[e._ordinal] = node.uid
            cursor = node

        # close to END
        if self.end.uid not in self.nodes[cursor.uid].children:
            self.nodes[cursor.uid].children.append(self.end.uid)
            changed = True
        if changed:
            self.version += 1
        self.last_ord_to_uid = ord_to_uid
        return not changed

    def _match_or_create(self, cursor: TGNode, sig: Tuple, make) -> Tuple[TGNode, bool]:
        # 1) among children of the latest matched node
        for c in self.children_of(cursor.uid):
            if c.kind in ("op", "loop") and c.sig() == sig:
                return c, False
        # 2) merge-back: any equal node elsewhere (paper's branch re-merge)
        for n in self.nodes.values():
            if n.kind in ("op", "loop") and n.sig() == sig:
                self.nodes[cursor.uid].children.append(n.uid)
                return n, True
        # 3) new branch
        node = self._new(make())
        self.nodes[cursor.uid].children.append(node.uid)
        return node, True

    def _resolve_src_ref(self, r, arg_pos: int, e: TraceEntry, ord_to_uid) -> Src:
        if isinstance(r, Ref):
            uid = ord_to_uid[r.entry]
            n = self.nodes[uid]
            if n.kind == "loop":
                # output of a rolled loop = its carried slot's final value
                k = n.body.out_slot_for(r, getattr(n, "_last_ordinals", ()))
                return ("node", uid, k)
            return ("node", uid, r.out_idx)
        if isinstance(r, FeedRef):
            aval = dict(e.feed_avals).get(arg_pos)
            return ("feed", aval)
        if isinstance(r, VarRef):
            return ("var", r.var_id)
        if isinstance(r, Const):
            return ("const", r.value)
        raise TypeError(f"unknown ref {r!r}")

    def _resolve_src(self, s, ord_to_uid) -> Src:
        # outer srcs of rolled loops come pre-encoded with trace ordinals
        if s[0] == "ord":
            _, ordn, out_idx = s
            return ("node", ord_to_uid[ordn], out_idx)
        return s

    def _resolve_ref_uid(self, r, ord_to_uid) -> Optional[int]:
        if isinstance(r, Ref) and r.entry in ord_to_uid:
            return ord_to_uid[r.entry]
        return None

    # -- rewrite support (core/passes/) --------------------------------------
    def clone_for_rewrite(self) -> "TraceGraph":
        """Uid-preserving copy for the optimization passes (DESIGN.md §10).

        The clone shares immutable per-node state (attrs, avals, loop
        bodies) but owns fresh ``srcs`` tuples, children lists and
        annotation sets, so passes can rewrite sources, clear gating flags
        and splice hoisted nodes without ever touching the graph the
        Walker validates against.  ``version``/``family_key`` carry over;
        signature caches are dropped (srcs may be rewritten)."""
        g = TraceGraph.__new__(TraceGraph)
        g.family_key = self.family_key
        g.nodes = {uid: clone_node(n) for uid, n in self.nodes.items()}
        g._next_uid = self._next_uid
        g.start = g.nodes[self.start.uid]
        g.end = g.nodes[self.end.uid]
        g.version = self.version
        g.assigned_vars = set(self.assigned_vars)
        g.read_vars = set(self.read_vars)
        return g

    def splice_before(self, uid: int, node: TGNode) -> TGNode:
        """Insert ``node`` immediately before ``uid`` in the CFG (edge
        split): every parent edge into ``uid`` is redirected through the
        new node.  Only legal on a rewrite clone — fork children lists
        keep their order (the Case Select mapping), because ``uid``
        itself may be a fork child and the new node takes its slot."""
        node = self._new(node)
        for p in self.nodes.values():
            if p is node:
                continue
            p.children = [node.uid if c == uid else c for c in p.children]
            p._uchildren = (-1, ())
        node.children = [uid]
        return node

    # -- queries -------------------------------------------------------------
    def forks(self) -> List[int]:
        return [u for u, n in self.nodes.items()
                if n.kind != "end" and len(set(n.children)) > 1]

    def n_ops(self) -> int:
        return sum(1 for n in self.nodes.values() if n.kind in ("op", "loop"))


# --------------------------------------------------------------------------
# Loop rolling (tandem-repeat detection + carried-state analysis)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class LoopEntry:
    """A rolled loop occurrence inside one trace."""
    location: Tuple[str, int]
    body: LoopBody
    trips: int
    outer_srcs: Tuple[Src, ...]       # ('ord', ordinal, out_idx)|('feed',..)|...
    out_avals: Tuple[Aval, ...]       # final carried values
    ordinals: Tuple[int, ...]         # trace ordinals of all rolled entries


MAX_PERIOD = 8
MIN_TRIPS = 2


def roll_loops(trace: Trace) -> List[Any]:
    """Post-process a trace: collapse tandem-repeated op blocks into
    LoopEntries.  Conservative: a block rolls only if (a) signatures repeat
    exactly, (b) cross-instance dataflow forms a consistent carried-state
    pattern, (c) no feeds / fetches / var reads that vary per trip other
    than through carries, (d) no sync markers inside."""
    events = trace.events
    # Assign ordinals to entries in event order
    ordn = 0
    for ev in events:
        if isinstance(ev, TraceEntry):
            ev._ordinal = ordn
            ordn += 1

    # only entries participate in rolling; markers break blocks
    out: List[Any] = []
    i = 0
    while i < len(events):
        ev = events[i]
        if not isinstance(ev, TraceEntry):
            out.append(ev)
            i += 1
            continue
        rolled = _try_roll_at(events, i, trace)
        if rolled is not None:
            entry, consumed = rolled
            out.append(entry)
            i += consumed
        else:
            out.append(ev)
            i += 1
    return out


def _sig_at(events, i):
    ev = events[i]
    if not isinstance(ev, TraceEntry):
        return None
    return ev.signature()


def _try_roll_at(events, i, trace):
    best = None
    for p in range(1, MAX_PERIOD + 1):
        # block = events[i : i+p]; count tandem repeats
        if i + 2 * p > len(events):
            break
        sig0 = [_sig_at(events, i + k) for k in range(p)]
        if any(s is None for s in sig0):
            break
        reps = 1
        while True:
            base = i + reps * p
            if base + p > len(events):
                break
            sigr = [_sig_at(events, base + k) for k in range(p)]
            if sigr != sig0:
                break
            reps += 1
        if reps >= MIN_TRIPS:
            le = _analyze_block(events, i, p, reps, trace)
            if le is not None and (best is None or p * reps > best[1] * best[2]):
                best = (le, p, reps)
    if best is None:
        return None
    le, p, reps = best
    return le, p * reps


def make_out_slot_for(body: LoopBody, ordinals: Sequence[int]):
    """Build a LoopBody's ``out_slot_for`` closure: maps a Ref into the
    rolled region to the carry slot it produces (0 when not carried).

    ``ordinals`` are the trace ordinals of every rolled entry in
    instance-major order — _analyze_block passes the ordinals of the
    trace being rolled; persist/codec.py passes the node's persisted
    ``_last_ordinals`` to rebuild the closure after a round-trip
    (closures don't serialize, and ordinals restart at 0 per trace, so
    a warm process resolves refs into the hydrated loop identically)."""
    carry_key = {prod: k for k, (_, prod) in enumerate(body.carries)}
    p = max(1, len(body.entries))
    inst_ords = [{o: j for j, o in enumerate(ordinals[r:r + p])}
                 for r in range(0, len(ordinals), p)]

    def out_slot_for(ref, _ordinals, _ck=carry_key, _iords=inst_ords):
        # a Ref into the rolled region maps to the carry slot it produces
        for ords in _iords:
            if isinstance(ref, Ref) and ref.entry in ords:
                prod = (ords[ref.entry], ref.out_idx)
                if prod in _ck:
                    return _ck[prod]
        return 0
    return out_slot_for


def _analyze_block(events, i, p, reps, trace):
    """Validate the carried-state structure of a tandem repeat and build a
    LoopEntry, or return None if inconsistent.

    Classification of every input slot, per instance r:
      internal:  produced by the same instance            -> ('node', j, oi)
      carried:   produced by instance r-1, consistently   -> ('carry', k)
      invariant: identical outer Ref/const/var every trip -> ('inv', m) etc.
    """
    insts = [[events[i + r * p + k] for k in range(p)] for r in range(reps)]
    all_ordinals = tuple(e._ordinal for inst in insts for e in inst)
    inst_ords = [{e._ordinal: j for j, e in enumerate(inst)} for inst in insts]

    carries: List[Tuple[Src, Tuple[int, int]]] = []
    carry_key: Dict[Tuple[int, int], int] = {}   # (local_idx, oi) -> slot
    invariants: List[Src] = []
    inv_key: Dict[Src, int] = {}

    def as_outer(ref) -> Optional[Src]:
        if isinstance(ref, Ref):
            return ("ord", ref.entry, ref.out_idx)
        if isinstance(ref, VarRef):
            return ("var", ref.var_id)
        if isinstance(ref, Const):
            return ("const", ref.value)
        return None   # FeedRef: per-trip feeds unsupported in rolled loops

    body_entries = []
    for j, e in enumerate(insts[0]):
        locals_srcs = []
        for pos, first in enumerate(e.input_refs):
            if isinstance(first, Ref) and first.entry in inst_ords[0]:
                # internal — must be the same local slot in every instance
                loc_idx = inst_ords[0][first.entry]
                for r in range(1, reps):
                    fr = insts[r][j].input_refs[pos]
                    if not (isinstance(fr, Ref) and fr.entry in inst_ords[r]
                            and inst_ords[r][fr.entry] == loc_idx
                            and fr.out_idx == first.out_idx):
                        return None
                locals_srcs.append(("node", loc_idx, first.out_idx))
                continue
            # carried? instance r>=1 consumes instance r-1's local (j', oi)
            carried_prod = None
            is_carried = reps > 1
            for r in range(1, reps):
                fr = insts[r][j].input_refs[pos]
                if not (isinstance(fr, Ref) and fr.entry in inst_ords[r - 1]):
                    is_carried = False
                    break
                pj = (inst_ords[r - 1][fr.entry], fr.out_idx)
                if carried_prod is None:
                    carried_prod = pj
                elif carried_prod != pj:
                    return None
            if is_carried:
                init = as_outer(first)
                if init is None:
                    return None
                slot = carry_key.get(carried_prod)
                if slot is None:
                    slot = len(carries)
                    carries.append((init, carried_prod))
                    carry_key[carried_prod] = slot
                elif carries[slot][0] != init:
                    return None
                locals_srcs.append(("carry", slot))
                continue
            # invariant — identical in every instance
            for r in range(1, reps):
                if insts[r][j].input_refs[pos] != first:
                    return None
            if isinstance(first, Const):
                locals_srcs.append(("const", first.value))
            elif isinstance(first, VarRef):
                locals_srcs.append(("var", first.var_id))
            elif isinstance(first, Ref):
                src = as_outer(first)
                m = inv_key.get(src)
                if m is None:
                    m = len(invariants)
                    invariants.append(src)
                    inv_key[src] = m
                locals_srcs.append(("inv", m))
            else:
                return None   # FeedRef
        be = dataclasses.replace(e)
        be.srcs_local = tuple(locals_srcs)
        body_entries.append(be)

    if not carries:
        return None   # no carried state: keep unrolled

    body = LoopBody(entries=body_entries, carries=carries,
                    invariants=list(invariants))

    # fetches of rolled entries are only recoverable if they are the final
    # trip's carried outputs (post-loop materialization); mid-loop gating
    # fetches never reach here because SyncMarker events break the tandem
    # block contiguity.
    fetched = {r.entry for r in trace.fetches if isinstance(r, Ref)}
    for o in all_ordinals:
        if o in fetched:
            if o not in inst_ords[reps - 1]:
                return None     # fetch of a non-final trip value
            j = inst_ords[reps - 1][o]
            if not any(prod[0] == j for prod in carry_key):
                return None     # fetched value is not a carried output
    # var assigns inside the block must bind to carried producers
    for ev in trace.events:
        if (isinstance(ev, VarAssign) and isinstance(ev.ref, Ref)
                and ev.ref.entry in set(all_ordinals)):
            bound = False
            for r in range(reps):
                if ev.ref.entry in inst_ords[r]:
                    prod = (inst_ords[r][ev.ref.entry], ev.ref.out_idx)
                    if prod in carry_key:
                        body.var_binds[ev.var_id] = carry_key[prod]
                        bound = True
                    break
            if not bound:
                return None

    out_avals = tuple(
        body_entries[prod[0]].out_avals[prod[1]] for (_, prod) in carries)
    outer = tuple(init for (init, _) in carries) + tuple(invariants)
    body.out_slot_for = make_out_slot_for(body, all_ordinals)

    loc = body_entries[0].location
    return LoopEntry(location=loc, body=body, trips=reps, outer_srcs=outer,
                     out_avals=out_avals, ordinals=all_ordinals)
