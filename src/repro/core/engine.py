"""Public Terra API.

``terra.function(fn)`` wraps an imperative step function: each call is one
iteration.  The first iterations run imperatively while traces are
collected; once the TraceGraph covers the latest trace, execution switches
to imperative-symbolic co-execution.  All Python features of ``fn`` keep
working in every phase — third-party calls, object mutation, data-dependent
control flow, generators, try/except — because the Python interpreter
always executes ``fn`` itself (as the skeleton program in the co-execution
phase).

``terra.imperative()`` runs a block under a purely imperative engine (the
paper's baseline): ops execute eagerly, GradientTape works, nothing is
compiled.
"""

from __future__ import annotations

import contextlib
import functools
import hashlib
import time
from typing import Any, Callable, Optional

from repro.core.executor import SKELETON, TRACING, TerraEngine
from repro.core.executor import steady
from repro.core.executor.families import feed_signature
from repro.core.tensor import (TerraTensor, Variable, current_engine,
                               set_current_engine)


def _cache_scope(fn: Callable) -> str:
    """Process-stable digest identifying ``fn`` for the artifact store
    (DESIGN.md §14): module + qualname + a recursive fold over compiled
    bytecode, so two different step functions sharing a cache directory
    never hydrate each other's graphs, while restarting the process (or
    re-decorating the same source) keeps the scope stable."""
    h = hashlib.sha256()
    target = getattr(fn, "__func__", fn)
    h.update(f"{getattr(target, '__module__', '')}."
             f"{getattr(target, '__qualname__', repr(type(target)))}"
             .encode("utf-8"))

    def fold(code) -> None:
        h.update(code.co_code)
        for c in code.co_consts:
            if hasattr(c, "co_code"):
                fold(c)
    code = getattr(target, "__code__", None)
    if code is not None:
        fold(code)
    return h.hexdigest()[:16]


class TerraFunction:
    """An imperative DL program managed by the Terra runtime.

    Each call is keyed by a *shape-class signature* — the (shape, dtype) of
    the call's tensor arguments plus the avals of all bound Variables — and
    the engine keeps one TraceGraph (with its compiled segments) per shape
    class (DESIGN.md §8).  A batch-size or sequence-bucket change therefore
    switches to a sibling graph instead of discarding the current one; each
    shape class traces once, and flipping back is a dictionary lookup.
    ``max_families`` bounds the LRU of live shape classes; ``strict_feeds``
    controls whether a missing Input Feeding value on a taken path raises
    at dispatch time (default) or warns once and substitutes zeros.

    ``steady_state`` (opt-in, default 0 = off) enables zero-walker
    steady-state dispatch (executor/steady.py, DESIGN.md §12): after that
    many consecutive clean eligible iterations of one family, calls
    dispatch the compiled segment directly — ``fn`` is not executed — with
    every ``steady_probe``-th call forced through the full walker path.

    ``cache_dir`` (or ``$TERRA_CACHE_DIR``) enables the persistent artifact
    store (core/persist/, DESIGN.md §14): traced graphs and AOT-compiled
    segments are written to disk and hydrated on the next process start, so
    a warm boot reaches co-execution with zero retraces and zero segment
    recompiles.  ``save_checkpoint``/``restore_checkpoint`` persist the
    engine's Variable buffers and iteration counter for exact continuation.
    """

    def __init__(self, fn: Callable, lazy: bool = False, seed: int = 0,
                 min_covered: int = 1, max_families: int = 8,
                 strict_feeds: bool = True, optimize=None,
                 steady_state: int = 0, steady_probe: int = 64,
                 cache_dir: Optional[str] = None, profile: int = 0):
        self.fn = fn
        self.engine = TerraEngine(lazy=lazy, seed=seed,
                                  min_covered=min_covered,
                                  max_families=max_families,
                                  strict_feeds=strict_feeds,
                                  optimize=optimize,
                                  cache_dir=cache_dir,
                                  cache_scope=_cache_scope(fn))
        self.engine.steady_state = int(steady_state)
        self.engine.steady_probe = int(steady_probe)
        self.engine.profile_every = int(profile)
        functools.update_wrapper(self, fn)

    def __call__(self, *args, **kwargs):
        eng = self.engine
        prev = current_engine()
        set_current_engine(eng)
        t0 = time.perf_counter()
        try:
            out = steady.try_steady(eng, args, kwargs)
            if out is steady.MISS:
                eng._steady_poison = False
                eng.start_iteration(feed_sig=feed_signature(args, kwargs))
                out = self.fn(*args, **kwargs)
                eng.end_iteration()
                steady.attach_futures(eng, out)
                steady.observe(eng, args, kwargs, out)
        except BaseException:
            # leave the engine usable: cancel the half-open iteration and
            # roll back to its start snapshot before propagating
            eng.abort_iteration()
            raise
        finally:
            set_current_engine(prev)
        eng.events.add("py_total_time", time.perf_counter() - t0)
        return out

    @property
    def phase(self) -> str:
        return "co-execution" if self.engine.mode == SKELETON else "tracing"

    @property
    def stats(self):
        return self.engine.stats

    def wait(self):
        """Block until all dispatched graph work (including async device
        execution behind the variable store) has completed."""
        self.engine.sync()

    def save_checkpoint(self, path: str) -> None:
        """Persist Variable buffers + iteration state for exact
        continuation in a fresh process (core/persist/checkpoint.py)."""
        self.engine.save_checkpoint(path)

    def restore_checkpoint(self, path: str) -> None:
        self.engine.restore_checkpoint(path)

    def close(self):
        self.engine.close()


def function(fn: Callable = None, *, lazy: bool = False, seed: int = 0,
             min_covered: int = 1, max_families: int = 8,
             strict_feeds: bool = True, optimize=None,
             steady_state: int = 0, steady_probe: int = 64,
             cache_dir: Optional[str] = None, profile: int = 0):
    """Decorator/factory: manage an imperative step function with Terra.

    ``optimize`` selects the symbolic optimization pipeline run over each
    shape family's TraceGraph before segment compilation (DESIGN.md §10):
    ``"all"`` (default; adds Pallas kernel substitution on TPU), ``"safe"``
    (no constant-feed folding — for drivers whose feeds change per call),
    ``"none"`` (compile the trace verbatim, the pre-pass behaviour), or an
    explicit tuple of pass names.  ``None`` defers to ``$TERRA_OPTIMIZE``.

    ``cache_dir`` enables the persistent artifact store for warm boots
    (DESIGN.md §14); ``None`` defers to ``$TERRA_CACHE_DIR`` (unset: off).

    ``profile`` (opt-in, default 0 = off) samples device-time attribution
    every ``profile``-th iteration (DESIGN.md §15): on a sampled iteration
    the GraphRunner thread blocks on each segment's outputs and emits a
    ``SegmentProfile`` event splitting host dispatch time from device
    execution time.  Requires a structured event processor to be attached;
    non-sampled iterations stay zero-overhead.
    """
    kw = dict(lazy=lazy, seed=seed, min_covered=min_covered,
              max_families=max_families, strict_feeds=strict_feeds,
              optimize=optimize, steady_state=steady_state,
              steady_probe=steady_probe, cache_dir=cache_dir,
              profile=profile)
    if fn is None:
        return lambda f: TerraFunction(f, **kw)
    return TerraFunction(fn, **kw)


@contextlib.contextmanager
def imperative(seed: int = 0):
    """Pure imperative execution (the paper's TensorFlow-eager baseline).

    Every iteration is traced and discarded; ops run eagerly; GradientTape
    and Variables work.  Use ``imp.step()`` to delimit iterations when
    measuring, or just run — the engine treats the whole block as one
    iteration.
    """
    eng = TerraEngine(seed=seed)
    eng.min_covered = 10**9            # never switch to co-execution
    prev = current_engine()
    set_current_engine(eng)
    eng.start_iteration()

    class _Imp:
        engine = eng

        @staticmethod
        def step():
            eng.end_iteration()
            eng.start_iteration()

    try:
        yield _Imp
    finally:
        try:
            eng.end_iteration()
        except Exception:
            pass
        set_current_engine(prev)
        eng.close()
