"""Strict tagged-value codec for persisted artifacts (DESIGN.md §14).

Family records round-trip TraceGraphs, loop bodies and pass observations
through JSON with the strictness discipline of events/schema.py: every
value is a ``[tag, ...]`` list; unknown tags or unencodable values raise
:class:`CodecError`, which the persist layer treats as a clean cache miss
— never a wrong load.  Deliberately NOT serialized (DESIGN.md §14):
``TGNode.entry_stamp`` (``hash()`` is salted per process; the Walker
re-stamps on first structural acceptance) and ``LoopBody.out_slot_for``
(a closure; rebuilt from the persisted ``_last_ordinals``)."""

from __future__ import annotations

import base64
import json
from typing import Any, Dict, List, Set, Tuple

import numpy as np

from repro.core.ops import Const
from repro.core.passes.analysis import (FeedObservations, FetchObservations,
                                        FoldedConst, _VARYING)
from repro.core.trace import Aval, FeedRef, Ref, TraceEntry, VarRef
from repro.core.tracegraph import (LoopBody, TGNode, TraceGraph,
                                   make_out_slot_for)

FORMAT = 1
MAX_ARRAY_BYTES = 1 << 16       # matches analysis.MAX_FOLD_BYTES


class CodecError(ValueError):
    """Value outside the persistable set (encode) or a malformed /
    unknown tag (decode)."""


def _json_key(enc) -> str:
    # encoded values are nested lists of JSON primitives: dumping them is
    # a deterministic total order for canonicalizing sets/dicts
    return json.dumps(enc, sort_keys=True, separators=(",", ":"))


def _enc_array(a: np.ndarray) -> list:
    a = np.ascontiguousarray(a)
    if a.dtype == object or a.nbytes > MAX_ARRAY_BYTES:
        raise CodecError(f"array not persistable: {a.dtype} {a.nbytes}B")
    return [list(a.shape), str(a.dtype),
            base64.b64encode(a.tobytes()).decode("ascii")]


def _dec_array(shape, dtype, b64) -> np.ndarray:
    raw = base64.b64decode(b64.encode("ascii"))
    arr = np.frombuffer(raw, dtype=np.dtype(dtype))
    return arr.reshape(tuple(shape)).copy()


def encode(v) -> list:
    """Encode one value as a tagged JSON-native list."""
    if v is None:
        return ["n"]
    if isinstance(v, bool):
        return ["b", v]
    if isinstance(v, int):
        return ["i", v]
    if isinstance(v, float):
        return ["f", v]
    if isinstance(v, str):
        return ["s", v]
    if isinstance(v, tuple):
        return ["t", [encode(x) for x in v]]
    if isinstance(v, list):
        return ["l", [encode(x) for x in v]]
    if isinstance(v, (set, frozenset)):
        return ["set", sorted((encode(x) for x in v), key=_json_key)]
    if isinstance(v, dict):
        items = [[encode(k), encode(x)] for k, x in v.items()]
        items.sort(key=lambda kv: _json_key(kv[0]))
        return ["d", items]
    if isinstance(v, Aval):
        return ["aval", list(v.shape), v.dtype]
    if isinstance(v, Ref):
        return ["ref", v.entry, v.out_idx]
    if isinstance(v, FeedRef):
        return ["fref", v.entry, v.arg_pos]
    if isinstance(v, VarRef):
        return ["vref", v.var_id]
    if isinstance(v, Const):
        return ["c", encode(v.value)]
    if isinstance(v, FoldedConst):
        return ["fc"] + _enc_array(v.value)
    if isinstance(v, slice):
        return ["sl", encode(v.start), encode(v.stop), encode(v.step)]
    if v is Ellipsis:
        return ["e"]
    if isinstance(v, np.dtype):
        return ["dt", str(v)]
    if isinstance(v, np.generic):
        return ["np", str(v.dtype), v.item()]
    if isinstance(v, np.ndarray):
        return ["nda"] + _enc_array(v)
    raise CodecError(f"unencodable value of type {type(v).__name__}")


_SIMPLE = {"b": bool, "i": int, "f": float, "s": str}


def decode(e):
    """Strict inverse of :func:`encode`."""
    if not isinstance(e, list) or not e:
        raise CodecError(f"malformed encoding {e!r}")
    tag = e[0]
    try:
        if tag == "n":
            return None
        if tag in _SIMPLE:
            return _SIMPLE[tag](e[1])
        if tag == "t":
            return tuple(decode(x) for x in e[1])
        if tag == "l":
            return [decode(x) for x in e[1]]
        if tag == "set":
            return {decode(x) for x in e[1]}
        if tag == "d":
            return {decode(k): decode(x) for k, x in e[1]}
        if tag == "aval":
            return Aval(tuple(e[1]), str(e[2]))
        if tag == "ref":
            return Ref(int(e[1]), int(e[2]))
        if tag == "fref":
            return FeedRef(int(e[1]), int(e[2]))
        if tag == "vref":
            return VarRef(int(e[1]))
        if tag == "c":
            return Const(decode(e[1]))
        if tag == "fc":
            return FoldedConst(_dec_array(e[1], e[2], e[3]))
        if tag == "sl":
            return slice(decode(e[1]), decode(e[2]), decode(e[3]))
        if tag == "e":
            return Ellipsis
        if tag == "dt":
            return np.dtype(e[1])
        if tag == "np":
            return np.dtype(e[1]).type(e[2])
        if tag == "nda":
            return _dec_array(e[1], e[2], e[3])
    except CodecError:
        raise
    except Exception as exc:
        raise CodecError(f"bad {tag!r} payload: {exc}") from None
    raise CodecError(f"unknown tag {tag!r}")


def _check_keys(d: dict, required: Tuple[str, ...],
                optional: Tuple[str, ...] = ()) -> None:
    extra = set(d) - set(required) - set(optional)
    missing = set(required) - set(d)
    if extra or missing:
        raise CodecError(f"extra fields {sorted(extra)}, "
                         f"missing fields {sorted(missing)}")


# -- TraceEntry / LoopBody / TGNode / TraceGraph ----------------------------

def entry_to_dict(e: TraceEntry) -> dict:
    d = {"op": e.op_name, "attrs": encode(e.attrs),
         "loc": [e.location[0], e.location[1]],
         "irefs": encode(e.input_refs), "avals": encode(e.out_avals),
         "favals": encode(e.feed_avals)}
    sl = getattr(e, "srcs_local", None)
    if sl is not None:
        d["slocal"] = encode(sl)
    return d


def entry_from_dict(d: dict) -> TraceEntry:
    _check_keys(d, ("op", "attrs", "loc", "irefs", "avals", "favals"),
                ("slocal",))
    e = TraceEntry(op_name=str(d["op"]), attrs=decode(d["attrs"]),
                   location=(str(d["loc"][0]), int(d["loc"][1])),
                   input_refs=decode(d["irefs"]),
                   out_avals=decode(d["avals"]),
                   feed_avals=decode(d["favals"]))
    if "slocal" in d:
        e.srcs_local = decode(d["slocal"])
    return e


def body_to_dict(b: LoopBody) -> dict:
    return {"entries": [entry_to_dict(e) for e in b.entries],
            "carries": encode(tuple(b.carries)),
            "invariants": encode(tuple(b.invariants)),
            "var_binds": encode(b.var_binds)}


def body_from_dict(d: dict) -> LoopBody:
    _check_keys(d, ("entries", "carries", "invariants", "var_binds"))
    return LoopBody(entries=[entry_from_dict(x) for x in d["entries"]],
                    carries=[tuple(c) for c in decode(d["carries"])],
                    invariants=list(decode(d["invariants"])),
                    var_binds=dict(decode(d["var_binds"])))


def node_to_dict(n: TGNode) -> dict:
    d = {"uid": n.uid, "kind": n.kind, "op": n.op_name,
         "attrs": encode(n.attrs), "loc": [n.location[0], n.location[1]],
         "srcs": encode(n.srcs), "avals": encode(n.out_avals),
         "children": list(n.children), "fetch": sorted(n.fetch_idxs),
         "sync": n.sync_after, "assigns": encode(n.var_assigns),
         "trips": sorted(n.trips)}
    if n.body is not None:
        d["body"] = body_to_dict(n.body)
        d["lords"] = list(getattr(n, "_last_ordinals", ()))
    return d


def node_from_dict(d: dict) -> TGNode:
    _check_keys(d, ("uid", "kind", "op", "attrs", "loc", "srcs", "avals",
                    "children", "fetch", "sync", "assigns", "trips"),
                ("body", "lords"))
    n = TGNode(int(d["uid"]), str(d["kind"]), op_name=str(d["op"]),
               attrs=decode(d["attrs"]),
               location=(str(d["loc"][0]), int(d["loc"][1])),
               srcs=decode(d["srcs"]), out_avals=decode(d["avals"]),
               children=[int(c) for c in d["children"]],
               fetch_idxs={int(i) for i in d["fetch"]},
               sync_after=bool(d["sync"]), var_assigns=decode(d["assigns"]),
               trips={int(t) for t in d["trips"]})
    if "body" in d:
        n.body = body_from_dict(d["body"])
        lords = tuple(int(o) for o in d.get("lords", ()))
        n._last_ordinals = lords
        n.body.out_slot_for = make_out_slot_for(n.body, lords)
    return n


def tg_to_dict(tg: TraceGraph) -> dict:
    return {"nodes": [node_to_dict(tg.nodes[u]) for u in sorted(tg.nodes)],
            "next_uid": tg._next_uid, "start": tg.start.uid,
            "end": tg.end.uid, "version": tg.version,
            "assigned": sorted(tg.assigned_vars),
            "read": sorted(tg.read_vars)}


def tg_from_dict(d: dict, family_key=None) -> TraceGraph:
    _check_keys(d, ("nodes", "next_uid", "start", "end", "version",
                    "assigned", "read"))
    g = TraceGraph.__new__(TraceGraph)
    g.family_key = family_key
    g.nodes = {}
    for nd in d["nodes"]:
        n = node_from_dict(nd)
        g.nodes[n.uid] = n
    g._next_uid = int(d["next_uid"])
    g.start = g.nodes[int(d["start"])]
    g.end = g.nodes[int(d["end"])]
    g.version = int(d["version"])
    g.assigned_vars = {int(v) for v in d["assigned"]}
    g.read_vars = {int(v) for v in d["read"]}
    return g


# -- observation records -----------------------------------------------------

def feed_obs_to_dict(fo: FeedObservations) -> dict:
    slots = []
    for k in sorted(fo.slots):
        v = fo.slots[k]
        slots.append([list(k), None if v is _VARYING
                      else [_enc_array(v[0]), int(v[1])]])
    return {"version": fo.version, "slots": slots}


def feed_obs_from_dict(d: dict) -> FeedObservations:
    _check_keys(d, ("version", "slots"))
    fo = FeedObservations()
    fo.version = int(d["version"])
    for k, v in d["slots"]:
        key = (int(k[0]), int(k[1]))
        fo.slots[key] = _VARYING if v is None else (
            _dec_array(*v[0]), int(v[1]))
    return fo


def fetch_obs_to_dict(fo: FetchObservations) -> dict:
    ra = [[list(k),
           sorted(fo.read_after[k], key=lambda u: -1 if u is None else u)]
          for k in sorted(fo.read_after)]
    return {"version": fo.version, "read_after": ra}


def fetch_obs_from_dict(d: dict) -> FetchObservations:
    _check_keys(d, ("version", "read_after"))
    fo = FetchObservations()
    fo.version = int(d["version"])
    for k, pts in d["read_after"]:
        fo.read_after[(int(k[0]), int(k[1]))] = {
            None if p is None else int(p) for p in pts}
    return fo


# -- family records -----------------------------------------------------------

def family_record(tg, feed_obs, fetch_obs, feed_sig, var_avals,
                  tombstones, pipeline) -> dict:
    """Everything needed to hydrate a family in a fresh process.  The
    pass pipeline is recorded for inspection only — hydration replays
    ``run_passes`` with the *current* engine pipeline, because the
    observations are pipeline-independent facts about the program."""
    return {"fmt": FORMAT,
            "feed_sig": encode(feed_sig),
            "tg": tg_to_dict(tg),
            "feed_obs": feed_obs_to_dict(feed_obs),
            "fetch_obs": fetch_obs_to_dict(fetch_obs),
            "var_avals": [[int(vid), [list(a.shape), a.dtype]]
                          for vid, a in sorted(var_avals.items())],
            "tombstones": [[int(vid), [list(s), str(dt)]]
                           for vid, (s, dt) in sorted(tombstones.items())],
            "pipeline": list(pipeline)}


class FamilyRecord:
    __slots__ = ("feed_sig", "tg", "feed_obs", "fetch_obs", "var_avals",
                 "tombstones", "pipeline")


def parse_family_record(doc: dict) -> FamilyRecord:
    if not isinstance(doc, dict) or doc.get("fmt") != FORMAT:
        raise CodecError(f"unsupported family record {type(doc).__name__}")
    _check_keys(doc, ("fmt", "feed_sig", "tg", "feed_obs", "fetch_obs",
                      "var_avals", "tombstones", "pipeline"))
    rec = FamilyRecord()
    rec.feed_sig = decode(doc["feed_sig"])
    rec.tg = tg_from_dict(doc["tg"])
    rec.feed_obs = feed_obs_from_dict(doc["feed_obs"])
    rec.fetch_obs = fetch_obs_from_dict(doc["fetch_obs"])
    rec.var_avals = {int(vid): Aval(tuple(a[0]), str(a[1]))
                     for vid, a in doc["var_avals"]}
    rec.tombstones = {int(vid): (tuple(s[0]), str(s[1]))
                      for vid, s in doc["tombstones"]}
    rec.pipeline = tuple(str(p) for p in doc["pipeline"])
    return rec


def collect_var_ids(tg: TraceGraph) -> Set[int]:
    """Every variable id the graph reads or writes — the coverage set a
    family record must describe (live avals or tombstones) to be saved."""
    out: Set[int] = set()
    for n in tg.nodes.values():
        for s in n.srcs:
            if s and s[0] == "var":
                out.add(s[1])
        for vid, _ in n.var_assigns:
            out.add(vid)
        if n.body is not None:
            out.update(n.body.var_binds)
            for e in n.body.entries:
                for s in getattr(e, "srcs_local", ()):
                    if s and s[0] == "var":
                        out.add(s[1])
    return out
