"""Persistent artifact store + engine checkpoint/restore (DESIGN.md §14).

Warm-boot co-execution: with ``$TERRA_CACHE_DIR`` set (or ``cache_dir``
passed to :func:`repro.core.engine.function`), every GraphProgram
regeneration persists the family's TraceGraph + pass observations and
every compiled segment's jax AOT executable.  A fresh process hydrates
them instead of tracing and compiling — zero retraces, zero segment
recompiles — while the Walker still validates the hydrated graph
op-by-op on the first iteration ("slower never wrong").

Module map:

* codec.py — strict tagged round-trip of TraceGraphs and observations
* keys.py — sha256 cache keys + the versioned store namespace
* store.py — atomic content-addressed file store
* aot.py — AOT compile/serialize/deserialize of segments
* warmboot.py — :class:`PersistLayer`, the engine-facing glue
* checkpoint.py — :func:`save_engine` / :func:`restore_engine`

Usage::

    os.environ["TERRA_CACHE_DIR"] = "/var/cache/terra"   # before import
    step = terra.function(train_step)    # warm-boots automatically

    step.engine.save_checkpoint("ckpt/")             # process A
    step.engine.restore_checkpoint("ckpt/")          # process B, then call
"""

from repro.core.persist.checkpoint import restore_engine, save_engine
from repro.core.persist.warmboot import PersistLayer

__all__ = ["PersistLayer", "save_engine", "restore_engine"]
