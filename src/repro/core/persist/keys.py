"""Cache-key discipline for the artifact store (DESIGN.md §14).

Nothing derived from Python's process-salted ``hash()`` (entry stamps,
``avals_digest``, ``FoldedConst._key``) ever reaches disk: on-disk keys
are sha256 digests of the canonical JSON form produced by codec.py.  The
store namespace folds in the jax version, the active backend and a digest
of the repro source tree, so upgrading jax, switching platform or editing
the engine makes every prior artifact a clean miss — never a wrong load.
``$TERRA_CACHE_SALT`` is appended to the namespace when set (the tests'
version-skew lever; also handy for manual cache busting)."""

from __future__ import annotations

import functools
import hashlib
import json
import os
from typing import Optional

import jax

from repro.core.persist import codec


def canonical_json(v) -> str:
    """Deterministic JSON for any codec-encodable value; raises
    :class:`codec.CodecError` otherwise."""
    return json.dumps(codec.encode(v), sort_keys=True,
                      separators=(",", ":"))


def digest_of(v) -> Optional[str]:
    """sha256 digest of a value's canonical form, or None when the value
    is not encodable (callers treat None as 'not persistable')."""
    try:
        s = canonical_json(v)
    except codec.CodecError:
        return None
    return hashlib.sha256(s.encode("utf-8")).hexdigest()[:24]


@functools.lru_cache(maxsize=1)
def code_digest() -> str:
    """Digest of every .py file under the repro package — any source edit
    invalidates the whole cache namespace."""
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    h = hashlib.sha256()
    paths = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for fn in filenames:
            if fn.endswith(".py"):
                paths.append(os.path.join(dirpath, fn))
    for p in sorted(paths):
        h.update(os.path.relpath(p, root).encode("utf-8"))
        try:
            with open(p, "rb") as f:
                h.update(f.read())
        except OSError:
            h.update(b"<unreadable>")
    return h.hexdigest()[:16]


def namespace() -> str:
    """Versioned manifest key: the store root subdirectory all artifacts
    of this (jax version, backend, code) combination live under."""
    ns = f"jax{jax.__version__}-{jax.default_backend()}-code{code_digest()}"
    salt = os.environ.get("TERRA_CACHE_SALT", "")
    if salt:
        ns += f"-{salt}"
    return ns


def family_dir(scope: str, feed_sig) -> Optional[str]:
    """Relative directory holding all candidate records for one
    (function scope, feed signature) pair; sibling var-aval classes are
    sibling files inside it."""
    d = digest_of(("family", scope, feed_sig))
    return None if d is None else f"fam/{d}"


def record_name(var_avals: dict) -> Optional[str]:
    d = digest_of(("vars", tuple(sorted(var_avals.items()))))
    return None if d is None else f"{d}.json"


def segment_rel(signature, var_avals_of_reads) -> Optional[str]:
    """Relative path of a segment's AOT executable.  The structural
    signature does not capture variable avals (var_reads are raw ids), so
    they are folded in here — two families sharing a signature but
    differing in buffer shapes must not share an executable."""
    d = digest_of(("segment", signature, var_avals_of_reads))
    return None if d is None else f"seg/{d}.bin"
