"""PersistLayer: warm-boot glue between the engine and the ArtifactStore.

Three artifact kinds (DESIGN.md §14):

* **family records** (``fam/<dir>/<vars>.json``) — serialized TraceGraph +
  pass observations + variable avals, written after every GraphProgram
  regeneration and on LRU eviction.  A cold ``FamilyManager`` miss whose
  feed signature matches a record hydrates the graph and rebuilds the
  GraphProgram by replaying the pass pipeline — no tracing.  Legality:
  the Walker still validates the hydrated graph op-by-op on its first
  iteration; any mismatch diverges into a fresh trace and deletes the
  record ("slower never wrong").
* **segment executables** (``seg/<digest>.bin``) — jax AOT blobs,
  consulted by ``SegmentCache.get_or_build`` through the ``loader``
  hook (a load is a cache HIT: ``segments_recompiled`` stays 0).
* **engine checkpoints** — see checkpoint.py (plain directories, not
  content-addressed).

Every failure mode — unreadable file, schema violation, aval conflict,
AOT deserialization error — is a clean miss that falls back to the
ordinary trace/compile path.
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

from repro.core.events import emit as ev
from repro.core.persist import aot, codec, keys
from repro.core.persist.store import ArtifactStore

_NEVER_HYDRATE = 10 ** 9        # engine.imperative() sets min_covered here


class PersistLayer:
    """One per engine; owns the store handle and the hit/miss accounting."""

    def __init__(self, root: str, events, scope: str = "", engine=None):
        self.store = ArtifactStore(root, keys.namespace())
        self.events = events
        self.stats = events.counters
        self.scope = scope
        self.engine = engine
        self.segments_dropped = 0   # in-memory evictions (disk blobs kept)

    # -- accounting ---------------------------------------------------------
    def _hit(self, kind: str, ref: str) -> None:
        self.stats["artifact_hits"] += 1
        ev.artifact_hit(self.events, kind, ref)

    def _miss(self, kind: str, ref: str, reason: str) -> None:
        self.stats["artifact_misses"] += 1
        ev.artifact_miss(self.events, kind, ref, reason)

    def _stored(self, kind: str, ref: str, nbytes: int) -> None:
        self.stats["artifacts_stored"] += 1
        ev.artifact_store(self.events, kind, ref, nbytes)

    # -- family records -------------------------------------------------------
    def save_family(self, fam) -> None:
        """Persist one family's graph + observations.  Skipped (never an
        error) when the family has no program yet, is still an unconfirmed
        hydration, or references state the record cannot describe."""
        eng = self.engine
        if fam.gp is None or eng is None or fam.hydrated:
            return
        reldir = keys.family_dir(self.scope, fam.key[0])
        if reldir is None:
            return
        var_avals = dict(fam.gp.var_avals)
        tombs = {vid: (tuple(s), str(dt))
                 for vid, (s, dt) in eng.store.tombstones.items()}
        if codec.collect_var_ids(fam.tg) - set(var_avals) - set(tombs):
            return              # graph reads vars we cannot placehold
        name = keys.record_name(var_avals)
        if name is None:
            return
        try:
            doc = codec.family_record(fam.tg, fam.feed_obs, fam.fetch_obs,
                                      fam.key[0], var_avals, tombs,
                                      eng.pipeline)
        except codec.CodecError:
            return
        rel = f"{reldir}/{name}"
        nbytes = self.store.write_json(rel, doc)
        if nbytes:
            fam._persist_rec = rel
            self._stored("family", rel, nbytes)

    def hydrate_family(self, key: Tuple, engine) -> Optional[Any]:
        """Rebuild a TraceFamily from disk for a cold activation, or None
        (the ordinary fresh-trace path).  Candidates under the (scope,
        feed_signature) directory are tried newest-first; one whose
        variable avals conflict with live state is skipped, and a
        malformed one is deleted."""
        if engine.min_covered >= _NEVER_HYDRATE:
            return None         # imperative baseline never hydrates
        reldir = keys.family_dir(self.scope, key[0])
        if reldir is None:
            return None
        names = self.store.list(reldir)
        if not names:
            self._miss("family", reldir, "absent")
            return None
        for name in names:
            fam = self._try_hydrate(f"{reldir}/{name}", key, engine)
            if fam is not None:
                return fam
        self._miss("family", reldir, "no-usable-candidate")
        return None

    def _try_hydrate(self, rel: str, key: Tuple, engine) -> Optional[Any]:
        doc = self.store.read_json(rel)
        if doc is None:
            self.store.delete(rel)      # unreadable/truncated: clean miss
            return None
        live = engine.store.vars
        try:
            rec = codec.parse_family_record(doc)
        except codec.CodecError:
            self.store.delete(rel)      # schema violation: clean miss
            return None
        if rec.feed_sig != key[0]:
            return None
        for vid, aval in rec.var_avals.items():
            v = live.get(vid)
            if v is not None and v.aval != aval:
                return None             # conflicting live state: skip
        try:
            rec.tg.family_key = key
            gp = self._build_program(rec, key, engine)
        except Exception:
            self.store.delete(rel)      # unbuildable record: clean miss
            return None
        # vars the record describes but this process hasn't registered yet
        # get tombstone placeholders: dead-branch reads need an aval, and
        # ensure() clears the tombstone the moment the real var registers
        for vid, aval in rec.var_avals.items():
            if vid not in live:
                engine.store.tombstones.setdefault(
                    vid, (tuple(aval.shape), aval.dtype))
        for vid, (shape, dt) in rec.tombstones.items():
            if vid not in live:
                engine.store.tombstones.setdefault(vid, (tuple(shape), dt))
        from repro.core.executor.families import TraceFamily
        fam = TraceFamily(key, rec.tg, gp, mode="skeleton",
                          covered_streak=engine.min_covered,
                          feed_obs=rec.feed_obs, fetch_obs=rec.fetch_obs)
        fam.hydrated = True
        fam._persist_rec = rel
        self.stats["warm_families"] += 1
        self._hit("family", rel)
        return fam

    def _build_program(self, rec, key, engine):
        from repro.core.graphgen import GraphProgram
        from repro.core.passes import run_passes
        # replay the pass pipeline with the CURRENT engine configuration:
        # observations are pipeline-independent facts, so a record written
        # under a different $TERRA_OPTIMIZE hydrates correctly
        va = dict(rec.var_avals)
        opt = run_passes(rec.tg, va, engine.pipeline,
                         rec.feed_obs, rec.fetch_obs)
        gp = GraphProgram(rec.tg, va, seg_cache=engine.seg_cache,
                          family_key=key, opt=opt)
        gp.opt_token = (engine.pipeline, rec.feed_obs.version,
                        rec.fetch_obs.version)
        return gp

    def on_family_evicted(self, fam) -> None:
        """LRU eviction callback: save the victim's graph (if it isn't on
        disk already) so the eviction is reversible via hydration."""
        if fam._persist_rec is None:
            self.save_family(fam)

    def on_hydrated_divergence(self, fam) -> None:
        """The hydrated graph failed first-iteration validation: the record
        describes a different program — delete it (the fresh trace's save
        overwrites the slot)."""
        if fam._persist_rec is not None:
            self.store.delete(fam._persist_rec)
            fam._persist_rec = None

    # -- segment executables ---------------------------------------------------
    def _segment_rel(self, gp, sp) -> Optional[str]:
        va = tuple(sorted((v, gp.var_avals[v]) for v in sp.var_reads
                          if v in gp.var_avals))
        return keys.segment_rel(sp.signature, va)

    def load_segment(self, gp, sp, jit_each: bool) -> Optional[Any]:
        """SegmentCache ``loader`` hook: the on-disk AOT executable, or
        None (in-memory miss semantics; builder runs next)."""
        if not jit_each:
            return None
        rel = self._segment_rel(gp, sp)
        if rel is None:
            return None
        blob = self.store.read_bytes(rel)
        if blob is None:
            self._miss("segment", rel, "absent")
            return None
        try:
            fn = aot.load_compiled(blob)
        except Exception:
            self.store.delete(rel)      # stale/corrupt blob: clean miss
            self._miss("segment", rel, "corrupt")
            return None
        self.stats["aot_loads"] += 1
        self._hit("segment", rel)
        return fn

    def build_segment(self, gp, sp, jit_each: bool) -> Any:
        """SegmentCache ``builder`` hook: AOT-compile + serialize to disk,
        falling back to the plain jit wrapper (signature-only persistence)
        when AOT is unavailable for this segment."""
        if not jit_each:
            return gp._compile_segment(sp, jit_each)
        rel = self._segment_rel(gp, sp)
        if rel is None:
            return gp._compile_segment(sp, jit_each)
        try:
            compiled, blob = aot.compile_aot(gp, sp)
        except Exception:
            return gp._compile_segment(sp, jit_each)
        if blob is not None:
            nbytes = self.store.write_bytes(rel, blob)
            if nbytes:
                self._stored("segment", rel, nbytes)
        return compiled

    def on_segments_evicted(self, dropped: List) -> None:
        """SegmentCache.retain callback.  Nothing to write: executables
        were serialized at build time and deliberately survive in-memory
        eviction — that is what lets an evicted-then-reactivated family
        reload instead of recompiling."""
        self.segments_dropped += len(dropped)
