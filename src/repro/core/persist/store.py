"""Content-addressed on-disk artifact store (DESIGN.md §14).

Layout: ``<root>/<namespace>/<relpath>`` where ``namespace`` comes from
keys.namespace() (jax version + backend + code digest).  Writes are
atomic (temp file + ``os.replace``) so a concurrent reader sees either
the old artifact or the new one, never a torn file; reads return None on
ANY failure — a missing, truncated or unparsable artifact is always a
clean cache miss."""

from __future__ import annotations

import json
import os
from typing import Any, List, Optional


class ArtifactStore:
    def __init__(self, root: str, namespace: str):
        self.root = root
        self.base = os.path.join(root, namespace)

    def path(self, rel: str) -> str:
        return os.path.join(self.base, rel)

    # -- writes (atomic; failures degrade to 'not persisted') --------------
    def write_bytes(self, rel: str, data: bytes) -> int:
        """Write atomically; returns bytes written (0 on failure)."""
        path = self.path(rel)
        tmp = f"{path}.tmp{os.getpid()}"
        try:
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)
            return len(data)
        except OSError:
            try:
                os.remove(tmp)
            except OSError:
                pass
            return 0

    def write_json(self, rel: str, doc: Any) -> int:
        try:
            data = json.dumps(doc, separators=(",", ":")).encode("utf-8")
        except (TypeError, ValueError):
            return 0
        return self.write_bytes(rel, data)

    # -- reads (any failure is a miss) --------------------------------------
    def read_bytes(self, rel: str) -> Optional[bytes]:
        try:
            with open(self.path(rel), "rb") as f:
                return f.read()
        except OSError:
            return None

    def read_json(self, rel: str) -> Optional[Any]:
        data = self.read_bytes(rel)
        if data is None:
            return None
        try:
            return json.loads(data.decode("utf-8"))
        except Exception:
            return None

    def delete(self, rel: str) -> None:
        try:
            os.remove(self.path(rel))
        except OSError:
            pass

    def list(self, reldir: str) -> List[str]:
        """Artifact names under a relative directory, newest first (the
        hydration order: most recently written candidate wins)."""
        base = self.path(reldir)
        try:
            names = [n for n in os.listdir(base) if ".tmp" not in n]
        except OSError:
            return []

        def mtime(n: str) -> float:
            try:
                return os.path.getmtime(os.path.join(base, n))
            except OSError:
                return 0.0
        return sorted(names, key=mtime, reverse=True)
