"""Engine checkpoint/restore (DESIGN.md §14).

A checkpoint is a plain directory (NOT content-addressed, works without
``$TERRA_CACHE_DIR``): ``variables.npz`` holds every VariableStore buffer
keyed by var id, ``engine.json`` the iteration counter (which keeps the
per-iteration rng stream — ``fold_in(base_key, iter_id)`` — aligned after
restore) and the released-variable tombstones.

Restore is buffer seeding, deliberately decoupled from Variable
registration: ``VariableStore.ensure`` only seeds a buffer when none
exists, so buffers restored *before* the program re-registers its
Variables survive registration and the first iteration reads checkpointed
state.  What is NOT in a checkpoint: TraceGraphs, compiled segments and
walker state (the artifact store covers those; a restored engine without
a warm cache simply retraces — slower, never wrong) and pending runner
work (callers checkpoint at iteration boundaries, after ``sync()``).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.events import emit as ev

FORMAT = 1


def _write_atomic(path: str, data: bytes) -> None:
    tmp = f"{path}.tmp{os.getpid()}"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes                 # jax dependency: bfloat16 etc.
        return np.dtype(getattr(ml_dtypes, name))


def pack_arrays(arrays: dict) -> dict:
    """Flatten arrays to raw bytes + a string sidecar (``k__meta`` =
    [dtype, *shape]) so extension dtypes (bfloat16) survive ``np.savez``,
    which would otherwise reload them as opaque void records."""
    out = {}
    for k, v in arrays.items():
        a = np.ascontiguousarray(np.asarray(v))
        out[k] = a.reshape(-1).view(np.uint8)
        out[f"{k}__meta"] = np.array([str(a.dtype)]
                                     + [str(s) for s in a.shape])
    return out


def unpack_array(z, k: str) -> np.ndarray:
    meta = [str(x) for x in z[f"{k}__meta"]]
    dt = _np_dtype(meta[0])
    shape = tuple(int(s) for s in meta[1:])
    return z[k].view(dt).reshape(shape)


def save_engine(engine, path: str) -> None:
    """Snapshot VariableStore buffers + iteration state into ``path``."""
    engine.sync()
    os.makedirs(path, exist_ok=True)
    arrays = {str(vid): np.asarray(buf)
              for vid, buf in engine.store.buffers.items()}
    npz = os.path.join(path, "variables.npz")
    tmp = os.path.join(path, f"variables.tmp{os.getpid()}.npz")
    np.savez(tmp, **pack_arrays(arrays))
    os.replace(tmp, npz)
    meta = {"fmt": FORMAT, "iter_id": engine.iter_id,
            "tombstones": [[int(vid), [list(s), str(dt)]]
                           for vid, (s, dt)
                           in sorted(engine.store.tombstones.items())]}
    _write_atomic(os.path.join(path, "engine.json"),
                  json.dumps(meta, indent=1).encode("utf-8"))
    engine.stats["checkpoint_saves"] += 1
    ev.checkpoint_save(engine.events, path, vars_saved=len(arrays))


def restore_engine(engine, path: str) -> dict:
    """Seed a fresh engine from a checkpoint directory; call before the
    first iteration (buffers must land before Variables re-register).
    Raises on a missing or malformed checkpoint — a checkpoint is
    explicit state the caller asked for, so unlike the artifact store a
    failure here must not silently degrade to a cold start."""
    import jax.numpy as jnp
    with open(os.path.join(path, "engine.json"), "rb") as f:
        meta = json.loads(f.read().decode("utf-8"))
    if meta.get("fmt") != FORMAT:
        raise ValueError(f"unsupported checkpoint format {meta.get('fmt')!r}")
    with np.load(os.path.join(path, "variables.npz")) as z:
        for k in z.files:
            if k.endswith("__meta"):
                continue
            engine.store.buffers[int(k)] = jnp.asarray(unpack_array(z, k))
    engine.iter_id = int(meta["iter_id"])
    for vid, (shape, dt) in meta["tombstones"]:
        if int(vid) not in engine.store.vars:
            engine.store.tombstones.setdefault(
                int(vid), (tuple(shape), str(dt)))
    engine.stats["checkpoint_restores"] += 1
    ev.checkpoint_restore(engine.events, path,
                          vars_restored=len(engine.store.buffers))
    return meta
