"""jax AOT (ahead-of-time) segment persistence (DESIGN.md §14).

A segment's jitted callable is lowered against ShapeDtypeStruct specs
matching the dispatch call convention exactly —
``fn(don_var_in, keep_var_in, feeds, sels, trips, carries_in)`` with
``donate_argnums=(0,)`` — compiled once, and the compiled executable
serialized via ``jax.experimental.serialize_executable``.  A warm process
deserializes and calls it directly: zero tracing, zero XLA compilation.

Everything here is best-effort: any failure (unsupported dtype, a
tombstoned variable, a backend that cannot serialize executables) makes
the caller fall back to the ordinary ``jax.jit`` wrapper — signature-only
persistence, which still skips tracing and pass reruns."""

from __future__ import annotations

import pickle
from typing import Any, Optional, Tuple

import numpy as np
import jax


def _sds(aval) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(aval.shape), np.dtype(aval.dtype))


def segment_specs(gp, sp) -> Tuple:
    """Abstract argument specs for one SegProg, mirroring the concrete
    arrays SegmentDispatcher passes at runtime (donated variable buffers,
    retained buffers, Input Feeding slots, Case Select / Loop Cond vectors
    and cross-segment carries)."""
    don = tuple(_sds(gp.var_avals[v]) for v in sp.don_var_ids)
    keep = tuple(_sds(gp.var_avals[v]) for v in sp.keep_var_ids)
    feeds = tuple(_sds(a) for (_, _, a) in sp.feed_keys)
    sels = jax.ShapeDtypeStruct((gp.n_selectors,), np.int32)
    trips = jax.ShapeDtypeStruct((gp.n_trips,), np.int32)
    carries = tuple(_sds(gp._aval_of(k)) for k in sp.carries_in)
    return don, keep, feeds, sels, trips, carries


def compile_aot(gp, sp) -> Tuple[Any, Optional[bytes]]:
    """Compile one segment ahead of time.  Returns ``(compiled, blob)``
    where ``blob`` is the serialized executable (None when serialization
    is unavailable — the compiled object is still usable in-process).
    Raises on lowering/compilation failure; callers catch and fall back."""
    specs = segment_specs(gp, sp)
    jitted = gp._compile_segment(sp, jit_each=True)
    compiled = jitted.lower(*specs).compile()
    try:
        from jax.experimental import serialize_executable as se
        blob = pickle.dumps(se.serialize(compiled))
    except Exception:
        blob = None
    return compiled, blob


def load_compiled(blob: bytes) -> Any:
    """Deserialize an AOT executable.  Raises on any mismatch (stale
    format, different XLA build) — callers treat that as a corrupt
    artifact and delete it."""
    from jax.experimental import serialize_executable as se
    return se.deserialize_and_load(*pickle.loads(blob))
