"""Symbolic optimization pass pipeline over the decoupled graph.

Terra's decoupling argument (paper §3) is that once DL ops are separated
from Python features, the symbolic side can deliver "the optimized
performance of symbolic graph execution".  This package is that promise
made concrete (DESIGN.md §10): a pipeline of semantics-preserving
rewrites that runs **once per shape family**, between trace completion
and segment compilation, over a rewrite-safe *clone* of the TraceGraph —
the Walker keeps validating against the original graph, so divergence
detection, rollback and walker stamps are untouched.

Passes (canonical order):

    fold      constant-feed folding: Input Feeds observed identical across
              the covered streak demote to baked constants; a later value
              mismatch diverges back to a feed (walker probe)
    cse       common-subexpression elimination keyed on TGNode.sig()
              minus program location, including hoisting duplicates out
              of sibling switch branches
    kernels   pattern-match traced subgraphs (rms_norm, softmax
              attention) into the Pallas kernels under repro/kernels/
    dce       dead-op elimination for nodes whose outputs are never
              fetched, variable-written or loop-carried
    coalesce  segment coalescing: drop gating boundaries whose fetch
              values Python provably reads late (fetch-timing
              observations), plus the empty trailing segment

``optimize="none"`` short-circuits to no pipeline: the GraphProgram then
compiles the original graph exactly as before, bit for bit.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Sequence, Set, Tuple

from repro.core.passes.analysis import (FeedObservations, FetchObservations,
                                        FoldedConst, observe_iteration)

Key = Tuple[int, int]

PASS_ORDER = ("fold", "cse", "kernels", "dce", "coalesce")

PIPELINES = {
    "none": (),
    # "safe": everything that never bakes a Python value into the graph —
    # serving uses this so per-call feeds (decode tokens) are never folded
    "safe": ("cse", "dce", "coalesce"),
    "all": ("fold", "cse", "dce", "coalesce"),
}


def resolve_pipeline(optimize, backend: Optional[str] = None) -> Tuple[str, ...]:
    """Normalize the ``optimize=`` knob to a canonical pass tuple.

    ``None`` defers to ``TERRA_OPTIMIZE`` (default ``all``).  ``"all"``
    additionally enables kernel substitution on TPU backends, where the
    Pallas kernels compile natively; elsewhere ``kernels`` must be
    requested explicitly (interpret-mode execution is for validation, not
    speed).  An explicit tuple/list is validated and reordered."""
    if optimize is None:
        optimize = os.environ.get("TERRA_OPTIMIZE") or "all"
    if isinstance(optimize, str):
        if optimize not in PIPELINES:
            raise ValueError(f"unknown optimize level {optimize!r}; "
                             f"expected one of {sorted(PIPELINES)} or a "
                             f"tuple of pass names {PASS_ORDER}")
        passes = set(PIPELINES[optimize])
        if optimize == "all":
            if backend is None:
                import jax
                backend = jax.default_backend()
            if backend == "tpu":
                passes.add("kernels")
    else:
        passes = set(optimize)
        unknown = passes - set(PASS_ORDER)
        if unknown:
            raise ValueError(f"unknown pass names {sorted(unknown)}")
    return tuple(p for p in PASS_ORDER if p in passes)


@dataclasses.dataclass
class OptResult:
    """Pipeline output consumed by GraphProgram: the optimized graph plus
    the execution-time annotations graphgen honors (skip dead nodes, bind
    alias outputs from their representative, unwrap folded constants) and
    the walker-side fold probes.  Cached on the GraphProgram (per family)
    and rebuilt whenever the graph version or the observations change."""
    otg: Any                                     # rewritten TraceGraph clone
    pipeline: Tuple[str, ...] = ()
    dead: Set[int] = dataclasses.field(default_factory=set)
    alias_nodes: Dict[int, Tuple[Key, ...]] = dataclasses.field(
        default_factory=dict)
    folded: Dict[Key, FoldedConst] = dataclasses.field(default_factory=dict)
    # kernel substitution can move a feed source onto a new consumer node;
    # the Walker still collects the value under the ORIGINAL (uid, pos),
    # so graphgen emits dispatch feed keys through this map:
    # (new_uid, new_pos) -> (orig_uid, orig_pos)
    feed_moved: Dict[Key, Key] = dataclasses.field(default_factory=dict)
    drop_empty_trailing: bool = False
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)
    # per-pass counter deltas, in pipeline order: pass name -> the subset
    # of ``counters`` that pass changed (the PassPipelineRun event payload)
    per_pass: Dict[str, Dict[str, int]] = dataclasses.field(
        default_factory=dict)

    def eff_srcs(self, n) -> Tuple:
        """Effective dataflow sources of a node after rewriting: dead
        nodes consume nothing, alias nodes consume their representative's
        outputs, everything else its (possibly rewritten) srcs."""
        if n.uid in self.dead:
            return ()
        al = self.alias_nodes.get(n.uid)
        if al is not None:
            return tuple(("node", u, oi) for (u, oi) in al)
        return n.srcs

    def bump(self, counter: str, by: int = 1) -> None:
        self.counters[counter] = self.counters.get(counter, 0) + by


class PassContext:
    """Mutable state threaded through one pipeline run."""

    def __init__(self, otg, opt: OptResult, var_avals,
                 feed_obs: FeedObservations, fetch_obs: FetchObservations):
        self.otg = otg
        self.opt = opt
        self.var_avals = var_avals
        self.feed_obs = feed_obs
        self.fetch_obs = fetch_obs
        self._structure = None

    @property
    def structure(self):
        if self._structure is None:
            from repro.core.casing import Structure
            self._structure = Structure(self.otg)
        return self._structure

    def invalidate_structure(self) -> None:
        self._structure = None


def run_passes(tg, var_avals, pipeline: Sequence[str],
               feed_obs: FeedObservations,
               fetch_obs: FetchObservations) -> Optional[OptResult]:
    """Run ``pipeline`` over a rewrite clone of ``tg``; None when empty."""
    if not pipeline:
        return None
    from repro.core.passes import coalesce, cse, dce, feed_fold, kernel_sub
    runners = {"fold": feed_fold.run, "cse": cse.run,
               "kernels": kernel_sub.run, "dce": dce.run,
               "coalesce": coalesce.run}
    otg = tg.clone_for_rewrite()
    opt = OptResult(otg=otg, pipeline=tuple(pipeline))
    ctx = PassContext(otg, opt, var_avals, feed_obs, fetch_obs)
    for name in PASS_ORDER:
        if name in pipeline:
            before = dict(opt.counters)
            runners[name](ctx)
            delta = {k: v - before.get(k, 0)
                     for k, v in opt.counters.items()
                     if v != before.get(k, 0)}
            opt.per_pass[name] = delta
    return opt


__all__ = ["FeedObservations", "FetchObservations", "FoldedConst",
           "OptResult", "PassContext", "observe_iteration", "PASS_ORDER",
           "PIPELINES", "resolve_pipeline", "run_passes"]
