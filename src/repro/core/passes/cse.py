"""Common-subexpression elimination (pipeline stage ``cse``, DESIGN.md §10).

``merge_trace`` already dedups nodes whose full ``sig()`` — including the
program location — matches, so the duplicates left for this pass are ops
that compute the same value *from different source lines*: the same
expression in two tape regions (GAN-style double forward), a hand-inlined
recomputation, or the same subexpression in sibling switch branches.  The
CSE key is therefore ``sig()`` minus location: (op, attrs, sources).

Two mechanisms, both CFG-shape-preserving for the Walker:

* **Dominating reuse** — a duplicate whose earliest occurrence executes on
  every path through it (its region path is a prefix of the duplicate's,
  and it comes earlier in flat program order) is merged: every consumer's
  source is rewritten to the representative, and the duplicate either
  becomes an *alias node* (it still carries fetch/Variable annotations —
  graphgen binds its outputs from the representative's values) or is
  marked dead outright.
* **Branch hoisting** — a key that appears in two or more sibling branches
  of one switch region, with every source *strictly dominating* the fork
  (variable reads and constants always qualify; node sources must come
  earlier at an enclosing level), is hoisted: a fresh node is spliced
  into the CFG just before the fork (the optimized graph only; the
  Walker never sees it) and all branch occurrences are merged into it.
  XLA cannot do this across ``lax.switch`` branch boundaries.  A
  duplicate consuming the fork node's *own* output is left alone —
  splicing after the fork would re-root the switch region and break the
  Case Select slot keying.

Hard exclusions: nodes with Input Feeding sources never merge — two feed
slots with equal avals are *different values* (per-iteration RNG keys are
the canonical example) — and rolled-loop nodes are left alone.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.core.casing import SwitchItem
from repro.core.passes.analysis import region_info
from repro.core.tracegraph import TGNode

Key = Tuple[int, int]


def _eligible(n, opt) -> bool:
    return (n.kind == "op" and n.uid not in opt.dead
            and n.uid not in opt.alias_nodes
            and not any(s[0] == "feed" for s in n.srcs))


def _cse_key(n) -> Optional[Tuple]:
    key = (n.op_name, n.attrs, n.srcs)
    try:
        hash(key)
    except TypeError:
        return None
    return key


def _dominates(rep_uid: int, dup_uid: int, info) -> bool:
    rp, dp = info.path.get(rep_uid), info.path.get(dup_uid)
    if rp is None or dp is None:
        return False
    return (dp[:len(rp)] == rp
            and info.flatpos[rep_uid] < info.flatpos[dup_uid])


def _merge(rep: TGNode, dup: TGNode, opt, rewrites: Dict[Key, Key]) -> None:
    for oi in range(len(dup.out_avals)):
        rewrites[(dup.uid, oi)] = (rep.uid, oi)
    if dup.fetch_idxs or dup.var_assigns:
        opt.alias_nodes[dup.uid] = tuple(
            (rep.uid, oi) for oi in range(len(dup.out_avals)))
    else:
        opt.dead.add(dup.uid)


def _apply_rewrites(otg, rewrites: Dict[Key, Key]) -> None:
    if not rewrites:
        return

    def R(key: Key) -> Key:          # path compression over merge rounds
        while key in rewrites:
            key = rewrites[key]
        return key

    for n in otg.nodes.values():
        if n.kind not in ("op", "loop") or not n.srcs:
            continue
        new = tuple(("node",) + R((s[1], s[2])) if s[0] == "node" else s
                    for s in n.srcs)
        if new != n.srcs:
            n.srcs = new
            n._sig_cache = None


def run(ctx) -> None:
    otg, opt = ctx.otg, ctx.opt
    info = region_info(ctx.structure)
    rewrites: Dict[Key, Key] = {}
    hits = 0

    # -- dominating reuse, to fixpoint (merges can expose new duplicates) --
    changed = True
    while changed:
        changed = False
        groups: Dict[Tuple, List[TGNode]] = {}
        for n in otg.nodes.values():
            if _eligible(n, opt):
                key = _cse_key(n)
                if key is not None:
                    groups.setdefault(key, []).append(n)
        round_rw: Dict[Key, Key] = {}
        for nodes in groups.values():
            if len(nodes) < 2:
                continue
            nodes.sort(key=lambda n: info.flatpos.get(n.uid, 1 << 30))
            rep = nodes[0]
            for dup in nodes[1:]:
                if dup.out_avals != rep.out_avals:
                    continue
                if _dominates(rep.uid, dup.uid, info):
                    _merge(rep, dup, opt, round_rw)
                    hits += 1
                    changed = True
        rewrites.update(round_rw)
        _apply_rewrites(otg, round_rw)

    # -- branch hoisting ---------------------------------------------------
    structure = ctx.structure
    fork_pos, spliced = info.flatpos, False
    for item in structure.iter_items():
        if not isinstance(item, SwitchItem):
            continue
        fuid = item.fork_uid
        groups: Dict[Tuple, List[Tuple[int, TGNode]]] = {}
        for bi, branch in enumerate(item.branches):
            for uid in structure.uids_in(branch):
                n = otg.nodes[uid]
                if not _eligible(n, opt):
                    continue
                if not all(s[0] != "node"
                           or _dominates(s[1], fuid, info)
                           for s in n.srcs):
                    continue        # a source lives inside a branch
                key = _cse_key(n)
                if key is not None:
                    groups.setdefault(key, []).append((bi, n))
        round_rw: Dict[Key, Key] = {}
        for occurrences in groups.values():
            if len({bi for bi, _ in occurrences}) < 2:
                continue            # one branch only: no cross-branch win
            first = occurrences[0][1]
            host = otg.splice_before(fuid, TGNode(
                0, "op", op_name=first.op_name, attrs=first.attrs,
                location=first.location, srcs=first.srcs,
                out_avals=first.out_avals))
            spliced = True
            for _, dup in occurrences:
                _merge(host, dup, opt, round_rw)
                hits += 1
        rewrites.update(round_rw)
        _apply_rewrites(otg, round_rw)
    if spliced:
        ctx.invalidate_structure()

    # canonicalize alias targets: a representative merged away in a later
    # round (or hoisted) must not leave aliases pointing at a dead node
    if rewrites and opt.alias_nodes:
        def R(key: Key) -> Key:
            while key in rewrites:
                key = rewrites[key]
            return key
        for uid, keys in list(opt.alias_nodes.items()):
            opt.alias_nodes[uid] = tuple(R(k) for k in keys)
    if hits:
        opt.bump("cse_hits", hits)
