"""Shared analyses for the optimization passes (DESIGN.md §10).

Two kinds of input feed the pipeline:

* **Structural** — liveness over the (cloned) TraceGraph and region/order
  maps over its Structure, computed fresh per pipeline run.
* **Observational** — per-family records accumulated across *traced*
  iterations, because two legality questions are invisible to the graph:
  did an Input Feeding slot ever change value (constant-feed folding), and
  how late does Python actually read each fetched value (segment
  coalescing)?  Both records only move in the conservative direction:
  a slot marked varying never becomes stable again, and a fetch's earliest
  observed read point only ever moves earlier.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Set, Tuple

import numpy as np

from repro.core.trace import Ref, SyncMarker, TraceEntry

Key = Tuple[int, int]

# feeds larger than this (bytes) are never considered for folding: the
# equality probe runs on the Python thread every traced iteration and the
# folded value is baked into the XLA program as a literal
MAX_FOLD_BYTES = 1 << 16


class FoldedConst:
    """A hashable baked constant standing in a rewritten ``srcs`` slot.

    Segment signatures are dict keys, so the folded value is identified by
    a digest of its bytes; ``_resolve`` unwraps ``.value`` at compile time.
    """

    __slots__ = ("value", "_key")

    def __init__(self, value):
        self.value = np.asarray(value)
        v = self.value
        self._key = (v.shape, str(v.dtype), hash(v.tobytes()))

    def equals(self, other) -> bool:
        o = np.asarray(other)
        return (o.shape == self.value.shape
                and o.dtype == self.value.dtype
                and np.array_equal(o, self.value))

    def __hash__(self):
        return hash(self._key)

    def __eq__(self, other):
        return isinstance(other, FoldedConst) and self._key == other._key

    def __repr__(self):
        return f"FoldedConst(shape={self.value.shape})"


_VARYING = object()


class FeedObservations:
    """Per-family Input Feeding stability record: (uid, arg_pos) -> either
    (value, count) while every observed value matched, or varying forever
    after the first mismatch.  ``version`` bumps exactly when a pipeline
    rerun could change its output (a slot becoming foldable at its second
    stable observation, or a fold candidate going varying)."""

    def __init__(self):
        self.slots: Dict[Key, Any] = {}
        self.version = 0

    def observe(self, key: Key, value) -> None:
        cur = self.slots.get(key)
        if cur is _VARYING:
            return
        try:
            arr = np.asarray(value)
        except Exception:
            self.slots[key] = _VARYING
            return
        if arr.nbytes > MAX_FOLD_BYTES or arr.dtype == object:
            self.slots[key] = _VARYING
            return
        if cur is None:
            self.slots[key] = (arr, 1)
            return
        prev, count = cur
        if prev.shape == arr.shape and prev.dtype == arr.dtype \
                and np.array_equal(prev, arr):
            self.slots[key] = (prev, count + 1)
            if count + 1 == 2:      # now foldable
                self.version += 1
        else:
            self.slots[key] = _VARYING
            if count >= 2:          # was foldable
                self.version += 1

    def stable_value(self, key: Key):
        """The fold candidate for ``key``: its value if every observation
        matched at least twice, else None."""
        cur = self.slots.get(key)
        if cur is None or cur is _VARYING:
            return None
        value, count = cur
        return value if count >= 2 else None


class FetchObservations:
    """Per-family Output Fetching timing record: for each fetched
    (uid, out_idx), the set of 'last validated node uids' at the moments
    Python materialized it mid-iteration.  Coalescing asks: was this value
    *ever* read before the end of the following segment?  An unobserved
    key imposes no constraint (it was only read after the iteration
    closed, which is the note_fetch non-gating path)."""

    MAX_POINTS = 8

    def __init__(self):
        self.read_after: Dict[Key, Set[Optional[int]]] = {}
        self.version = 0

    def observe(self, key: Key, last_uid: Optional[int]) -> None:
        pts = self.read_after.get(key)
        if pts is None:
            pts = self.read_after[key] = set()
        if last_uid in pts:
            return
        if len(pts) >= self.MAX_POINTS:
            # too many distinct read points: pin the most conservative
            last_uid = None         # "read immediately" sentinel
            if last_uid in pts:
                return
        pts.add(last_uid)
        self.version += 1

    def earliest_read_pos(self, key: Key, flatpos: Dict[int, int]):
        """Smallest flat program position at which ``key`` was observed
        read, or None when it was never read mid-iteration."""
        pts = self.read_after.get(key)
        if not pts:
            return None
        return min(flatpos.get(u, -1) if u is not None else -1
                   for u in pts)


def observe_iteration(trace, feed_log: Dict, tg, feed_obs: FeedObservations,
                      fetch_obs: FetchObservations) -> None:
    """Record one traced iteration into the family's observation state.
    Must run after ``merge_trace`` (uses ``tg.last_ord_to_uid``)."""
    ord_to_uid = getattr(tg, "last_ord_to_uid", None)
    if ord_to_uid is None:
        return
    last_uid: Optional[int] = None
    for ev in trace.events:
        if isinstance(ev, TraceEntry):
            u = ord_to_uid.get(getattr(ev, "_ordinal", -1))
            if u is not None:
                last_uid = u
        elif isinstance(ev, SyncMarker) and isinstance(ev.ref, Ref):
            uid = ord_to_uid.get(ev.ref.entry)
            if uid is None:
                continue
            n = tg.nodes[uid]
            if n.kind == "loop":
                oi = n.body.out_slot_for(ev.ref,
                                         getattr(n, "_last_ordinals", ()))
            else:
                oi = ev.ref.out_idx
            fetch_obs.observe((uid, oi), last_uid)
    for (ordinal, pos), value in feed_log.items():
        uid = ord_to_uid.get(ordinal)
        if uid is None or tg.nodes[uid].kind == "loop":
            continue
        feed_obs.observe((uid, pos), value)


# --------------------------------------------------------------------------
# Structural analyses
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RegionInfo:
    """Flat execution order + enclosing-region path per node uid.

    ``flatpos`` is a depth-first program position (branch interiors before
    the post-join continuation); ``path[uid]`` is the chain of
    (fork_uid, branch_idx) regions enclosing the node.  A node R executes
    on every path through node N iff path(R) is a prefix of path(N) and
    flatpos(R) < flatpos(N) — the CSE dominance test."""
    flatpos: Dict[int, int]
    path: Dict[int, Tuple[Tuple[int, int], ...]]


def region_info(structure) -> RegionInfo:
    from repro.core.casing import NodeItem, SwitchItem
    flatpos: Dict[int, int] = {}
    path: Dict[int, Tuple] = {}
    counter = [0]

    def walk(program, cur_path):
        for item in program:
            if isinstance(item, NodeItem):
                flatpos[item.uid] = counter[0]
                path[item.uid] = cur_path
                counter[0] += 1
            elif isinstance(item, SwitchItem):
                flatpos[item.fork_uid] = counter[0]
                path[item.fork_uid] = cur_path
                counter[0] += 1
                for bi, b in enumerate(item.branches):
                    walk(b, cur_path + ((item.fork_uid, bi),))
    walk(structure.program, ())
    return RegionInfo(flatpos, path)


def live_uids(otg, opt) -> Set[int]:
    """Transitive liveness over the optimized graph: roots are nodes with
    fetch annotations, variable assignments or loop variable bindings;
    liveness propagates through effective sources (alias keys for CSE'd
    nodes).  Nodes already marked dead contribute nothing."""
    roots = []
    for uid, n in otg.nodes.items():
        if n.kind not in ("op", "loop") or uid in opt.dead:
            continue
        if n.fetch_idxs or n.var_assigns or (
                n.kind == "loop" and n.body is not None and n.body.var_binds):
            roots.append(uid)
    live: Set[int] = set()
    stack = list(roots)
    while stack:
        uid = stack.pop()
        if uid in live:
            continue
        live.add(uid)
        for s in opt.eff_srcs(otg.nodes[uid]):
            if s[0] == "node" and s[1] not in live:
                stack.append(s[1])
    return live
