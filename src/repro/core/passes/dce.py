"""Dead-op elimination (pipeline stage ``dce``, DESIGN.md §10).

A node is dead when nothing observable depends on it: its outputs are
never fetch-annotated, never bound to a framework Variable (directly or
through a rolled loop's ``var_binds``), and not consumed — transitively —
by any node that is.  Dead nodes stay in the cloned graph's CFG (so fork
children orders, the Case Select mapping and the Walker's validation path
are untouched) but graphgen skips their computation entirely and the
segment IO analysis ignores their sources, so their inputs stop being
carried across segments.

Legality notes:

* fetch annotations and variable writes are liveness **roots** — the pass
  can never remove them by construction;
* a CSE alias node (cse.py) is live iff it has fetch/var annotations; its
  effective source is its representative, which liveness follows;
* liveness is computed on effective (post-CSE) sources, so a value whose
  only consumers were rewritten away dies here — the canonical
  fold→cse→dce ordering.
"""

from __future__ import annotations

from repro.core.passes.analysis import live_uids


def run(ctx) -> None:
    otg, opt = ctx.otg, ctx.opt
    live = live_uids(otg, opt)
    eliminated = 0
    for uid, n in otg.nodes.items():
        if n.kind not in ("op", "loop"):
            continue
        if uid in live or uid in opt.dead:
            continue
        opt.dead.add(uid)
        opt.alias_nodes.pop(uid, None)
        eliminated += 1
    if eliminated:
        opt.bump("nodes_eliminated", eliminated)
