"""Pallas kernel substitution (pipeline stage ``kernels``, DESIGN.md §10).

The hand-written Pallas kernels under ``src/repro/kernels/`` were only
reachable from code that calls them directly; traced imperative programs
spell the same math as chains of fine-grained ops.  This pass closes the
gap: it pattern-matches traced subgraphs on the optimized clone and
rewrites them to single fused-kernel nodes.

Patterns:

* **rms_norm** — the registered ``rms_norm`` op node is retargeted to
  ``kernel.rms_norm`` (the fused single-pass Pallas RMSNorm).  The kernel
  follows the ``(1 + g)`` weight convention, so the wrapper shifts the
  gain; outputs agree with the unfused op within f32-accumulation
  tolerance.
* **softmax attention** — ``einsum('bst,btd->bsd', softmax(scores), v)``
  where ``scores = einsum('bsd,btd->bst', q, k) * D**-0.5`` optionally
  plus a constant-evaluable additive bias.  A bias that equals the
  standard causal ``(tril - 1) * 1e9`` matches the kernel's ``causal``
  mask; an all-zero (or absent) bias matches full attention.  The whole
  chain is rewritten in place of its final node, so consumers and fetch
  annotations are untouched; the intermediates must have no consumers
  outside the pattern (in particular no ``.vjp`` tape consumers — a
  differentiated attention keeps its unfused form) and fall to DCE.

The pass only runs when requested: ``optimize="all"`` enables it on TPU
backends where the kernels compile natively; elsewhere it must be named
explicitly (interpret-mode Pallas validates numerics but is not fast).
"""

from __future__ import annotations

from typing import Dict, Optional, Set, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import ops as ops_mod
from repro.core.passes.analysis import FoldedConst

Key = Tuple[int, int]

SCALE_RTOL = 1e-3
_CONST_EVAL_MAX = 32        # nodes per bias-chain evaluation


# --------------------------------------------------------------------------
# Fused-kernel op registry entries (impl-level: graphgen executes these)
# --------------------------------------------------------------------------

def _krms_impl(x, g, *, eps=1e-6):
    from repro.kernels import ops as kops
    return kops.rmsnorm(x, jnp.asarray(g) - 1.0, eps=float(eps))


def _kattn_impl(q, k, v, *, causal=True):
    from repro.kernels import ops as kops
    out = kops.flash_attention(q[:, None], k[:, None], v[:, None],
                               causal=bool(causal))
    return out[:, 0]


def _kpaged_decode_impl(*leaves, **attrs):
    from repro.serve.scheduler import pool_ops
    return pool_ops._slot_decode_kernel_impl(*leaves, **attrs)


if "kernel.rms_norm" not in ops_mod.OPS:
    ops_mod.def_op("kernel.rms_norm", _krms_impl)
    ops_mod.def_op("kernel.attention", _kattn_impl)
    ops_mod.def_op("kernel.slot_decode_paged", _kpaged_decode_impl)
    ops_mod._NONDIFF_OPS.update({"kernel.rms_norm", "kernel.attention",
                                 "kernel.slot_decode_paged"})


def _paged_decode_meta(n) -> bool:
    """True when a ``serve.slot_decode`` node steps a paged pool — the
    only decode class the paged-attention kernel applies to."""
    try:
        from repro.serve.scheduler import pool_ops
        return pool_ops.pool_meta(dict(n.attrs)["_meta"]).page_size > 0
    except Exception:
        return False


# --------------------------------------------------------------------------
# Matching helpers
# --------------------------------------------------------------------------

def _producer(otg, opt, src):
    if src[0] != "node":
        return None
    n = otg.nodes[src[1]]
    if n.kind != "op" or n.uid in opt.dead or n.uid in opt.alias_nodes:
        return None
    return n if src[2] == 0 else None


def _const_of(src):
    if src[0] != "const":
        return None
    v = src[1]
    return v.value if isinstance(v, FoldedConst) else v


def _const_eval(otg, src, memo: Dict, visited: Set[int]):
    """Evaluate a source whose transitive leaves are all constants, or
    return None.  ``visited`` collects the chain's node uids."""
    c = _const_of(src)
    if c is not None:
        return np.asarray(c)
    if src[0] != "node":
        return None
    key = (src[1], src[2])
    if key in memo:
        return memo[key]
    if len(visited) > _CONST_EVAL_MAX:
        return None
    n = otg.nodes[src[1]]
    if n.kind != "op":
        return None
    vals = []
    for s in n.srcs:
        v = _const_eval(otg, s, memo, visited)
        if v is None:
            return None
        vals.append(v)
    visited.add(n.uid)
    out = ops_mod.OPS[n.op_name].impl(*vals, **dict(n.attrs))
    outs = out if isinstance(out, tuple) else (out,)
    for oi, v in enumerate(outs):
        memo[(n.uid, oi)] = np.asarray(v)
    return memo.get(key)


def _consumers(otg, opt) -> Dict[Key, Set[int]]:
    cons: Dict[Key, Set[int]] = {}
    for uid, n in otg.nodes.items():
        if n.kind not in ("op", "loop"):
            continue
        for s in opt.eff_srcs(n):
            if s[0] == "node":
                cons.setdefault((s[1], s[2]), set()).add(uid)
    return cons


def _only_consumed_by(cons, node, allowed: Set[int]) -> bool:
    if node.fetch_idxs or node.var_assigns:
        return False
    for oi in range(len(node.out_avals)):
        if cons.get((node.uid, oi), set()) - allowed:
            return False
    return True


def _match_attention(otg, opt, cons, final) -> Optional[Tuple]:
    """final: einsum('bst,btd->bsd', <softmax>, v).  Returns
    (q_src, k_src, v_src, causal, interior_uids) or None."""
    sm = _producer(otg, opt, final.srcs[0])
    if sm is None or sm.op_name != "softmax":
        return None
    if dict(sm.attrs).get("axis", -1) != -1:
        return None
    scores = _producer(otg, opt, sm.srcs[0])
    if scores is None:
        return None
    bias = None
    if scores.op_name == "add":
        scaled = _producer(otg, opt, scores.srcs[0])
        bias_src = scores.srcs[1]
        if scaled is None or scaled.op_name != "mul":
            scaled = _producer(otg, opt, scores.srcs[1])
            bias_src = scores.srcs[0]
        if scaled is None or scaled.op_name != "mul":
            return None
        bias = _const_eval(otg, bias_src, {}, set())
        if bias is None:
            return None
        add_node = scores
    elif scores.op_name == "mul":
        scaled, add_node = scores, None
    else:
        return None
    scale, e_src = _const_of(scaled.srcs[1]), scaled.srcs[0]
    if scale is None:
        scale, e_src = _const_of(scaled.srcs[0]), scaled.srcs[1]
    if scale is None or np.ndim(scale) != 0:
        return None
    e = _producer(otg, opt, e_src)
    if e is None or e.op_name != "einsum" \
            or dict(e.attrs).get("expr") != "bsd,btd->bst":
        return None
    q_src, k_src = e.srcs
    v_src = final.srcs[1]
    q_aval = _src_aval(otg, opt, q_src)
    if q_aval is None or len(q_aval.shape) != 3:
        return None
    d = q_aval.shape[-1]
    if not np.isclose(float(scale), d ** -0.5, rtol=SCALE_RTOL):
        return None
    if bias is not None:
        if bias.ndim != 2:
            return None
        causal_bias = (np.tril(np.ones(bias.shape, np.float32)) - 1.0) * 1e9
        if np.allclose(bias, causal_bias, atol=1.0):
            causal = True
        elif np.allclose(bias, 0.0, atol=1e-6):
            causal = False
        else:
            return None
    else:
        causal = False
    interior = {e.uid, scaled.uid, sm.uid}
    if add_node is not None:
        interior.add(add_node.uid)
    allowed = interior | {final.uid}
    for uid in interior:
        if not _only_consumed_by(cons, otg.nodes[uid], allowed):
            return None
    return q_src, k_src, v_src, causal, interior


def _src_aval(otg, opt, src):
    if src[0] == "node":
        n = otg.nodes[src[1]]
        if n.kind != "op":
            return None
        return n.out_avals[src[2]]
    if src[0] == "feed":
        return src[1]
    if src[0] == "var":
        return opt_var_aval(opt, src[1])
    return None


def opt_var_aval(opt, var_id):
    return getattr(opt, "_var_avals", {}).get(var_id)


def run(ctx) -> None:
    otg, opt = ctx.otg, ctx.opt
    opt._var_avals = ctx.var_avals or {}
    cons = _consumers(otg, opt)
    substituted = 0
    for uid in list(otg.nodes):
        n = otg.nodes[uid]
        if n.kind != "op" or uid in opt.dead or uid in opt.alias_nodes:
            continue
        if n.op_name == "serve.slot_decode" and _paged_decode_meta(n):
            # same leaves, same attrs, same outputs — only the attention
            # inner loop changes (Pallas kernel vs gather + dense softmax)
            n.op_name = "kernel.slot_decode_paged"
            n._sig_cache = None
            substituted += 1
        elif n.op_name == "rms_norm":
            g_aval = _src_aval(otg, opt, n.srcs[1]) if len(n.srcs) > 1 else None
            x_aval = _src_aval(otg, opt, n.srcs[0]) if n.srcs else None
            if (g_aval is None or x_aval is None
                    or len(g_aval.shape) != 1
                    or g_aval.shape[0] != x_aval.shape[-1]):
                continue
            n.op_name = "kernel.rms_norm"
            n._sig_cache = None
            substituted += 1
        elif (n.op_name == "einsum"
                and dict(n.attrs).get("expr") == "bst,btd->bsd"):
            m = _match_attention(otg, opt, cons, n)
            if m is None:
                continue
            q_src, k_src, v_src, causal, interior = m
            e_uid = next(u for u in interior
                         if otg.nodes[u].op_name == "einsum")
            old_slots = {0: (e_uid, 0), 1: (e_uid, 1), 2: (uid, 1)}
            n.op_name = "kernel.attention"
            n.attrs = (("causal", causal),)
            n.srcs = (q_src, k_src, v_src)
            for pos, src in enumerate(n.srcs):
                if src[0] == "feed":
                    opt.feed_moved[(uid, pos)] = old_slots[pos]
            n._sig_cache = None
            substituted += 1
            cons = _consumers(otg, opt)   # srcs changed: rebuild
    if substituted:
        opt.bump("kernels_substituted", substituted)
