"""Constant-feed folding (pipeline stage ``fold``, DESIGN.md §10).

An Input Feeding slot whose fed Python value was byte-identical across at
least two traced iterations of the covered streak (FeedObservations) is
demoted to a baked constant: the node's ``('feed', aval)`` source is
rewritten to ``('const', FoldedConst(value))``, the slot disappears from
the segment's Input Feeding layout, and XLA constant-folds whatever
depends on it (e.g. a causal-mask bias recomputed from the same numpy
array every step).

Safety — the demotion must be reversible, because "was constant so far"
is not "is constant":

* the walker keeps a per-slot probe (``GraphProgram.folded_feeds``): when
  the skeleton collects a value for a folded slot it compares against the
  baked constant and raises DivergenceError on mismatch, which cancels
  the iteration and re-enters tracing;
* the mismatching observation marks the slot varying (monotone) and bumps
  the observation version, so the next GraphProgram regeneration restores
  the feed — the slot folds at most once per value regime;
* slots above ``MAX_FOLD_BYTES`` or with non-array values never fold
  (the equality probe runs every iteration on the Python thread);
* per-iteration RNG key feeds vary by construction and therefore never
  qualify.
"""

from __future__ import annotations

from repro.core.passes.analysis import FoldedConst


def run(ctx) -> None:
    otg, opt, obs = ctx.otg, ctx.opt, ctx.feed_obs
    folded = 0
    for uid, n in otg.nodes.items():
        if n.kind != "op" or uid in opt.dead:
            continue
        if not any(s[0] == "feed" for s in n.srcs):
            continue
        new_srcs = list(n.srcs)
        changed = False
        for pos, s in enumerate(n.srcs):
            if s[0] != "feed":
                continue
            value = obs.stable_value((uid, pos))
            if value is None:
                continue
            fc = FoldedConst(value)
            new_srcs[pos] = ("const", fc)
            opt.folded[(uid, pos)] = fc
            folded += 1
            changed = True
        if changed:
            n.srcs = tuple(new_srcs)
            n._sig_cache = None
    if folded:
        opt.bump("feeds_folded", folded)
