"""Segment coalescing (pipeline stage ``coalesce``, DESIGN.md §10).

Every gating fetch cuts a segment (DESIGN.md §2) so Python can obtain the
value without waiting for downstream graph work — but the cut is only
*useful* when Python actually blocks on the value before the downstream
work is dispatched.  A program that fetches for logging or metrics and
reads the values late (or only after the iteration closes) pays one
dispatch per boundary for nothing.

The pass removes a boundary when the fetch-timing observations
(analysis.FetchObservations, recorded across traced iterations) prove the
late-read pattern: every fetch key of the segments merged so far was only
ever materialized at-or-after the node that ends the *following* segment.
Under that condition the merged segment has already been dispatched by
the time Python asks, so the read hits a completed future exactly as
before — with strictly fewer dispatches per iteration.  If steady-state
Python ever reads earlier than the traces promised, the read falls back
to path-specialized chain dispatch (dispatch.py): slower, never wrong.

Merging into the trailing region (no later gating node) requires the keys
to have *no* observed mid-iteration read at all, since the final segment
only dispatches at iteration end.  The always-empty trailing segment the
segmenter appends after a program-final boundary is dropped
unconditionally — it computes nothing and fetches nothing.

Values crossing a removed boundary become segment-internal dataflow
instead of explicit carries; variable reads keep their meaning because a
``VarRef`` read can only precede the first write of that variable on any
validated path (trace.py), so no read inside the merged region can
observe an intra-region write.
"""

from __future__ import annotations

from repro.core.casing import NodeItem
from repro.core.passes.analysis import region_info


def run(ctx) -> None:
    otg, opt, obs = ctx.otg, ctx.opt, ctx.fetch_obs
    structure = ctx.structure
    info = region_info(structure)
    segments = structure.segments
    if segments and not segments[-1]:
        opt.drop_empty_trailing = True
        segments = segments[:-1]
    if len(segments) < 2:
        if opt.drop_empty_trailing:
            opt.bump("segments_coalesced")
            ctx.invalidate_structure()
        return

    def seg_fetch_keys(seg):
        keys = []
        for uid in structure.uids_in(seg):
            n = otg.nodes[uid]
            if uid in opt.dead:
                continue
            for oi in sorted(n.fetch_idxs):
                keys.append((uid, oi))
        return keys

    def end_uid(seg):
        for item in reversed(seg):
            if isinstance(item, NodeItem):
                return item.uid
        return None

    coalesced = 0
    group_keys = seg_fetch_keys(segments[0])
    for si in range(len(segments) - 1):
        nxt = segments[si + 1]
        boundary = end_uid(segments[si])
        e = end_uid(nxt)
        # the merged group would dispatch at the following segment's own
        # gating node; a following segment WITHOUT one (the true trailing
        # region) only dispatches at iteration end, so merging into it
        # requires the keys to have no mid-iteration read at all
        gated_end = e is not None and otg.nodes[e].sync_after
        ok = boundary is not None
        for key in group_keys:
            pos = obs.earliest_read_pos(key, info.flatpos)
            if pos is None:
                continue            # never read mid-iteration
            if not gated_end or pos < info.flatpos.get(e, -1):
                ok = False
                break
        if ok:
            otg.nodes[boundary].sync_after = False
            coalesced += 1
            group_keys += seg_fetch_keys(nxt)
        else:
            group_keys = seg_fetch_keys(nxt)
    if coalesced or opt.drop_empty_trailing:
        opt.bump("segments_coalesced",
                 coalesced + (1 if opt.drop_empty_trailing else 0))
        ctx.invalidate_structure()
