"""Typed event taxonomy for the structured observability layer.

Every instrumented moment of the co-execution lifecycle is one of the
dataclasses below (DESIGN.md §13): iteration open/close, segment dispatch
and GraphRunner completion, walker validation outcomes, the divergence →
rollback → replay chain (causally linked by ``iter_id``), steady-state
entry/exit/probe/poison, pass-pipeline runs, and the serving request
lifecycle (submit → admit → prefill → per-token → retire, keyed by
``rid``).

Events are cheap plain dataclasses constructed **only** when a structured
processor is attached to the stream (``EventStream.on``); the counters-only
path never builds one.  ``ts`` is stamped by the stream's injected clock at
emit time, so all timestamps in one stream share one clock and are monotone
per emitting thread.  The ``EVENT_TYPES`` registry is the JSONL schema:
``schema.py`` round-trips events through it and rejects unknown types or
field sets.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

EVENT_TYPES: Dict[str, type] = {}


def _event(cls):
    cls = dataclasses.dataclass(cls)
    EVENT_TYPES[cls.__name__] = cls
    return cls


class Event:
    """Base class; ``ts`` is stamped by :meth:`EventStream.emit`."""
    ts: Optional[float] = None


# --------------------------------------------------------------------------
# engine iteration lifecycle
# --------------------------------------------------------------------------

@_event
class IterationStart(Event):
    iter_id: int
    mode: str                       # "tracing" | "skeleton"
    family: str                     # short digest of the family key


@_event
class IterationEnd(Event):
    iter_id: int
    mode: str
    traced: bool                    # ended through the tracing path
    ops_validated: int = 0          # walker outcome (skeleton iterations)
    fast_hits: int = 0              # ... of which via the stamp fast path


@_event
class Transition(Event):
    """Phase transition into co-execution (tracing -> skeleton)."""
    iter_id: int


@_event
class FamilySwitch(Event):
    """Shape-class change at iteration start (DESIGN.md §8)."""
    family: str
    created: bool                   # True: new class (will trace)


# --------------------------------------------------------------------------
# segment dispatch / runner completion
# --------------------------------------------------------------------------

@_event
class SegmentDispatch(Event):
    iter_id: int
    kind: str                       # "segment" | "chain" | "steady"
    index: int                      # segment index (-1 for chains)
    seq: int                        # GraphRunner submit sequence
    feeds: int = 0                  # Input Feeding values shipped


@_event
class RunnerComplete(Event):
    """One GraphRunner closure finished (emitted from the runner thread);
    joins to :class:`SegmentDispatch` on ``seq``."""
    seq: int
    wall: float                     # closure execution wall time
    stall: float                    # queue-empty time before it started


@_event
class SegmentProfile(Event):
    """Sampled device-time attribution for one dispatched segment
    (DESIGN.md §15): on a profiling iteration the GraphRunner thread
    blocks on the segment's outputs and stamps host dispatch time and
    dispatch-to-device-done wall separately.  Joins to
    :class:`SegmentDispatch` on ``(iter_id, kind, index)``; ``kernels``
    lists the Pallas-substituted ops baked into the segment (pass
    metadata carried through the DispatchPlan)."""
    iter_id: int
    kind: str                       # "segment" | "chain" | "steady"
    index: int
    dispatch: float                 # host time in the dispatch call
    device: float                   # dispatch start -> outputs ready
    kernels: Tuple[str, ...] = ()


# --------------------------------------------------------------------------
# divergence -> rollback -> replay/retrace (causally linked by iter_id)
# --------------------------------------------------------------------------

@_event
class Divergence(Event):
    iter_id: int
    reason: str


@_event
class Rollback(Event):
    """Pending symbolic work cancelled + variable store restored to the
    iteration-start snapshot."""
    iter_id: int
    vars_restored: int = 0


@_event
class Replay(Event):
    """Validated prefix replayed eagerly (the divergence recovery); the
    iteration then finishes imperatively and re-enters tracing."""
    iter_id: int
    entries: int = 0


@_event
class Retrace(Event):
    """Re-entered tracing without a replay (an aborted iteration)."""
    iter_id: int
    reason: str = ""


# --------------------------------------------------------------------------
# zero-walker steady state (DESIGN.md §12)
# --------------------------------------------------------------------------

@_event
class SteadyEnter(Event):
    iter_id: int
    family: str = ""


@_event
class SteadyExit(Event):
    iter_id: int
    reason: str = ""


@_event
class SteadyProbe(Event):
    """A forced walker validation iteration (every steady_probe-th call)."""
    iter_id: int


@_event
class SteadyPoison(Event):
    """Python observed device state inside an open skeleton iteration;
    the current streak cannot enter (or stay in) steady state."""
    iter_id: int


# --------------------------------------------------------------------------
# symbolic optimization pass pipeline (DESIGN.md §10)
# --------------------------------------------------------------------------

@_event
class PassPipelineRun(Event):
    iter_id: int
    family: str
    pipeline: Tuple[str, ...]
    deltas: Any                     # {pass name: {counter: delta}}


# --------------------------------------------------------------------------
# serving request lifecycle + scheduler steps (DESIGN.md §11/§13)
# --------------------------------------------------------------------------

@_event
class RequestSubmit(Event):
    rid: int
    prompt_len: int
    max_new: int


@_event
class RequestAdmit(Event):
    rid: int
    slot: int
    queued_s: float = 0.0           # arrival -> admission wait


@_event
class RequestPrefill(Event):
    rid: int
    bucket: int                     # padded prompt length
    prompt_len: int


@_event
class RequestToken(Event):
    rid: int
    token: int
    index: int                      # position in the request's output


@_event
class RequestRetire(Event):
    rid: int
    reason: str                     # "eos" | "budget"
    tokens: int


@_event
class ForkObserved(Event):
    """A control-flow fork's case selection observed during skeleton
    validation (groundwork for JANUS-style speculation): per-family
    selector distributions accumulate on the TraceFamily and each
    observation is emitted for offline analysis."""
    family: str                     # short digest of the family key
    fork: int                       # fork node uid in the TraceGraph
    case: int                       # matched case index


@_event
class StepDispatch(Event):
    """One scheduler step dispatched (decode or prefill)."""
    kind: str                       # "decode" | "prefill"
    rows: int
    dur: float                      # host time spent dispatching
    queue_depth: int = 0            # arrivals waiting for a slot
    resident: int = 0               # KV tokens resident in the pool


@_event
class StepHarvest(Event):
    """The lagged harvest of a step's token frame."""
    kind: str
    wait: float                     # host time blocked on the fetch


@_event
class SchedulerIdle(Event):
    wait: float                     # seconds until the next known arrival


# -- persistence (core/persist/, DESIGN.md §14) ------------------------------

@_event
class ArtifactHit(Event):
    """A warm boot loaded an artifact instead of tracing/compiling."""
    kind: str                       # "family" | "segment"
    key: str                        # store-relative artifact path


@_event
class ArtifactMiss(Event):
    kind: str
    key: str
    reason: str = ""                # "absent" | "corrupt" | ...


@_event
class ArtifactStore(Event):
    """An artifact was written to the persistent store."""
    kind: str
    key: str
    nbytes: int = 0


@_event
class CheckpointSave(Event):
    path: str
    vars_saved: int = 0
    requests: int = 0               # scheduler checkpoints: live requests


@_event
class CheckpointRestore(Event):
    path: str
    vars_restored: int = 0
    requests: int = 0
