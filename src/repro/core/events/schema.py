"""Event (de)serialization + JSONL trace validation.

The wire format is one flat JSON object per event: ``type`` (the class
name in ``EVENT_TYPES``), ``ts`` (the stream clock stamp), and the
dataclass fields.  ``from_dict`` is strict — an unknown type, a missing
field or an unexpected field is a schema violation — so the CI step that
validates the bench's exported ``trace.jsonl`` actually proves the
artifact parses back into the typed event set (DESIGN.md §13)."""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

from repro.core.events.types import EVENT_TYPES


def _plain(v):
    # most event fields are already JSON-native: test those first so the
    # per-event serialization cost (the ≤2 % tracing-overhead budget)
    # stays a few isinstance checks, not reflection
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_plain(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _plain(x) for k, x in v.items()}
    if hasattr(v, "item") and not isinstance(v, bytes):
        try:
            return v.item()         # numpy scalar -> Python scalar
        except Exception:
            return repr(v)
    return repr(v)


# field-name tuples cached per event class: dataclasses.fields() is
# reflection-heavy and event_to_dict runs once per event on traced runs
_FIELDS: Dict[type, tuple] = {}


def _field_names(cls) -> tuple:
    names = _FIELDS.get(cls)
    if names is None:
        names = tuple(f.name for f in dataclasses.fields(cls))
        _FIELDS[cls] = names
    return names


def event_to_dict(event) -> Dict[str, Any]:
    d = {"type": type(event).__name__, "ts": event.ts}
    for name in _field_names(type(event)):
        d[name] = _plain(getattr(event, name))
    return d


def dict_to_event(d: Dict[str, Any]):
    """Strict inverse of :func:`event_to_dict`; raises ValueError on any
    schema violation."""
    d = dict(d)
    name = d.pop("type", None)
    cls = EVENT_TYPES.get(name)
    if cls is None:
        raise ValueError(f"unknown event type {name!r}")
    ts = d.pop("ts", None)
    names = {f.name for f in dataclasses.fields(cls)}
    required = {f.name for f in dataclasses.fields(cls)
                if f.default is dataclasses.MISSING
                and f.default_factory is dataclasses.MISSING}
    extra, missing = set(d) - names, required - set(d)
    if extra or missing:
        raise ValueError(f"{name}: extra fields {sorted(extra)}, "
                         f"missing fields {sorted(missing)}")
    ev = cls(**d)
    ev.ts = ts
    return ev


def load_jsonl(path: str) -> List[Any]:
    """Parse a JSONL trace back into typed events, validating every line."""
    out = []
    with open(path) as f:
        for i, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(dict_to_event(json.loads(line)))
            except ValueError as e:
                raise ValueError(f"{path}:{i}: {e}") from None
    return out


def validate_jsonl(path: str) -> Dict[str, int]:
    """Validate a trace file; returns per-type event counts (the CI
    schema-check step prints these)."""
    counts: Dict[str, int] = {}
    for ev in load_jsonl(path):
        name = type(ev).__name__
        counts[name] = counts.get(name, 0) + 1
    return counts
