"""Structured observability layer: typed events, one stream, pluggable
processors (DESIGN.md §13).

    types.py       — the event taxonomy + EVENT_TYPES registry
    stream.py      — EventStream: counter fast path, clock, processors
    processors.py  — Counters / Timing / RequestTrace / Jsonl / List
    schema.py      — JSONL (de)serialization + trace validation
    emit.py        — allocation-light emit helpers for the executor

The engine owns one EventStream for its lifetime (``engine.events``);
``engine.stats`` is the stream's counter dict.  The serving scheduler
shares its engine's stream (one substrate, one clock) and benchmarks
attach processors to derive their breakdowns instead of keeping private
accumulators.
"""

from repro.core.events import types
from repro.core.events.processors import (CountersProcessor, JsonlSink,
                                          ListProcessor, Processor,
                                          RequestTraceProcessor,
                                          TimingProcessor)
from repro.core.events.schema import (dict_to_event, event_to_dict,
                                      load_jsonl, validate_jsonl)
from repro.core.events.stream import EventStream

__all__ = [
    "types", "EventStream", "Processor", "CountersProcessor",
    "TimingProcessor", "RequestTraceProcessor", "JsonlSink",
    "ListProcessor", "event_to_dict", "dict_to_event", "load_jsonl",
    "validate_jsonl",
]
