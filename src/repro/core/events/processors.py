"""Pluggable event processors (DESIGN.md §13).

A processor is anything with ``process(event)`` / ``close()``.  Processors
compose: attach any number to one :class:`EventStream`; each sees every
structured event in emission order (emission is serialized by the stream).
The contract is deliberately small so drivers and benchmarks can bring
their own — the four below cover the repo's needs:

* :class:`CountersProcessor` — the always-on flat counter dict; the
  stream's ``inc``/``add`` fast path writes into it directly, so its
  ``data`` dict reproduces the pre-event-layer ``engine.stats`` /
  scheduler counters bit for bit.
* :class:`TimingProcessor` — per-step and per-segment host-time breakdown
  (dispatch / fetch-wait / runner occupancy), replacing the benchmarks'
  private accumulators.
* :class:`RequestTraceProcessor` — one JSON-serializable causal trace per
  serving request (submit → admit → prefill → tokens → retire).
* :class:`JsonlSink` — buffered JSONL export of the full stream; the
  artifact the schema validator (schema.py) checks in CI.
* :class:`ListProcessor` — in-memory capture, for tests and ad-hoc
  debugging.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List, Optional

from repro.core.events import types as T


class Processor:
    """Structured-event consumer contract."""

    def process(self, event) -> None:      # pragma: no cover - interface
        raise NotImplementedError

    def close(self) -> None:
        pass


class CountersProcessor(Processor):
    """Owns the flat counter dict the stream's fast path writes into.

    It deliberately ignores structured events: counters are updated
    through ``EventStream.inc``/``add``/``put`` so the disabled-tracing
    path stays one dict op — this class exists to make "counters" a
    processor like any other (the dict can be seeded, snapshotted and
    swapped) without taxing the hot path."""

    def __init__(self, data: Optional[Dict] = None):
        self.data: Dict = {} if data is None else data

    def process(self, event) -> None:
        pass

    def snapshot(self) -> Dict:
        return dict(self.data)


class ListProcessor(Processor):
    """Append every event to ``events`` (tests, ad-hoc inspection)."""

    def __init__(self):
        self.events: List[Any] = []

    def process(self, event) -> None:
        self.events.append(event)

    def of_type(self, *types) -> List[Any]:
        return [e for e in self.events if isinstance(e, types)]


class TimingProcessor(Processor):
    """Host-overhead breakdown from StepDispatch / StepHarvest /
    SegmentDispatch / RunnerComplete events.

    ``summary()`` yields the numbers bench_serving reports per arm:
    total dispatch and fetch-wait seconds (split by step kind), step
    counts, per-step microseconds, and GraphRunner occupancy (exec /
    stall) over the window since construction or the last ``reset()``."""

    def __init__(self):
        # type-keyed dispatch: events this processor ignores (tokens,
        # lifecycle) cost one dict lookup, not an isinstance chain
        self._handlers = {T.StepDispatch: self._step,
                          T.StepHarvest: self._harvest,
                          T.SegmentDispatch: self._segment,
                          T.RunnerComplete: self._runner,
                          T.SchedulerIdle: self._idle}
        self.reset()

    def reset(self) -> None:
        self.dispatch_s: Dict[str, float] = {}
        self.harvest_s: Dict[str, float] = {}
        self.steps: Dict[str, int] = {}
        self.segments = 0
        self.runner_exec_s = 0.0
        self.runner_stall_s = 0.0
        self.idle_waits = 0

    def process(self, event) -> None:
        h = self._handlers.get(type(event))
        if h is not None:
            h(event)

    def _step(self, e) -> None:
        self.dispatch_s[e.kind] = self.dispatch_s.get(e.kind, 0.0) + e.dur
        self.steps[e.kind] = self.steps.get(e.kind, 0) + 1

    def _harvest(self, e) -> None:
        self.harvest_s[e.kind] = self.harvest_s.get(e.kind, 0.0) + e.wait

    def _segment(self, e) -> None:
        self.segments += 1

    def _runner(self, e) -> None:
        self.runner_exec_s += e.wall
        self.runner_stall_s += e.stall

    def _idle(self, e) -> None:
        self.idle_waits += 1

    def summary(self) -> Dict[str, Any]:
        dispatch = sum(self.dispatch_s.values())
        fetch = sum(self.harvest_s.values())
        steps = max(1, sum(self.steps.values()))
        return {
            "dispatch_s": dispatch, "fetch_wait_s": fetch,
            "dispatch_by_kind_ms":
                {k: round(v * 1e3, 3) for k, v in self.dispatch_s.items()},
            "fetch_wait_by_kind_ms":
                {k: round(v * 1e3, 3) for k, v in self.harvest_s.items()},
            "steps": dict(self.steps), "segments": self.segments,
            "dispatch_us_per_step": round(dispatch / steps * 1e6, 1),
            "fetch_wait_us_per_step": round(fetch / steps * 1e6, 1),
            "runner_exec_ms": round(self.runner_exec_s * 1e3, 3),
            "runner_stall_ms": round(self.runner_stall_s * 1e3, 3),
            "idle_waits": self.idle_waits,
        }


class RequestTraceProcessor(Processor):
    """One causal trace per serving request, keyed by ``rid``.

    A trace is the ordered list of this request's lifecycle events
    (submit → admit → prefill → token* → retire); ``trace()``/``pop()``
    return them as JSON-serializable records with the stream clock's
    timestamps.  Events buffer as-is and serialize only on access — an
    emitted event is never mutated afterwards, and per-token dict
    building would otherwise dominate the tracing cost the bench gates.
    Retired traces stay available until ``pop()``/``reset()`` so a
    driver can export and drop them incrementally."""

    def __init__(self):
        self.traces: Dict[int, List[Any]] = {}

    def process(self, event) -> None:
        rid = getattr(event, "rid", None)
        if rid is not None:
            self.traces.setdefault(rid, []).append(event)

    def trace(self, rid: int) -> List[Dict[str, Any]]:
        from repro.core.events.schema import event_to_dict  # no cycle
        return [event_to_dict(e) for e in self.traces.get(rid, [])]

    def pop(self, rid: int) -> List[Dict[str, Any]]:
        out = self.trace(rid)
        self.traces.pop(rid, None)
        return out

    def reset(self) -> None:
        self.traces = {}


class JsonlSink(Processor):
    """Buffered JSONL export: one ``{"type": ..., "ts": ..., ...}`` object
    per line, in emission order.  The per-event cost is ONE list append —
    an emitted event is never mutated afterwards, so serialization
    (event_to_dict + json.dumps) safely defers to ``flush``/``close``;
    this is the path the bench's ≤2 % tracing-overhead gate measures."""

    def __init__(self, path: str):
        self.path = path
        self._events: List[Any] = []

    def process(self, event) -> None:
        self._events.append(event)

    def flush(self) -> None:
        from repro.core.events.schema import event_to_dict
        if self._events:
            with open(self.path, "a") as f:
                f.write("\n".join(json.dumps(event_to_dict(e))
                                  for e in self._events) + "\n")
            self._events = []

    def close(self) -> None:
        self.flush()
