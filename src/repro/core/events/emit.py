"""Thin emit helpers for the executor's instrumentation sites.

Each helper folds the hot-path discipline in: it checks ``es.on`` first
and constructs the event object only when a structured processor is
attached — so an instrumented site is exactly one function call on the
counters-only path (DESIGN.md §13).  Serving-side emission lives in
serve/scheduler/telemetry.py against the same stream.
"""

from __future__ import annotations

import zlib

from repro.core.events import types as T


def fam_digest(key) -> str:
    """Short, process-stable digest of a family key for event payloads
    (full keys embed shape tuples; events only need a join key)."""
    return format(zlib.crc32(repr(key).encode()), "08x")


def iteration_start(es, iter_id, mode, key) -> None:
    if es.on:
        es.emit(T.IterationStart(iter_id, mode, fam_digest(key)))


def iteration_end(es, iter_id, mode, traced, ops=0, fast=0) -> None:
    if es.on:
        es.emit(T.IterationEnd(iter_id, mode, traced, ops, fast))


def transition(es, iter_id) -> None:
    if es.on:
        es.emit(T.Transition(iter_id))


def family_switch(es, key, created) -> None:
    if es.on:
        es.emit(T.FamilySwitch(fam_digest(key), created))


def segment_dispatch(es, iter_id, kind, index, seq, feeds=0) -> None:
    if es.on:
        es.emit(T.SegmentDispatch(iter_id, kind, index, seq, feeds))


def runner_complete(es, seq, wall, stall) -> None:
    if es.on:
        es.emit(T.RunnerComplete(seq, wall, stall))


def segment_profile(es, iter_id, kind, index, dispatch, device,
                    kernels=()) -> None:
    if es.on:
        es.emit(T.SegmentProfile(iter_id, kind, index, dispatch, device,
                                 tuple(kernels)))


def fork_observed(es, key, fork, case) -> None:
    if es.on:
        es.emit(T.ForkObserved(fam_digest(key), fork, case))


def divergence(es, iter_id, reason) -> None:
    if es.on:
        es.emit(T.Divergence(iter_id, str(reason)))


def rollback(es, iter_id, vars_restored=0) -> None:
    if es.on:
        es.emit(T.Rollback(iter_id, vars_restored))


def replay(es, iter_id, entries=0) -> None:
    if es.on:
        es.emit(T.Replay(iter_id, entries))


def retrace(es, iter_id, reason="") -> None:
    if es.on:
        es.emit(T.Retrace(iter_id, reason))


def steady_enter(es, iter_id, key) -> None:
    if es.on:
        es.emit(T.SteadyEnter(iter_id, fam_digest(key)))


def steady_exit(es, iter_id, reason) -> None:
    if es.on:
        es.emit(T.SteadyExit(iter_id, reason))


def steady_probe(es, iter_id) -> None:
    if es.on:
        es.emit(T.SteadyProbe(iter_id))


def steady_poison(es, iter_id) -> None:
    if es.on:
        es.emit(T.SteadyPoison(iter_id))


def pass_run(es, iter_id, key, pipeline, deltas) -> None:
    if es.on:
        es.emit(T.PassPipelineRun(iter_id, fam_digest(key),
                                  tuple(pipeline), deltas))


def artifact_hit(es, kind, key) -> None:
    if es.on:
        es.emit(T.ArtifactHit(kind, str(key)))


def artifact_miss(es, kind, key, reason="") -> None:
    if es.on:
        es.emit(T.ArtifactMiss(kind, str(key), reason))


def artifact_store(es, kind, key, nbytes=0) -> None:
    if es.on:
        es.emit(T.ArtifactStore(kind, str(key), nbytes))


def checkpoint_save(es, path, vars_saved=0, requests=0) -> None:
    if es.on:
        es.emit(T.CheckpointSave(str(path), vars_saved, requests))


def checkpoint_restore(es, path, vars_restored=0, requests=0) -> None:
    if es.on:
        es.emit(T.CheckpointRestore(str(path), vars_restored, requests))
