"""EventStream: the one instrumentation substrate (DESIGN.md §13).

Every counter bump and every structured lifecycle event in the engine,
executor, scheduler and benchmarks flows through one of these.  The design
constraint is the decode hot path: with no structured processor attached
the stream must cost no more than the ad-hoc ``stats[...] +=`` dicts it
replaced, so the API splits into two tiers:

* **counters** — ``inc`` / ``add`` / ``put`` update the stream's counter
  dict directly (one method call, one dict op, no allocation).  The dict
  is owned by the always-attached :class:`CountersProcessor` and *is* the
  ``engine.stats`` object — bit-compatible with the pre-event-layer
  counters by construction.
* **structured events** — guarded by the ``on`` flag at every emit site
  (``if es.on: es.emit(Evt(...))`` or an ``emit.py`` helper that folds the
  predicate in).  When no structured processor is attached, ``on`` is
  False and **no event object is ever constructed**.

``emit`` stamps ``event.ts`` from the stream's injected clock — there is
exactly one clock per stream (the serving scheduler injects its virtual
clock here once instead of special-casing ``time.perf_counter`` at every
use), and :meth:`sleep` centralizes the only behavioural difference a
virtual clock implies (never sleep real time against a frozen clock).

Processors may be attached/detached at any time; emission is serialized
by a lock because the GraphRunner thread emits completion events
concurrently with the Python thread.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional

from repro.core.events.processors import CountersProcessor, Processor


class EventStream:
    """Counter fast path + pluggable structured processors, one clock."""

    def __init__(self, counters: Optional[Dict] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.counters_proc = CountersProcessor(counters)
        self.counters: Dict = self.counters_proc.data
        self.clock = clock
        self._procs: List[Processor] = []
        self.on = False                 # any structured processor attached
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    # counter tier (always on; the hot path)
    # ------------------------------------------------------------------
    def inc(self, key: str, n: int = 1) -> None:
        c = self.counters
        c[key] = c.get(key, 0) + n

    def add(self, key: str, dt: float) -> None:
        c = self.counters
        c[key] = c.get(key, 0.0) + dt

    def put(self, key: str, value) -> None:
        self.counters[key] = value

    def seed(self, defaults: Dict) -> None:
        """Register counter keys without clobbering live values (the
        scheduler seeds its keys into its engine's existing stream)."""
        for k, v in defaults.items():
            self.counters.setdefault(k, v)

    # ------------------------------------------------------------------
    # structured tier (only when a processor is attached)
    # ------------------------------------------------------------------
    def attach(self, proc: Processor) -> Processor:
        with self._lock:
            self._procs.append(proc)
            self.on = True
        return proc

    def detach(self, proc: Processor) -> None:
        with self._lock:
            self._procs = [p for p in self._procs if p is not proc]
            self.on = bool(self._procs)

    def emit(self, event) -> None:
        """Deliver one event to every structured processor.  Callers guard
        with ``es.on`` so the event object exists only when someone
        listens; emitting on a stream that raced to empty is harmless."""
        event.ts = self.clock()
        with self._lock:
            for p in self._procs:
                p.process(event)

    # ------------------------------------------------------------------
    # the injected clock
    # ------------------------------------------------------------------
    def set_clock(self, clock: Callable[[], float]) -> None:
        self.clock = clock

    @property
    def clock_is_real(self) -> bool:
        return self.clock is time.perf_counter

    def sleep(self, seconds: float) -> None:
        """Wait for ``seconds`` of *this stream's* time.  Under the real
        clock that is a bounded real sleep; under an injected (virtual)
        clock real sleeping would hang the caller against frozen time, so
        yield and let the caller re-poll."""
        time.sleep(seconds if self.clock_is_real else 0)

    def close(self) -> None:
        with self._lock:
            procs, self._procs = self._procs, []
            self.on = False
        for p in procs:
            p.close()
