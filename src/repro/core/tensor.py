"""TerraTensor: the tensor handle of the imperative op layer.

In the *tracing phase* a TerraTensor holds a concrete ``jax.Array`` (eager
value) in addition to its trace reference.  In the *co-execution phase* the
PythonRunner executes the skeleton program, so TerraTensors are placeholders
("empty tensor objects", paper §4.1): only the abstract value is known and
materialization triggers a fetch from the GraphRunner.

The same object is also used during divergence fallback: the CoExecutor
replays the validated prefix eagerly and fills ``_eager`` in-place, after
which the iteration continues imperatively (paper: "falls back to the
tracing phase") without re-running Python side effects.
"""

from __future__ import annotations

import threading
from typing import Any, Optional

import numpy as np

from repro.core.trace import Aval

_TLS = threading.local()


def current_engine():
    return getattr(_TLS, "engine", None)


def set_current_engine(engine) -> None:
    _TLS.engine = engine


class TerraTensor:
    """Handle for a DL-op result inside a Terra-managed program."""

    __slots__ = ("ref", "aval", "_eager", "engine", "_iter", "_future",
                 "__weakref__")

    def __init__(self, ref, aval: Aval, eager=None, engine=None, iter_id=-1):
        self.ref = ref
        self.aval = aval
        self._eager = eager
        self.engine = engine
        self._iter = iter_id
        # dispatch-layer fetch future, attached when the producing
        # iteration closes: lets the value be awaited *after* a later
        # iteration has started (the scheduler's lag-harvest window)
        self._future = None

    # -- metadata (always available; no materialization needed) ------------
    @property
    def shape(self):
        return self.aval.shape

    @property
    def dtype(self):
        return self.aval.dtype

    @property
    def ndim(self):
        return len(self.aval.shape)

    def __len__(self):
        if not self.aval.shape:
            raise TypeError("len() of a 0-d tensor")
        return self.aval.shape[0]

    def __repr__(self):
        kind = "eager" if self._eager is not None else "placeholder"
        return f"TerraTensor({kind}, shape={self.aval.shape}, dtype={self.aval.dtype})"

    # -- materialization (fetch points) -------------------------------------
    def value(self):
        """Materialize: returns a concrete jax array (paper's Output Fetching)."""
        if self._eager is not None:
            if self.engine is not None:
                # annotate the fetch point even in eager phases so the
                # generated graph outputs it (paper §4.2 Communication Point)
                self.engine.note_fetch(self)
            return self._eager
        if self.engine is None:
            raise RuntimeError("placeholder TerraTensor with no engine")
        return self.engine.materialize(self)

    def numpy(self):
        return np.asarray(self.value())

    def item(self):
        return self.numpy().item()

    def __float__(self):
        return float(self.item())

    def __int__(self):
        return int(self.item())

    def __bool__(self):
        return bool(self.numpy().all())

    def __array__(self, dtype=None):
        a = self.numpy()
        return a.astype(dtype) if dtype is not None else a

    # -- operator sugar (dispatches into the instrumented op layer) ---------
    def _ops(self):
        from repro.core import ops
        return ops

    def __add__(self, o):      return self._ops().add(self, o)
    def __radd__(self, o):     return self._ops().add(o, self)
    def __sub__(self, o):      return self._ops().sub(self, o)
    def __rsub__(self, o):     return self._ops().sub(o, self)
    def __mul__(self, o):      return self._ops().mul(self, o)
    def __rmul__(self, o):     return self._ops().mul(o, self)
    def __truediv__(self, o):  return self._ops().div(self, o)
    def __rtruediv__(self, o): return self._ops().div(o, self)
    def __pow__(self, o):      return self._ops().power(self, o)
    def __neg__(self):         return self._ops().neg(self)
    def __matmul__(self, o):   return self._ops().matmul(self, o)
    def __getitem__(self, idx):return self._ops().getitem(self, idx=idx)
    def __gt__(self, o):       return self._ops().greater(self, o)
    def __lt__(self, o):       return self._ops().less(self, o)
    def __ge__(self, o):       return self._ops().greater_equal(self, o)
    def __le__(self, o):       return self._ops().less_equal(self, o)

    def reshape(self, *shape):
        if len(shape) == 1 and isinstance(shape[0], (tuple, list)):
            shape = tuple(shape[0])
        return self._ops().reshape(self, new_shape=tuple(shape))

    def transpose(self, *axes):
        if len(axes) == 1 and isinstance(axes[0], (tuple, list)):
            axes = tuple(axes[0])
        return self._ops().transpose(self, axes=axes or None)

    @property
    def T(self):
        return self.transpose()

    def astype(self, dtype):
        return self._ops().cast(self, dtype=str(np.dtype(dtype)))

    def sum(self, axis=None, keepdims=False):
        return self._ops().reduce_sum(self, axis=axis, keepdims=keepdims)

    def mean(self, axis=None, keepdims=False):
        return self._ops().reduce_mean(self, axis=axis, keepdims=keepdims)

    def max(self, axis=None, keepdims=False):
        return self._ops().reduce_max(self, axis=axis, keepdims=keepdims)


class Variable:
    """A framework variable (TF resource-variable analogue).

    The authoritative buffer lives in the engine's variable store (on device,
    donated between iterations in co-execution).  Reads and ``assign`` are
    recorded in the trace so the generated symbolic graph threads the update
    — this is what lets Terra run programs with Python *object mutation*
    (Figure 1c) that static converters mishandle.
    """

    _next_id = [0]
    _lock = threading.Lock()

    def __init__(self, init_value, name: str = ""):
        import jax.numpy as jnp
        with Variable._lock:
            self.var_id = Variable._next_id[0]
            Variable._next_id[0] += 1
        self.name = name or f"var{self.var_id}"
        self._value = jnp.asarray(init_value)
        self.aval = Aval.of(self._value)

    # read
    def read(self) -> Any:
        eng = current_engine()
        if eng is None:
            return self._value
        return eng.read_variable(self)

    def assign(self, new_value) -> None:
        eng = current_engine()
        if eng is None:
            import jax.numpy as jnp
            self._value = jnp.asarray(new_value)
            return
        eng.assign_variable(self, new_value)

    def assign_sub(self, delta) -> None:
        from repro.core import ops
        self.assign(ops.sub(self.read(), delta))

    def assign_add(self, delta) -> None:
        from repro.core import ops
        self.assign(ops.add(self.read(), delta))

    def value(self):
        eng = current_engine()
        if eng is None:
            return self._value
        return eng.variable_value(self)

    def numpy(self):
        return np.asarray(self.value())

    def __repr__(self):
        return f"Variable({self.name}, shape={self.aval.shape}, dtype={self.aval.dtype})"
