"""Terra: imperative-symbolic co-execution (the paper's contribution).

Public surface:
    terra.function / TerraFunction — manage an imperative program
    terra.imperative               — pure-imperative baseline engine
    ops.*                          — the instrumented DL op namespace
    GradientTape                   — tape autodiff (backward ops are traced)
    Variable                       — mutable state threaded through graphs
    terra_op                       — register a pure-JAX fn as one DL op
"""

from repro.core import ops
from repro.core.engine import TerraFunction, function, imperative
from repro.core.ops import GradientTape, terra_op
from repro.core.executor import (SKELETON, TRACING, DivergenceError,
                                 TerraEngine)
from repro.core.tensor import TerraTensor, Variable

__all__ = [
    "ops", "TerraFunction", "function", "imperative", "GradientTape",
    "terra_op", "Variable", "TerraTensor", "TerraEngine",
    "DivergenceError", "SKELETON", "TRACING",
]
