"""GPipe-style pipeline parallelism over shard_map + collective_permute.

Beyond-paper scale feature: the ``pod`` axis of the multi-pod mesh can act
as a pipeline axis — each pod holds a contiguous group of super-blocks, and
microbatches stream through stages with ``jax.lax.ppermute`` moving
activations between neighbours.  Bubble fraction = (S-1)/(M+S-1) for S
stages and M microbatches; the dry-run §Perf log quantifies when this beats
pure DP across pods (it wins when cross-pod DCN gradient all-reduce is the
bottleneck, because PP sends activations instead of gradients).

This module is deliberately self-contained and works on any 1-D axis: the
unit tests run it on a host-device mesh.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:                     # jax < 0.5 ships it as experimental
    from jax.experimental.shard_map import shard_map

# pvary marks device-varying values for the new replication checker; older
# jax has no checker to satisfy, so it degenerates to identity
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def pipeline_forward(stage_fn: Callable, n_stages: int, axis: str):
    """Build a pipelined forward: ``stage_fn(stage_params, x) -> x``.

    Returns fn(stacked_stage_params, microbatches [M, mb, ...]) -> [M, mb, ...]
    to be wrapped in shard_map over ``axis`` (each device along the axis
    holds one stage's params and processes the stream).
    """

    def pipelined(stage_params, mbs):
        M = mbs.shape[0]
        stage = jax.lax.axis_index(axis)
        n_ticks = M + n_stages - 1
        # replicated inputs feed device-varying collectives: mark them as
        # varying along the pipeline axis (jax >= 0.8 vma typing)
        mbs = _pvary(mbs, (axis,))

        def tick(carry, t):
            buf, outs = carry            # buf: activation entering this stage
            # stage 0 injects microbatch t (when valid)
            inject = jnp.where(t < M, t, M - 1)
            x_in = jnp.where(stage == 0, mbs[inject], buf)
            y = stage_fn(stage_params, x_in)
            # pass activations stage s -> s+1
            y_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            # last stage emits the finished microbatch (t - S + 1)
            out_idx = t - (n_stages - 1)
            valid = (out_idx >= 0) & (stage == n_stages - 1)
            outs = jnp.where(
                valid,
                outs.at[jnp.clip(out_idx, 0, M - 1)].set(y),
                outs)
            return (y_next, outs), None

        buf0 = jnp.zeros_like(mbs[0])
        outs0 = jnp.zeros_like(mbs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage holds real outputs; psum of the masked value
        # replicates them along the pipeline axis
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return pipelined


def make_pipelined_apply(mesh: Mesh, axis: str, stage_fn: Callable):
    """shard_map wrapper: stage params sharded along ``axis`` (leading dim
    = n_stages), microbatches replicated in, outputs replicated out."""
    n_stages = mesh.shape[axis]
    fn = pipeline_forward(stage_fn, n_stages, axis)

    def sharded(stacked_params, mbs):
        return shard_map(
            lambda p, x: fn(jax.tree.map(lambda a: a[0], p), x),
            mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
        )(stacked_params, mbs)

    return sharded
