"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Model code annotates arrays with *logical* axis names; this module maps them
to mesh axes via a rule table, MaxText-style.  The production meshes
(launch/mesh.py) are:

    single-pod:  (16, 16)            axes ("data", "model")
    multi-pod:   (2, 16, 16)         axes ("pod", "data", "model")

Default rules:
    batch       -> ("pod", "data")      # DP across pods and data axis
    fsdp        -> ("data",)            # ZeRO-3 weight shard (+pod optional)
    tp          -> ("model",)           # tensor parallel: heads / ffn hidden
    expert      -> ("model",)           # EP: MoE expert dim
    seq         -> ()                   # sequence kept unsharded by default
    sp          -> ("model",)           # sequence parallel for long-context
    vocab       -> ("model",)

Rules are plain data; the perf loop (§Perf) swaps rule tables to move
roofline terms.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("data",),
    "fsdp_pod": ("pod", "data"),
    "tp": ("model",),
    "expert": ("model",),
    "capacity": ("data",),     # MoE per-expert token slots shard over data
    "seq": (),
    "sp": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "d_model": (),
    "d_ff": ("model",),
    "unsharded": (),
}


class ShardingPolicy:
    """Resolves logical axis names to mesh axes for a given mesh."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    def spec(self, *logical: Optional[str]) -> P:
        if self.mesh is None:
            return P()
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
                continue
            axes = tuple(a for a in self.rules.get(name, ())
                         if a in self.mesh.axis_names)
            parts.append(axes if axes else None)
        return P(*parts)

    def sharding(self, *logical: Optional[str]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))


def current_policy() -> Optional[ShardingPolicy]:
    return getattr(_TLS, "policy", None)


@contextlib.contextmanager
def use_policy(policy: Optional[ShardingPolicy]):
    prev = current_policy()
    _TLS.policy = policy
    try:
        yield policy
    finally:
        _TLS.policy = prev


def logical(x, *names: Optional[str]):
    """Annotate activation sharding with logical axis names.  A no-op when
    no policy/mesh is active (single-device smoke tests)."""
    pol = current_policy()
    if pol is None or pol.mesh is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(f"rank mismatch: {names} for shape {x.shape}")
    spec = pol.spec(*names)
    # never request a partition that does not divide the dim, and never use
    # one mesh axis for two tensor dims (first occurrence wins)
    fixed = []
    used: set = set()
    for dim, part in zip(x.shape, spec):
        if part is None:
            fixed.append(None)
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        axes = tuple(a for a in axes if a not in used)
        size = 1
        for a in axes:
            size *= pol.mesh.shape[a]
        if not axes or dim % size != 0:
            fixed.append(None)
            continue
        used.update(axes)
        fixed.append(axes if len(axes) > 1 else axes[0])
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(pol.mesh, P(*fixed)))


def param_spec(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
               pol: ShardingPolicy) -> P:
    """PartitionSpec for a parameter, dropping non-divisible partitions."""
    spec = pol.spec(*logical_axes)
    fixed = []
    for dim, part in zip(shape, spec):
        if part is None:
            fixed.append(None)
            continue
        axes = (part,) if isinstance(part, str) else tuple(part)
        size = 1
        for a in axes:
            size *= pol.mesh.shape[a]
        fixed.append(part if dim % size == 0 else None)
    return P(*fixed)
