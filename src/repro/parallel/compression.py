"""Gradient compression for the data-parallel all-reduce (beyond-paper
distributed-optimization feature).

Two schemes, both with error feedback so compression noise does not bias
the optimizer:

* ``bf16``  — cast f32 grads to bf16 before the cross-replica psum (halves
  gradient wire bytes; the residual r = g - decompress(compress(g)) is
  carried to the next step).
* ``int8``  — per-tensor-block scale quantization (4x reduction); blocks of
  256 values share one f32 scale.

Used with the explicit shard_map data-parallel step (``dp_allreduce``);
with pjit the gradient reduction is implicit, so compression plugs in where
the collective is visible.  EXPERIMENTS.md §Perf quantifies the wire-byte
reduction on the collective-bound cells.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:                     # jax < 0.5 ships it as experimental
    from jax.experimental.shard_map import shard_map


# --------------------------------------------------------------------------
# codecs
# --------------------------------------------------------------------------

def compress_bf16(g):
    return g.astype(jnp.bfloat16)


def decompress_bf16(c):
    return c.astype(jnp.float32)


def compress_int8(g, block: int = 256):
    flat = g.reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), g.shape, pad


def decompress_int8(packed):
    q, scale, shape, pad = packed
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


# --------------------------------------------------------------------------
# error-feedback compressed all-reduce
# --------------------------------------------------------------------------

def compressed_psum_bf16(grads, residuals, axis: str):
    """Returns (mean-reduced grads, new residuals).  Call inside shard_map
    over the data axis."""
    def one(g, r):
        g = g.astype(jnp.float32) + r
        c = compress_bf16(g)
        new_r = g - decompress_bf16(c)
        summed = jax.lax.psum(c.astype(jnp.float32), axis)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return summed / n, new_r
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in out]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]))


def zero_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def dp_allreduce(mesh: Mesh, axis: str, compression: str = "bf16"):
    """Explicit data-parallel gradient mean with optional compression,
    for use where the collective must be visible (shard_map step)."""
    def reduce_fn(grads, residuals):
        if compression == "none":
            n = mesh.shape[axis]
            return (jax.tree.map(
                lambda g: jax.lax.psum(g, axis) / n, grads), residuals)
        if compression == "bf16":
            return compressed_psum_bf16(grads, residuals, axis)
        raise ValueError(compression)

    def apply(grads, residuals):
        return shard_map(
            reduce_fn, mesh=mesh,
            in_specs=(P(axis), P(axis)),
            out_specs=(P(axis), P(axis)),
        )(grads, residuals)

    return apply


def wire_bytes_saved(grads, compression: str) -> Tuple[int, int]:
    """(uncompressed, compressed) wire bytes for reporting."""
    total = sum(g.size * 4 for g in jax.tree.leaves(grads))
    factor = {"none": 1.0, "bf16": 0.5, "int8": 0.25 + 4.0 / 256}[compression]
    return total, int(total * factor)
