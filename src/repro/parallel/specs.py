"""Parameter / batch / cache PartitionSpecs for the production meshes.

Name-pattern rules (Megatron/MaxText-style):
  column-parallel weights  [d, X]      -> (fsdp, tp)       X = heads*hd | d_ff
  row-parallel weights     [X, d]      -> (tp, fsdp)
  MoE expert weights       [E, d, f]   -> (expert=tp, -, -)   (fine-grained)
                                          fallback (-, fsdp, tp) when E does
                                          not divide the model axis (Mixtral)
  embeddings / lm head     [V, d]      -> (tp=vocab, fsdp)
  vectors / scalars                    -> replicated
Stacked super-block leaves get a leading None.  Every rule drops
non-divisible partitions (parallel.sharding.param_spec semantics).

KV caches shard batch over (pod, data) and the *sequence* dim over the
model axis (sequence parallelism) — kv-head counts (8) do not divide the
16-way model axis, and SP is what keeps a 32k x 128 cache at ~1 GiB/chip.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

COLUMN = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_in_x", "w_in_y",
          "w_a", "w_x", "w_router"}
ROW = {"wo", "w_down", "w_out"}
EMBED = {"embed", "lm_head", "enc_pos"}


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _fit(mesh, dim: int, axes) -> Optional[Any]:
    """Return axes if they divide dim, else None (replicate)."""
    if not axes:
        return None
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    if dim % _axes_size(mesh, axes):
        return None
    return axes if len(axes) > 1 else axes[0]


def param_spec_for(mesh, path: str, shape: Tuple[int, ...],
                   fsdp: bool = True) -> P:
    """PartitionSpec for one parameter leaf, identified by its tree path.

    ``fsdp=False`` (serve mode): parameters shard over the model axis only
    — no per-layer all-gather of weight shards at inference (§Perf lever
    for the collective-bound prefill cells)."""
    name = path.split("|")[-1]
    data_axes = ("data",) if fsdp else ()
    nd = len(shape)
    lead = ()                       # stacked super-block axis
    core = shape
    if name in COLUMN | ROW and nd == 3:
        lead, core = (None,), shape[1:]
    if name in COLUMN | ROW and nd == 4:     # stacked MoE expert weights
        lead, core = (None,), shape[1:]

    if name in EMBED and nd == 2:
        return P(_fit(mesh, shape[0], ("model",)),
                 _fit(mesh, shape[1], data_axes))
    if len(core) == 3 and name in COLUMN | ROW:
        # expert weights [E, d, f] / [E, f, d]
        e = _fit(mesh, core[0], ("model",))
        if e is not None:
            return P(*lead, e, None, None)
        if name in ROW:
            return P(*lead, None, _fit(mesh, core[1], ("model",)),
                     _fit(mesh, core[2], data_axes))
        return P(*lead, None, _fit(mesh, core[1], data_axes),
                 _fit(mesh, core[2], ("model",)))
    if len(core) == 2 and name in COLUMN:
        return P(*lead, _fit(mesh, core[0], data_axes),
                 _fit(mesh, core[1], ("model",)))
    if len(core) == 2 and name in ROW:
        return P(*lead, _fit(mesh, core[0], ("model",)),
                 _fit(mesh, core[1], data_axes))
    # conv kernels, norm scales, biases, gates, router scalars: replicate
    return P(*([None] * nd))


def _flat_paths(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "|".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        yield key, leaf


def tree_param_specs(mesh, params, fsdp: bool = True):
    leaves = []
    for key, leaf in _flat_paths(params):
        leaves.append(param_spec_for(mesh, key, leaf.shape, fsdp=fsdp))
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_shardings(mesh, params):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_param_specs(mesh, params))


def opt_state_specs(mesh, opt_state, param_specs):
    """m / v / master mirror the parameter sharding; step is replicated."""
    return {
        "step": P(),
        "m": param_specs, "v": param_specs, "master": param_specs,
    }


def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh, shape: Tuple[int, ...]) -> P:
    b = _fit(mesh, shape[0], batch_axes(mesh))
    return P(b, *([None] * (len(shape) - 1)))


def cache_spec_for(mesh, path: str, shape: Tuple[int, ...]) -> P:
    """KV/recurrent cache leaves.  k/v: [nb, B, S, Hkv, D] -> batch over
    (pod,data), seq over model (SP).  Recurrent states: batch only."""
    name = path.split("|")[-1]
    if name in ("k", "v") and len(shape) >= 5:
        return P(None, _fit(mesh, shape[1], batch_axes(mesh)),
                 _fit(mesh, shape[2], ("model",)), None, None)
    if name in ("k", "v") and len(shape) == 4:     # unstacked (extra blocks)
        return P(_fit(mesh, shape[0], batch_axes(mesh)),
                 _fit(mesh, shape[1], ("model",)), None, None)
    if name == "len":
        return P()
    # conv/ssm/h states: shard batch; distribute width over model if it fits
    if len(shape) >= 2:
        lead = None if len(shape) < 3 else None
        bdim = 1 if len(shape) >= 3 else 0
        spec = [None] * len(shape)
        spec[bdim] = _fit(mesh, shape[bdim], batch_axes(mesh))
        spec[-1] = _fit(mesh, shape[-1], ("model",))
        return P(*spec)
    return P(*([None] * len(shape)))


def tree_cache_specs(mesh, cache):
    leaves = []
    for key, leaf in _flat_paths(cache):
        leaves.append(cache_spec_for(mesh, key, leaf.shape))
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, leaves)
