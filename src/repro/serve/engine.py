"""Batched serving engine: request queue -> batched prefill -> decode loop.

A deliberately small but real continuous-serving driver: requests arrive
with prompts; the engine forms a batch, prefills once, then decodes all
sequences in lock-step, retiring finished sequences at EOS / max-tokens.
The decode loop is an imperative Python program (per-request bookkeeping,
early exits, third-party detokenizers all live here), so it runs under
Terra co-execution by default (``use_terra=True``): the decode step is a
single DL op, params and KV cache live in the engine's device-resident
variable store, and only the sampled token is fetched per step — serving
is the paper's other first-class workload (see serve/terra_decode.py).
``use_terra=False`` keeps the hand-jitted donate-the-cache baseline."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.events import EventStream
from repro.core.executor.families import bucket_pow2
from repro.serve.serve_step import jit_serve_steps
from repro.serve.terra_decode import TerraDecoder


@dataclasses.dataclass(eq=False)    # identity semantics: prompt is an array
class Request:
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never
    out_tokens: Optional[list] = None
    done: bool = False
    # latency accounting (bench_serving): all three on the same
    # time.perf_counter() clock; arrival defaults to construction time
    arrival_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    # per-token streaming callback — the third-party-code stand-in; called
    # as stream(request, token, index) from the serving loop's Python side
    stream: Optional[Callable] = None
    # request id stamped by the scheduler at submit time (the join key of
    # the request's event trace, DESIGN.md §13); a resubmission restarts
    # the lifecycle and gets a fresh rid
    rid: Optional[int] = None

    def __post_init__(self):
        if self.arrival_time is None:
            self.arrival_time = time.perf_counter()


class ServingEngine:
    """``bucket_batches=True`` pads every batch up to the next power-of-two
    size (repeating the last prompt row; pad rows decode but are ignored),
    bounding the number of distinct batch shapes — and therefore TraceGraph
    families (DESIGN.md §8) — to O(log max-batch)."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 temperature: float = 0.0, use_terra: bool = True,
                 bucket_batches: bool = False, optimize=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.bucket_batches = bucket_batches
        self.prefill, self.decode = jit_serve_steps(cfg, max_len,
                                                    temperature,
                                                    donate_cache=True)
        # serving defaults to the SAFE pass pipeline (no constant-feed
        # folding: decode-step token feeds change every call, DESIGN.md
        # §10); $TERRA_OPTIMIZE still overrides when optimize is None
        self.terra = (TerraDecoder(cfg, params, temperature,
                                   optimize=optimize)
                      if use_terra else None)
        # lock-step counters ride the same event substrate as everything
        # else (DESIGN.md §13): stats IS the stream's counter dict
        self.events = EventStream(counters={
            "prefill_tokens": 0, "decode_steps": 0,
            "decode_time": 0.0, "prefill_time": 0.0})
        self.stats = self.events.counters

    def run_batch(self, requests: List[Request], **extras) -> List[Request]:
        """Serve one batch of same-length prompts in lock-step.

        Ragged prompt lengths are rejected up front (the batch tensor is
        rectangular by construction — variable-length admission is what
        the continuous-batching scheduler in serve/scheduler/ is for).
        The decode loop's budget tracks the *live* requests only: rows
        that hit EOS or their token budget stop counting, so the loop
        ends exactly when the last live row finishes; pad rows added by
        ``bucket_batches`` never extend it."""
        B = len(requests)
        lengths = {len(r.prompt) for r in requests}
        if len(lengths) != 1:
            raise ValueError(
                f"run_batch requires same-length prompts, got lengths "
                f"{sorted(lengths)}; use "
                f"serve.scheduler.ContinuousBatchingScheduler for "
                f"mixed-length workloads")
        prompts = np.stack([r.prompt for r in requests]).astype(np.int32)
        if self.bucket_batches:
            padded = bucket_pow2(B)
            if padded > B:
                prompts = np.concatenate(
                    [prompts, np.repeat(prompts[-1:], padded - B, axis=0)])
        t0 = time.perf_counter()
        next_tok, cache = self.prefill(self.params, prompts, **extras)
        next_tok = np.asarray(jax.block_until_ready(next_tok))[:, None]
        now = time.perf_counter()
        self.stats["prefill_time"] += now - t0
        # pad rows are repeats, not work done for a request
        self.stats["prefill_tokens"] += prompts[:B].size

        def live():
            return [r for r in requests
                    if not r.done and len(r.out_tokens) < r.max_new_tokens]

        cap = self.max_len - prompts.shape[1] - 1   # cache capacity
        t0 = time.perf_counter()
        dec_extras = {k: v for k, v in extras.items()
                      if k != "frontend_embeds"}
        # the finally block keeps the engine and the batch's accounting
        # consistent even when a user stream callback raises mid-batch:
        # pending symbolic work is drained, unfinished rows get their
        # finish stamp, and decode_time is recorded
        try:
            for r, t in zip(requests, next_tok[:, 0]):
                r.out_tokens = [int(t)]
                r.first_token_time = now
                r.done = (int(t) == r.eos_id)
                if r.done or r.max_new_tokens <= 1:
                    r.finish_time = now
                if r.stream is not None:
                    r.stream(r, int(t), 0)
            if self.terra is not None:
                self.terra.begin_batch(cache)
            steps = 0
            while steps < cap:
                # the break condition counts live rows only: done/pad
                # rows never stretch the loop
                if not live():
                    break
                if self.terra is not None:
                    tok = self.terra.step(next_tok,
                                          cross_states=dec_extras.get(
                                              "cross_states"))
                    next_tok = np.asarray(tok)    # Output Fetching point
                else:
                    tok, cache = self.decode(self.params, cache,
                                             jnp.asarray(next_tok),
                                             **dec_extras)
                    next_tok = np.asarray(tok)
                steps += 1
                self.stats["decode_steps"] += 1
                now = time.perf_counter()
                for i, r in enumerate(requests):
                    if r.done or len(r.out_tokens) >= r.max_new_tokens:
                        continue
                    t = int(next_tok[i, 0])
                    r.out_tokens.append(t)
                    if t == r.eos_id:
                        r.done = True
                    # stamp finish at the step the row actually retires,
                    # not at batch drain — early-EOS latency must not
                    # include the steps the row merely rode along for
                    if (r.done or len(r.out_tokens) >= r.max_new_tokens) \
                            and r.finish_time is None:
                        r.finish_time = now
                    if r.stream is not None:
                        r.stream(r, t, len(r.out_tokens) - 1)
        finally:
            if self.terra is not None:
                self.terra.wait()
            now = time.perf_counter()
            for r in requests:
                if r.finish_time is None:  # capped, or aborted mid-batch
                    r.finish_time = now
            self.stats["decode_time"] += now - t0
        return requests
