"""Batched serving engine: request queue -> batched prefill -> decode loop.

A deliberately small but real continuous-serving driver: requests arrive
with prompts; the engine forms a batch, prefills once, then decodes all
sequences in lock-step, retiring finished sequences at EOS / max-tokens.
The decode loop is an imperative Python program (per-request bookkeeping,
early exits, third-party detokenizers all live here), so it runs under
Terra co-execution by default (``use_terra=True``): the decode step is a
single DL op, params and KV cache live in the engine's device-resident
variable store, and only the sampled token is fetched per step — serving
is the paper's other first-class workload (see serve/terra_decode.py).
``use_terra=False`` keeps the hand-jitted donate-the-cache baseline."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.executor.families import bucket_pow2
from repro.serve.serve_step import jit_serve_steps
from repro.serve.terra_decode import TerraDecoder


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [S] int32
    max_new_tokens: int = 32
    eos_id: int = -1                # -1: never
    out_tokens: Optional[list] = None
    done: bool = False


class ServingEngine:
    """``bucket_batches=True`` pads every batch up to the next power-of-two
    size (repeating the last prompt row; pad rows decode but are ignored),
    bounding the number of distinct batch shapes — and therefore TraceGraph
    families (DESIGN.md §8) — to O(log max-batch)."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 temperature: float = 0.0, use_terra: bool = True,
                 bucket_batches: bool = False, optimize=None):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.bucket_batches = bucket_batches
        self.prefill, self.decode = jit_serve_steps(cfg, max_len,
                                                    temperature,
                                                    donate_cache=True)
        # serving defaults to the SAFE pass pipeline (no constant-feed
        # folding: decode-step token feeds change every call, DESIGN.md
        # §10); $TERRA_OPTIMIZE still overrides when optimize is None
        self.terra = (TerraDecoder(cfg, params, temperature,
                                   optimize=optimize)
                      if use_terra else None)
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_time": 0.0, "prefill_time": 0.0}

    def run_batch(self, requests: List[Request], **extras) -> List[Request]:
        """Serve one batch of same-length prompts in lock-step."""
        B = len(requests)
        prompts = np.stack([r.prompt for r in requests]).astype(np.int32)
        if self.bucket_batches:
            padded = bucket_pow2(B)
            if padded > B:
                prompts = np.concatenate(
                    [prompts, np.repeat(prompts[-1:], padded - B, axis=0)])
        t0 = time.perf_counter()
        next_tok, cache = self.prefill(self.params, prompts, **extras)
        next_tok = np.asarray(jax.block_until_ready(next_tok))[:, None]
        self.stats["prefill_time"] += time.perf_counter() - t0
        self.stats["prefill_tokens"] += prompts.size

        for r, t in zip(requests, next_tok[:, 0]):
            r.out_tokens = [int(t)]
            r.done = (int(t) == r.eos_id)

        max_new = max(r.max_new_tokens for r in requests)
        budget = min(max_new - 1, self.max_len - prompts.shape[1] - 1)
        t0 = time.perf_counter()
        dec_extras = {k: v for k, v in extras.items()
                      if k != "frontend_embeds"}
        if self.terra is not None:
            self.terra.begin_batch(cache)
        for _ in range(budget):
            if all(r.done or len(r.out_tokens) >= r.max_new_tokens
                   for r in requests):
                break
            if self.terra is not None:
                tok = self.terra.step(next_tok,
                                      cross_states=dec_extras.get(
                                          "cross_states"))
                next_tok = np.asarray(tok)        # Output Fetching point
            else:
                tok, cache = self.decode(self.params, cache,
                                         jnp.asarray(next_tok), **dec_extras)
                next_tok = np.asarray(tok)
            self.stats["decode_steps"] += 1
            for i, r in enumerate(requests):
                if r.done or len(r.out_tokens) >= r.max_new_tokens:
                    continue
                t = int(next_tok[i, 0])
                r.out_tokens.append(t)
                if t == r.eos_id:
                    r.done = True
        if self.terra is not None:
            self.terra.wait()
        self.stats["decode_time"] += time.perf_counter() - t0
        return requests
