"""Serving steps: jit-compiled prefill and single-token decode.

``serve_step`` (decode) is what the decode_32k / long_500k dry-run cells
lower: one new token against a KV/recurrent cache of seq_len, with the
cache donated for in-place buffer reuse.  Sampling is greedy or
temperature-categorical; the batched engine drives continuous decoding.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M


def build_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, tokens, *, cross_states=None,
                     frontend_embeds=None):
        logits, cache = M.prefill(cfg, params, tokens, max_len,
                                  cross_states=cross_states,
                                  frontend_embeds=frontend_embeds)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, cache
    return prefill_step


def build_decode_step(cfg: ModelConfig, temperature: float = 0.0):
    def decode_step(params, cache, tokens, rng=None, *, cross_states=None):
        logits, cache = M.decode_step(cfg, params, cache, tokens,
                                      cross_states=cross_states)
        if temperature > 0.0 and rng is not None:
            next_tok = jax.random.categorical(
                rng, logits.astype(jnp.float32) / temperature, axis=-1)
        else:
            next_tok = jnp.argmax(logits, axis=-1)
        return next_tok.astype(jnp.int32)[:, None], cache
    return decode_step


def jit_serve_steps(cfg: ModelConfig, max_len: int, temperature: float = 0.0,
                    donate_cache: bool = True):
    prefill = jax.jit(build_prefill_step(cfg, max_len))
    decode = jax.jit(build_decode_step(cfg, temperature),
                     donate_argnums=(1,) if donate_cache else ())
    return prefill, decode
