"""ContinuousBatchingScheduler: the serving main loop under co-execution.

An ordinary imperative Python loop — arrival queue, slot pool, retirement,
streaming callbacks — run as the skeleton of a ``terra.function`` whose
one DL op is the masked ``slot_decode`` step (pool_ops.py).  Pool state
lives as framework Variables threading GraphRunner-to-GraphRunner on
device; the loop runs one step deep (dispatch N+1, then harvest N);
admission prefills splice device buffers through fenced closures
(varops).  ``page_size`` selects the paged arena (paged.py);
``use_terra=False`` is the hand-jitted scheduling baseline; and
``checkpoint``/``restore`` persist a quiescent scheduler for exact
cross-process continuation.  See DESIGN.md §11/§12/§14."""

from __future__ import annotations

import os
import time
from concurrent.futures import Future
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import function as terra_function
from repro.core import ops as ops_mod
from repro.core.executor import SKELETON, varops
from repro.core.ops import op_impl
from repro.core.tensor import TerraTensor, Variable
from repro.serve.scheduler import pool_ops
from repro.serve.scheduler import telemetry as tm
from repro.serve.scheduler.lifecycle import (ArrivalQueue, CallbackQueue,
                                             record_token)
from repro.serve.scheduler.paged import PagedLayout
from repro.serve.scheduler.planner import (DecodePlan, IdlePlan,
                                           PrefillPlan, StepPlanner)
from repro.serve.scheduler.slots import SlotPool

_STATIC = ("_meta", "_n_params", "_n_cache", "_has_rng")


class ContinuousBatchingScheduler:
    """Slot-pooled continuous-batching serving engine (DESIGN.md §11/§12)."""

    def __init__(self, cfg, params, *, max_slots: int = 8,
                 max_len: int = 256, temperature: float = 0.0,
                 use_terra: bool = True, optimize: Optional[str] = None,
                 prefill_batch_cap: Optional[int] = None,
                 bucket_floor: int = 8,
                 page_size: Optional[int] = None,
                 num_blocks: Optional[int] = None,
                 steady_state: int = 8, steady_probe: int = 128,
                 profile: int = 0,
                 clock: Callable[[], float] = time.perf_counter):
        pool_ops.check_supported(cfg)
        self.cfg = cfg
        self.max_len = max_len
        self.temperature = temperature
        self.use_terra = use_terra
        self.clock = clock
        self._has_rng = temperature > 0.0
        self._prefill_key = jax.random.PRNGKey(0)
        self.layout = None
        if page_size:
            if num_blocks is None:      # dense-equivalent arena + trash
                num_blocks = (max_slots * max_len) // page_size + 1
            self.layout = PagedLayout(page_size, num_blocks, max_len)
        ps = self.layout.block_size if self.layout else 0
        nb = self.layout.num_blocks if self.layout else 0

        leaves0, cache_def, axes, paged = pool_ops.build_pool_cache(
            cfg, max_slots, max_len, ps, nb)
        self._params_leaves, params_def = jax.tree_util.tree_flatten(params)
        self._np, self._nc = len(self._params_leaves), len(leaves0)
        self._mid = pool_ops.register_pool_meta(
            cfg, params_def, cache_def, axes, temperature, max_len,
            ps, nb, paged)
        self._attrs = dict(_meta=self._mid, _n_params=self._np,
                           _n_cache=self._nc, _has_rng=self._has_rng)
        pos0 = jnp.zeros(max_slots, jnp.int32)
        tokf0 = jnp.zeros((max_slots, 1), jnp.int32)

        if use_terra:
            # SAFE default: mask/block-table feeds never constant-fold (§10)
            if optimize is None:
                optimize = os.environ.get("TERRA_OPTIMIZE") or "safe"
            self._param_vars = [Variable(l, name=f"sched.p{i}")
                                for i, l in enumerate(self._params_leaves)]
            self._cache_vars = [Variable(l, name=f"sched.c{i}")
                                for i, l in enumerate(leaves0)]
            self._pos_var = Variable(pos0, name="sched.pos")
            self._tokf_var = Variable(tokf0, name="sched.tokf")
            self._tf = terra_function(self._step, optimize=optimize,
                                      steady_state=steady_state,
                                      steady_probe=steady_probe,
                                      profile=profile)
            self._prefill_jit = jax.jit(op_impl("serve.slot_prefill"),
                                        static_argnames=_STATIC)
        else:
            self._cache_leaves = list(leaves0)
            self._pos, self._tokf = pos0, tokf0
            # donate pool state (cache + pos + tokf) for in-place reuse
            donate = tuple(range(self._np, self._np + self._nc + 2))
            self._decode_jit = jax.jit(op_impl("serve.slot_decode"),
                                       static_argnames=_STATIC,
                                       donate_argnums=donate)
            self._prefill_jit = jax.jit(op_impl("serve.slot_prefill"),
                                        static_argnames=_STATIC,
                                        donate_argnums=donate)

        self.pool = SlotPool(max_slots, self.layout, row_tokens=max_len)
        self.queue = ArrivalQueue(clock)
        self.callbacks = CallbackQueue()
        self.planner = StepPlanner(cfg, self.queue, self.pool, max_len,
                                   prefill_batch_cap or max_slots,
                                   bucket_floor)
        self._pending = None            # the one in-flight (lagged) step
        # one instrumentation substrate (§13): share the engine's stream
        self.events = tm.make_stream(
            self._tf.engine.events if use_terra else None, clock)
        self.sched_stats = self.events.counters
        self._rid = 0
        self._ckpt_kw = dict(
            max_slots=max_slots, max_len=max_len, temperature=temperature,
            use_terra=use_terra, optimize=optimize,
            prefill_batch_cap=prefill_batch_cap, bucket_floor=bucket_floor,
            page_size=ps or None, num_blocks=nb or None, profile=profile,
            steady_state=steady_state, steady_probe=steady_probe)

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------
    def submit(self, request) -> None:
        L = len(request.prompt)
        if L < 1:
            raise ValueError("empty prompt")
        if L + request.max_new_tokens + 1 > self.max_len:
            raise ValueError(
                f"prompt ({L}) + max_new_tokens ({request.max_new_tokens})"
                f" exceeds pool max_len {self.max_len}")
        if self.layout is not None:
            need = self.layout.blocks_needed(L, request.max_new_tokens)
            if need > self.pool.allocator.capacity:
                raise ValueError(
                    f"request needs {need} blocks; arena capacity is "
                    f"{self.pool.allocator.capacity}")
        self._rid += 1
        tm.request_submit(self.events, request, self._rid)
        self.queue.submit(request)

    def serve(self, requests: List[object]) -> List[object]:
        """Convenience: submit a batch and run until drained."""
        for r in requests:
            self.submit(r)
        self.run()
        return requests

    def run(self, max_steps: Optional[int] = None) -> None:
        """Serve until drained, one step deep: dispatch the next step,
        *then* harvest the previous step's token frame."""
        steps = 0
        while (len(self.queue) or self.pool.active_count
               or self._pending is not None):
            plan = self.planner.next_plan(self.clock())
            if isinstance(plan, PrefillPlan):
                nxt = self._dispatch_prefill(plan)
            elif isinstance(plan, DecodePlan):
                nxt = self._dispatch_decode(plan)
            else:
                nxt = None
            prev, self._pending = self._pending, nxt
            if prev is not None:
                self._harvest(prev)
                self.callbacks.flush()
            elif nxt is None:
                self._idle(plan)
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        if self._pending is not None:
            self._harvest(self._pending)
            self._pending = None
        self.callbacks.flush()
        if self.use_terra:
            self._tf.wait()

    @property
    def stats(self) -> dict:
        return tm.merged_stats(self)

    def set_profile(self, every: int) -> None:
        """Runtime-mutable sampled profiling cadence (DESIGN.md §15)."""
        tm.set_profile(self, every)

    def enable_metrics(self, registry=None):
        """Attach a live metrics processor; returns its registry (§15)."""
        return tm.enable_metrics(self, registry)

    def checkpoint(self, path: str) -> None:
        """Persist quiescent state for cross-process continuation (§14)."""
        from repro.serve.scheduler.checkpoint import save_scheduler
        save_scheduler(self, path)

    @classmethod
    def restore(cls, path: str, cfg, params, **overrides):
        """Rebuild a checkpointed scheduler; decoding resumes with exactly
        the tokens the donor process would have produced."""
        from repro.serve.scheduler.checkpoint import restore_scheduler
        return restore_scheduler(cls, path, cfg, params, **overrides)

    def close(self) -> None:
        if self.use_terra:
            self._tf.close()

    # ------------------------------------------------------------------
    # step execution
    # ------------------------------------------------------------------
    def _step(self, mask, bt=None):
        """The co-executed skeleton step: one masked slot_decode node."""
        args = [v.read() for v in self._param_vars]
        args += [v.read() for v in self._cache_vars]
        args += [self._pos_var.read(), self._tokf_var.read(), mask]
        if bt is not None:
            args.append(bt)
        if self._has_rng:
            args.append(ops_mod._next_key())   # iteration-stable key feed
        outs = pool_ops.slot_decode(*args, **self._attrs)
        tok, leaves = outs[0], outs[1:-2]
        for var, leaf in zip(self._cache_vars, leaves):
            var.assign(leaf)
        self._pos_var.assign(outs[-2])
        self._tokf_var.assign(outs[-1])
        return tok

    def _dispatch_decode(self, plan: DecodePlan):
        t0 = time.perf_counter()
        if self.use_terra:
            tok = (self._tf(plan.mask) if plan.bt is None
                   else self._tf(plan.mask, plan.bt))
            if isinstance(tok, TerraTensor):
                if self._tf.engine.mode != SKELETON:
                    # warmup: fetch now so the trace records the fetch
                    # point (§4.2) the lagged harvest relies on
                    tok = np.asarray(tok)
                elif tok._eager is None and tok._future is None:
                    tok = np.asarray(tok)   # mid-replay: fetch, not stale
        else:
            args = self._params_leaves + self._cache_leaves
            args += [self._pos, self._tokf, jnp.asarray(plan.mask)]
            if plan.bt is not None:
                args.append(jnp.asarray(plan.bt))
            if self._has_rng:
                args.append(self._next_key())
            outs = self._decode_jit(*args, **self._attrs)
            tok, self._pos, self._tokf = outs[0], outs[-2], outs[-1]
            self._cache_leaves = list(outs[1:-2])
        pairs = [(s, r) for s, r in self.pool.active_items() if plan.mask[s]]
        self.pool.advance_active(plan.mask)
        self.planner.consume(plan.mask)
        self.sched_stats["decode_steps"] += 1
        tm.step_done(self, "decode", int(plan.mask.sum()), t0)
        return ("decode", tok, pairs)

    def _dispatch_prefill(self, plan: PrefillPlan):
        t0 = time.perf_counter()
        self.sched_stats["prefill_steps"] += 1
        self.sched_stats["admitted"] += len(plan.requests)
        self.sched_stats["prefill_tokens"] += int(
            np.sum(plan.lengths[:len(plan.requests)]))
        tm.admitted(self.events, plan, self.clock())
        key = self._next_key() if self._has_rng else None
        frames = [jnp.asarray(plan.tokens), jnp.asarray(plan.slots),
                  jnp.asarray(plan.lengths)]
        if plan.bt_rows is not None:
            frames.append(jnp.asarray(plan.bt_rows))
        if not self.use_terra:
            args = self._params_leaves + self._cache_leaves
            args += [self._pos, self._tokf] + frames
            if key is not None:
                args.append(key)
            outs = self._prefill_jit(*args, **self._attrs)
            tok, self._pos, self._tokf = outs[0], outs[-2], outs[-1]
            self._cache_leaves = list(outs[1:-2])
            tm.step_done(self, "prefill", len(plan.requests), t0)
            return ("prefill", tok, plan)
        eng = self._tf.engine
        state_vars = self._cache_vars + [self._pos_var, self._tokf_var]
        if eng.mode != SKELETON:
            # warmup (tracing) path: ops still run on the Python thread,
            # so the out-of-band rebind (§8) is the correct splice
            bufs = self._params_leaves + [eng.variable_value(v)
                                          for v in state_vars]
            outs = self._prefill_jit(*(bufs + frames
                                       + ([key] if key is not None else [])),
                                     **self._attrs)
            for var, leaf in zip(state_vars, list(outs[1:-2]) + [outs[-2],
                                                                 outs[-1]]):
                eng.reset_variable(var, leaf)
            tok = np.asarray(outs[0])
        else:
            # co-execution: consume the pool Variables' device buffers in
            # place through a fenced GraphRunner closure (§12); no stall
            pjit, attrs, nc = self._prefill_jit, self._attrs, self._nc

            def splice(bufs):
                args = bufs + frames
                if key is not None:
                    args.append(key)
                outs = pjit(*args, **attrs)
                return tuple(outs[1:-2]) + (outs[-2], outs[-1], outs[0])

            tok = varops.submit_variable_update(
                eng, self._param_vars + state_vars, state_vars,
                splice, n_results=1)[0]
        tm.step_done(self, "prefill", len(plan.requests), t0)
        return ("prefill", tok, plan)

    # ------------------------------------------------------------------
    # harvest + delivery (one step behind dispatch)
    # ------------------------------------------------------------------
    def _harvest(self, entry) -> None:
        kind, payload, extra = entry
        t0 = time.perf_counter()
        toks = np.asarray(payload.result()) if isinstance(payload, Future) \
            else np.asarray(payload)
        tm.harvest_done(self, kind, t0)
        now = self.clock()
        if kind == "decode":
            for slot, req in extra:
                # a request retired by an earlier harvest may have been
                # dispatched one garbage step (lag): never deliver it
                if req.done or self.pool.requests[slot] is not req:
                    continue
                self._deliver(req, int(toks[slot, 0]), slot, now)
        else:
            for i, req in enumerate(extra.requests):
                self._deliver(req, int(toks[i, 0]), int(extra.slots[i]), now)

    def _deliver(self, req, token: int, slot: int, now: float) -> None:
        finished = record_token(req, token, now)
        self.sched_stats["generated_tokens"] += 1
        tm.request_token(self.events, req, token)
        self.callbacks.push(req, token)
        if finished:
            self.pool.release(slot)
            self.sched_stats["retired"] += 1
            tm.request_retire(self.events, req)
            self.planner.mark_dirty()

    def _idle(self, plan: IdlePlan) -> None:
        self.callbacks.flush()
        self.sched_stats["idle_waits"] += 1
        tm.idle(self.events, plan.wait)
        if plan.wait and plan.wait > 0:
            # the stream owns the clock semantics (real sleep vs. yield)
            self.events.sleep(min(plan.wait, 0.02))

    def _next_key(self):
        self._prefill_key, k = jax.random.split(self._prefill_key)
        return k
