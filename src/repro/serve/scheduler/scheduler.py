"""ContinuousBatchingScheduler: the serving main loop under co-execution.

The loop is an ordinary imperative Python program — arrival queue,
free-list slot pool, per-request retirement, streaming callbacks — and
that is the point: it runs as the skeleton program of a
``terra.function`` whose single DL op is the masked ``slot_decode`` step
(pool_ops.py).  Model parameters, the slot-pooled cache and the per-slot
position counters live as framework Variables, so state threads
GraphRunner-to-GraphRunner on device; the only value crossing the fetch
boundary per step is the ``[max_slots, 1]`` sampled-token frame, and the
loop flushes queued streaming callbacks *after* dispatching the next
step so Python bookkeeping overlaps device work (PR-2 per-value fences).

Admission runs *between* decode iterations: prompts are length-bucketed,
prefilled by the jitted ``serve.slot_prefill`` op, and spliced into the
pool Variables through ``TerraEngine.reset_variable`` — the documented
out-of-band rebind (DESIGN.md §8).  Because every leaf keeps its aval,
the engine's shape-class signature never changes: admission/retirement
churn stays inside ONE TraceGraph family, with zero retraces after
warmup (the bench gate).

``use_terra=False`` runs the identical step functions as plain donated
``jax.jit`` calls — the Terra-off scheduling baseline.
"""

from __future__ import annotations

import os
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import function as terra_function
from repro.core import ops as ops_mod
from repro.core.ops import op_impl
from repro.core.tensor import Variable
from repro.serve.scheduler import pool_ops
from repro.serve.scheduler.lifecycle import (ArrivalQueue, CallbackQueue,
                                             record_token)
from repro.serve.scheduler.planner import (DecodePlan, IdlePlan,
                                           PrefillPlan, StepPlanner)
from repro.serve.scheduler.slots import SlotPool

_STATIC = ("_meta", "_n_params", "_n_cache", "_has_rng")


class ContinuousBatchingScheduler:
    """Slot-pooled continuous-batching serving engine (DESIGN.md §11)."""

    def __init__(self, cfg, params, *, max_slots: int = 8,
                 max_len: int = 256, temperature: float = 0.0,
                 use_terra: bool = True, optimize: Optional[str] = None,
                 prefill_batch_cap: Optional[int] = None,
                 bucket_floor: int = 8,
                 clock: Callable[[], float] = time.perf_counter):
        pool_ops.check_supported(cfg)
        self.cfg = cfg
        self.max_len = max_len
        self.temperature = temperature
        self.use_terra = use_terra
        self.clock = clock
        self._has_rng = temperature > 0.0
        self._prefill_key = jax.random.PRNGKey(0)

        leaves0, cache_def, axes = pool_ops.build_pool_cache(
            cfg, max_slots, max_len)
        self._params_leaves, params_def = jax.tree_util.tree_flatten(params)
        self._np, self._nc = len(self._params_leaves), len(leaves0)
        self._mid = pool_ops.register_pool_meta(
            cfg, params_def, cache_def, axes, temperature, max_len)
        self._attrs = dict(_meta=self._mid, _n_params=self._np,
                           _n_cache=self._nc, _has_rng=self._has_rng)
        pos0 = jnp.zeros(max_slots, jnp.int32)

        if use_terra:
            # SAFE pipeline by default: the token/mask feeds change every
            # step and must never constant-fold (DESIGN.md §10);
            # $TERRA_OPTIMIZE stays honored as the kill-switch
            if optimize is None:
                optimize = os.environ.get("TERRA_OPTIMIZE") or "safe"
            self._param_vars = [Variable(l, name=f"sched.p{i}")
                                for i, l in enumerate(self._params_leaves)]
            self._cache_vars = [Variable(l, name=f"sched.c{i}")
                                for i, l in enumerate(leaves0)]
            self._pos_var = Variable(pos0, name="sched.pos")
            self._tf = terra_function(self._step, optimize=optimize)
            self._prefill_jit = jax.jit(op_impl("serve.slot_prefill"),
                                        static_argnames=_STATIC)
        else:
            self._cache_leaves = list(leaves0)
            self._pos = pos0
            # donate pool state for in-place buffer reuse, like the
            # lock-step baseline's donate-the-cache decode
            donate = tuple(range(self._np, self._np + self._nc + 1))
            self._decode_jit = jax.jit(op_impl("serve.slot_decode"),
                                       static_argnames=_STATIC,
                                       donate_argnums=donate)
            self._prefill_jit = jax.jit(op_impl("serve.slot_prefill"),
                                        static_argnames=_STATIC,
                                        donate_argnums=donate)

        self.pool = SlotPool(max_slots)
        self.queue = ArrivalQueue(clock)
        self.callbacks = CallbackQueue()
        self.planner = StepPlanner(cfg, self.queue, self.pool, max_len,
                                   prefill_batch_cap or max_slots,
                                   bucket_floor)
        self.sched_stats = {"admitted": 0, "retired": 0, "decode_steps": 0,
                            "prefill_steps": 0, "prefill_tokens": 0,
                            "generated_tokens": 0, "idle_waits": 0}

    # ------------------------------------------------------------------
    # public surface
    # ------------------------------------------------------------------
    def submit(self, request) -> None:
        L = len(request.prompt)
        if L < 1:
            raise ValueError("empty prompt")
        if L + request.max_new_tokens + 1 > self.max_len:
            raise ValueError(
                f"prompt ({L}) + max_new_tokens "
                f"({request.max_new_tokens}) exceeds pool max_len "
                f"{self.max_len}")
        self.queue.submit(request)

    def serve(self, requests: List[object]) -> List[object]:
        """Convenience: submit a batch and run until drained."""
        for r in requests:
            self.submit(r)
        self.run()
        return requests

    def run(self, max_steps: Optional[int] = None) -> None:
        """Serve until the queue is empty and every slot is free."""
        steps = 0
        while len(self.queue) or self.pool.active_count:
            plan = self.planner.next_plan(self.clock())
            if isinstance(plan, PrefillPlan):
                self._admit(plan)
            elif isinstance(plan, DecodePlan):
                self._decode(plan)
            else:
                self._idle(plan)
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        self.callbacks.flush()
        if self.use_terra:
            self._tf.wait()

    @property
    def stats(self) -> dict:
        out = dict(self.sched_stats)
        out["callbacks_delivered"] = self.callbacks.delivered
        if self.use_terra:
            out.update(self._tf.stats)
            out["phase"] = self._tf.phase
        return out

    def close(self) -> None:
        if self.use_terra:
            self._tf.close()

    # ------------------------------------------------------------------
    # step execution
    # ------------------------------------------------------------------
    def _step(self, tokens, mask):
        """The co-executed skeleton step: one masked slot_decode node."""
        args = [v.read() for v in self._param_vars]
        args += [v.read() for v in self._cache_vars]
        args += [self._pos_var.read(), tokens, mask]
        if self._has_rng:
            args.append(ops_mod._next_key())   # iteration-stable key feed
        outs = pool_ops.slot_decode(*args, **self._attrs)
        tok, leaves, new_pos = outs[0], outs[1:-1], outs[-1]
        for var, leaf in zip(self._cache_vars, leaves):
            var.assign(leaf)
        self._pos_var.assign(new_pos)
        return tok

    def _decode(self, plan: DecodePlan) -> None:
        if self.use_terra:
            tok_t = self._tf(plan.tokens, plan.mask)
        else:
            args = self._params_leaves + self._cache_leaves
            args += [self._pos, jnp.asarray(plan.tokens),
                     jnp.asarray(plan.mask)]
            if self._has_rng:
                args.append(self._next_key())
            outs = self._decode_jit(*args, **self._attrs)
            tok_t, leaves, self._pos = outs[0], outs[1:-1], outs[-1]
            self._cache_leaves = list(leaves)
        # overlap: stream callbacks queued by the PREVIOUS step run while
        # the step just dispatched executes on the GraphRunner/device
        self.callbacks.flush()
        toks = np.asarray(tok_t)               # the fetch boundary
        now = self.clock()
        self.pool.advance_active()
        self.sched_stats["decode_steps"] += 1
        for slot, req in self.pool.active_items():
            self._deliver(req, int(toks[slot, 0]), slot, now)

    def _admit(self, plan: PrefillPlan) -> None:
        if self.use_terra:
            eng = self._tf.engine
            leaves = [eng.variable_value(v) for v in self._cache_vars]
            pos = eng.variable_value(self._pos_var)
        else:
            leaves, pos = self._cache_leaves, self._pos
        args = self._params_leaves + list(leaves)
        args += [pos, jnp.asarray(plan.tokens), jnp.asarray(plan.slots),
                 jnp.asarray(plan.lengths)]
        if self._has_rng:
            args.append(self._next_key())
        outs = self._prefill_jit(*args, **self._attrs)
        tok, new_leaves, new_pos = outs[0], outs[1:-1], outs[-1]
        if self.use_terra:
            # out-of-band rebind between iterations: same avals, so the
            # engine keeps the same shape family — no retrace (§8)
            for var, leaf in zip(self._cache_vars, new_leaves):
                eng.reset_variable(var, leaf)
            eng.reset_variable(self._pos_var, new_pos)
        else:
            self._cache_leaves = list(new_leaves)
            self._pos = new_pos
        toks = np.asarray(tok)
        now = self.clock()
        self.sched_stats["prefill_steps"] += 1
        self.sched_stats["admitted"] += len(plan.requests)
        self.sched_stats["prefill_tokens"] += int(
            np.sum(plan.lengths[:len(plan.requests)]))
        for i, req in enumerate(plan.requests):
            self._deliver(req, int(toks[i, 0]), int(plan.slots[i]), now)

    def _deliver(self, req, token: int, slot: int, now: float) -> None:
        finished = record_token(req, token, now)
        self.sched_stats["generated_tokens"] += 1
        self.callbacks.push(req, token)
        if finished:
            self.pool.release(slot)
            self.sched_stats["retired"] += 1
        else:
            self.planner.tok_frame[slot, 0] = token

    def _idle(self, plan: IdlePlan) -> None:
        self.callbacks.flush()
        self.sched_stats["idle_waits"] += 1
        if plan.wait and plan.wait > 0:
            # only a real clock advances while we sleep; under an
            # injected (virtual) clock just yield and re-poll — sleeping
            # real time against a frozen clock would hang the loop
            if self.clock is time.perf_counter:
                time.sleep(min(plan.wait, 0.02))
            else:
                time.sleep(0)

    def _next_key(self):
        self._prefill_key, k = jax.random.split(self._prefill_key)
        return k
