"""Serving-side instrumentation: the scheduler's view of the EventStream.

The scheduler does not own a private counter dict or clock special-cases
any more (DESIGN.md §13): under co-execution it shares its engine's
EventStream — one substrate, one injected clock, one flat counter dict
merging ``engine.stats`` and the scheduler counters — and under
``use_terra=False`` it gets a fresh stream seeded with the same keys.
The helpers below fold the ``es.on`` hot-path predicate exactly like
``core.events.emit`` does for the executor; request-lifecycle events are
keyed by the ``rid`` the scheduler stamps at submission (a resubmitted
request starts a fresh lifecycle, so it gets a fresh rid).
"""

from __future__ import annotations

import time
from typing import Callable, Optional

from repro.core.events import EventStream
from repro.core.events import types as T

# counter keys the scheduler contributes to the shared stream; the same
# registry role executor/stats.py plays for the engine
SCHED_DEFAULTS = {
    "admitted": 0, "retired": 0, "decode_steps": 0, "prefill_steps": 0,
    "prefill_tokens": 0, "generated_tokens": 0, "idle_waits": 0,
    "step_dispatch_time": 0.0, "harvest_wait_time": 0.0,
}


def make_stream(engine_events: Optional[EventStream],
                clock: Callable[[], float]) -> EventStream:
    """The scheduler's stream: the engine's (use_terra — scheduler and
    engine counters unify into one dict) or a fresh one (baseline).  The
    scheduler's clock is injected once here; every event timestamp and
    every idle sleep decision flows from it."""
    es = engine_events if engine_events is not None else EventStream()
    es.seed(SCHED_DEFAULTS)
    es.set_clock(clock)
    return es


# --------------------------------------------------------------------------
# request lifecycle (submit -> admit -> prefill -> token* -> retire)
# --------------------------------------------------------------------------

def merged_stats(sch) -> dict:
    """The scheduler's flat ``stats`` view: the shared counter dict (which
    already holds the engine counters under co-execution), the callback /
    pool gauges, and the engine phase."""
    out = dict(sch.sched_stats)
    out["callbacks_delivered"] = sch.callbacks.delivered
    out["peak_resident_tokens"] = sch.pool.peak_resident_tokens
    if sch.use_terra:
        out.update(sch._tf.stats)
        out["phase"] = sch._tf.phase
    return out


def request_submit(es: EventStream, req, rid: int) -> None:
    req.rid = rid
    if es.on:
        es.emit(T.RequestSubmit(rid, len(req.prompt),
                                int(req.max_new_tokens)))


def admitted(es: EventStream, plan, now: float) -> None:
    """Admission events for one PrefillPlan: each real row gets an Admit
    (with its queueing delay) and a Prefill at the group's bucket."""
    if not es.on:
        return
    for i, req in enumerate(plan.requests):
        queued = max(0.0, now - (req.arrival_time or now))
        es.emit(T.RequestAdmit(req.rid, int(plan.slots[i]), queued))
        es.emit(T.RequestPrefill(req.rid, int(plan.bucket),
                                 len(req.prompt)))


def request_token(es: EventStream, req, token: int) -> None:
    if es.on:
        es.emit(T.RequestToken(req.rid, int(token),
                               len(req.out_tokens) - 1))


def request_retire(es: EventStream, req) -> None:
    if es.on:
        es.emit(T.RequestRetire(req.rid,
                                "eos" if req.done else "budget",
                                len(req.out_tokens)))


# --------------------------------------------------------------------------
# step loop
# --------------------------------------------------------------------------

def step_dispatch(es: EventStream, kind: str, rows: int, dur: float,
                  queue_depth: int = 0, resident: int = 0) -> None:
    if es.on:
        es.emit(T.StepDispatch(kind, rows, dur, int(queue_depth),
                               int(resident)))


def step_harvest(es: EventStream, kind: str, wait: float) -> None:
    if es.on:
        es.emit(T.StepHarvest(kind, wait))


def step_done(sch, kind: str, rows: int, t0: float) -> None:
    """Close one dispatch: accumulate the host-time counter and emit the
    StepDispatch event carrying the live queue-depth / resident-token
    gauges (the metrics registry samples them from here)."""
    dur = time.perf_counter() - t0
    sch.sched_stats["step_dispatch_time"] += dur
    step_dispatch(sch.events, kind, rows, dur,
                  len(sch.queue), sch.pool.resident_tokens)


def harvest_done(sch, kind: str, t0: float) -> None:
    wait = time.perf_counter() - t0
    sch.sched_stats["harvest_wait_time"] += wait
    step_harvest(sch.events, kind, wait)


# --------------------------------------------------------------------------
# observability surface (repro.obs, DESIGN.md §15)
# --------------------------------------------------------------------------

def set_profile(sch, every: int) -> None:
    """(Re)set the sampled device-time profiling cadence — mutable at
    runtime so a serving process can turn attribution on for a window
    and back off without restarting."""
    if sch.use_terra:
        sch._tf.engine.profile_every = int(every)


def enable_metrics(sch, registry=None):
    """Attach a live :class:`repro.obs.MetricsProcessor` to the
    scheduler's event stream; returns the registry (serve it with
    ``repro.obs.http.MetricsServer`` for Prometheus scrapes)."""
    from repro.obs import MetricsProcessor
    mp = MetricsProcessor(registry)
    mp.registry.attach_counters(sch.sched_stats)
    sch.events.attach(mp)
    sch.metrics = mp.registry
    return mp.registry


def idle(es: EventStream, wait) -> None:
    if es.on:
        es.emit(T.SchedulerIdle(float(wait or 0.0)))
