"""Slot bookkeeping for the pooled KV cache.

The pool's *device* state (cache leaves, per-slot position counters)
lives as framework Variables inside the scheduler; this module is the
pure-Python side: a free list, the slot -> request binding, and host
mirrors of the per-slot counters so the planner never has to fetch
device state to make a scheduling decision.  All of it is exactly the
kind of imperative per-request bookkeeping the co-execution runtime
exists to keep cheap (PAPER.md): it runs on the Python thread while the
GraphRunner executes the queued decode step.

With a :class:`~repro.serve.scheduler.paged.PagedLayout` attached, each
slot additionally owns a row of the host block table: admission reserves
``blocks_needed(prompt, budget)`` arena blocks (all-or-nothing),
retirement returns them and zeroes the row so any still-in-flight decode
write for the retired slot lands in the trash block (DESIGN.md §12).
Capacity is then bounded by tokens *resident*, not slots.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.serve.scheduler.paged import BlockAllocator, PagedLayout


class SlotPool:
    """Fixed pool of ``max_slots`` cache rows with free-list allocation.

    Slots are handed out lowest-index-first so replays of the same
    workload are deterministic; releasing a slot returns it to the pool
    immediately (the device row is only ever overwritten by the next
    prefill into it — no clearing pass is needed, stale entries beyond a
    row's position counter are masked at every read).
    """

    def __init__(self, max_slots: int, layout: Optional[PagedLayout] = None,
                 row_tokens: int = 0):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self._free: List[int] = list(range(max_slots))
        self.requests: List[Optional[object]] = [None] * max_slots
        # host mirror of the device position counters (prompt length +
        # generated tokens); authoritative for planning, never fetched
        self.pos = np.zeros(max_slots, np.int32)
        # dense pools reserve a full cache row per active slot; counting
        # ``row_tokens`` (the scheduler's max_len) per allocation makes
        # resident/peak tokens comparable with the paged arena's
        # block-granular accounting below
        self.row_tokens = row_tokens
        self.layout = layout
        self.allocator: Optional[BlockAllocator] = None
        self.block_table: Optional[np.ndarray] = None
        self.resident_tokens = 0
        self.peak_resident_tokens = 0
        if layout is not None:
            self.allocator = BlockAllocator(layout.num_blocks)
            self.block_table = np.zeros((max_slots, layout.nbps), np.int32)

    # ------------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_count(self) -> int:
        return self.max_slots - len(self._free)

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.requests], bool)

    def active_items(self):
        """(slot, request) pairs for every occupied slot, in slot order."""
        return [(i, r) for i, r in enumerate(self.requests) if r is not None]

    # ------------------------------------------------------------------
    def alloc(self, request, length: int) -> int:
        """Bind ``request`` to the lowest free slot; returns the slot id.

        Paged pools also reserve the request's block budget here —
        all-or-nothing, so a failed reservation leaves no partial state.
        Callers gate admission on :meth:`admit_checker`, making the
        RuntimeError a genuine invariant violation, not backpressure.
        """
        if not self._free:
            raise RuntimeError("slot pool exhausted")
        slot = min(self._free)
        if self.layout is not None:
            need = self.layout.blocks_needed(
                length, getattr(request, "max_new_tokens", 0))
            blocks = self.allocator.alloc(need)
            if blocks is None:
                raise RuntimeError(
                    f"block arena exhausted ({need} blocks needed, "
                    f"{self.allocator.free_count} free)")
            row = self.block_table[slot]
            row[:] = 0
            row[:need] = blocks
            self.resident_tokens += need * self.layout.block_size
        else:
            self.resident_tokens += self.row_tokens
        self.peak_resident_tokens = max(self.peak_resident_tokens,
                                        self.resident_tokens)
        self._free.remove(slot)
        self.requests[slot] = request
        self.pos[slot] = length
        return slot

    def release(self, slot: int) -> None:
        if self.requests[slot] is None:
            raise RuntimeError(f"double free of slot {slot}")
        if self.layout is not None:
            row = self.block_table[slot]
            blocks = [int(b) for b in row[row > 0]]
            self.allocator.free(blocks)
            row[:] = 0
            self.resident_tokens -= len(blocks) * self.layout.block_size
        else:
            self.resident_tokens -= self.row_tokens
        self.requests[slot] = None
        self._free.append(slot)

    def advance_active(self, mask: Optional[np.ndarray] = None) -> None:
        """Mirror one masked decode step: masked rows advance by one
        (default: every active row)."""
        if mask is None:
            mask = self.active_mask()
        self.pos += np.asarray(mask, bool).astype(np.int32)

    # ------------------------------------------------------------------
    def admit_checker(self):
        """Admission-capacity predicate for one planning pass, or None
        when the pool is dense (slots are the only capacity axis).

        The returned closure is *stateful*: each accepted request
        decrements the remaining block budget, so a single admission
        group can never overcommit the arena."""
        if self.layout is None:
            return None
        remaining = self.allocator.free_count
        layout = self.layout

        def fits(req) -> bool:
            nonlocal remaining
            need = layout.blocks_needed(len(req.prompt), req.max_new_tokens)
            if need > remaining:
                return False
            remaining -= need
            return True

        return fits
