"""Slot bookkeeping for the pooled KV cache.

The pool's *device* state (cache leaves, per-slot position counters)
lives as framework Variables inside the scheduler; this module is the
pure-Python side: a free list, the slot -> request binding, and host
mirrors of the per-slot counters so the planner never has to fetch
device state to make a scheduling decision.  All of it is exactly the
kind of imperative per-request bookkeeping the co-execution runtime
exists to keep cheap (PAPER.md): it runs on the Python thread while the
GraphRunner executes the queued decode step.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np


class SlotPool:
    """Fixed pool of ``max_slots`` cache rows with free-list allocation.

    Slots are handed out lowest-index-first so replays of the same
    workload are deterministic; releasing a slot returns it to the pool
    immediately (the device row is only ever overwritten by the next
    prefill into it — no clearing pass is needed, stale entries beyond a
    row's position counter are masked at every read).
    """

    def __init__(self, max_slots: int):
        if max_slots < 1:
            raise ValueError("max_slots must be >= 1")
        self.max_slots = max_slots
        self._free: List[int] = list(range(max_slots))
        self.requests: List[Optional[object]] = [None] * max_slots
        # host mirror of the device position counters (prompt length +
        # generated tokens); authoritative for planning, never fetched
        self.pos = np.zeros(max_slots, np.int32)

    # ------------------------------------------------------------------
    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def active_count(self) -> int:
        return self.max_slots - len(self._free)

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.requests], bool)

    def active_items(self):
        """(slot, request) pairs for every occupied slot, in slot order."""
        return [(i, r) for i, r in enumerate(self.requests) if r is not None]

    # ------------------------------------------------------------------
    def alloc(self, request, length: int) -> int:
        """Bind ``request`` to the lowest free slot; returns the slot id."""
        if not self._free:
            raise RuntimeError("slot pool exhausted")
        slot = min(self._free)
        self._free.remove(slot)
        self.requests[slot] = request
        self.pos[slot] = length
        return slot

    def release(self, slot: int) -> None:
        if self.requests[slot] is None:
            raise RuntimeError(f"double free of slot {slot}")
        self.requests[slot] = None
        self._free.append(slot)

    def advance_active(self) -> None:
        """Mirror one masked decode step: active rows advance by one."""
        self.pos += self.active_mask().astype(np.int32)
