"""Request lifecycle: arrivals, admission grouping, retirement, streaming.

This is deliberately plain imperative Python — timestamped queues,
per-request counters, third-party streaming callbacks — i.e. the program
class the paper argues must keep running under the Python interpreter
(coverage argument, PAPER.md): none of it is expressible inside the
symbolic graph, and none of it needs to be, because only the sampled
tokens cross the fetch boundary each step.

Streaming callbacks are the repo's third-party-code stand-in: the
scheduler queues them as tokens are fetched and flushes the queue right
*after* dispatching the next decode step, so user callback time overlaps
queued device work (PR-2 per-value fences) instead of stalling the loop.
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional, Tuple

from repro.core.executor.families import bucket_pow2
from repro.serve.scheduler.pool_ops import pads_allowed


def bucket_len(cfg, length: int, max_len: int, floor: int = 8) -> int:
    """Length bucket a prompt prefills at.  Attention-only stacks pad to
    the next power-of-two cell (bounding prefill compile variants to
    O(log max_len)); recurrent stacks fold *every* position into their
    state, so padding would corrupt it — they prefill at exact length."""
    if not pads_allowed(cfg):
        return length
    return min(bucket_pow2(length, floor), max_len)


class ArrivalQueue:
    """Timestamped FIFO of submitted requests (arrival order preserved)."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._queue: List[object] = []
        self.submitted = 0

    def __len__(self) -> int:
        return len(self._queue)

    def submit(self, request) -> None:
        if request.arrival_time is None:
            request.arrival_time = self.clock()
        # re-submission starts a fresh lifecycle: stale timestamps would
        # otherwise survive record_token's stamp-once guards
        request.out_tokens = None
        request.done = False
        request.first_token_time = None
        request.finish_time = None
        self._queue.append(request)
        self.submitted += 1

    def next_arrival(self) -> Optional[float]:
        return min((r.arrival_time for r in self._queue), default=None)

    def pop_admission(self, now: float, free_slots: int, cfg, max_len: int,
                      batch_cap: int, bucket_floor: int = 8, fits=None):
        """One admission group: the earliest-arrived admissible request
        fixes the length bucket; every other admissible request of the
        same bucket joins, in arrival order, up to min(free slots,
        batch_cap).  Returns (bucket, [requests]) or None.

        ``fits`` (paged pools, SlotPool.admit_checker) is a stateful
        capacity predicate.  A head-of-line request that does not fit
        blocks the whole admission — FIFO is preserved, backpressure is
        queue-and-wait; a later group member that does not fit is merely
        skipped (it would strand capacity the head already reserved)."""
        limit = min(free_slots, batch_cap)
        if limit <= 0:
            return None
        ready = sorted((r for r in self._queue if r.arrival_time <= now),
                       key=lambda r: r.arrival_time)
        if not ready:
            return None
        if fits is not None and not fits(ready[0]):
            return None
        bucket = bucket_len(cfg, len(ready[0].prompt), max_len,
                            bucket_floor)
        group: List[object] = []
        for r in ready:
            if len(group) >= limit:
                break
            if bucket_len(cfg, len(r.prompt), max_len,
                          bucket_floor) != bucket:
                continue
            if group and fits is not None and not fits(r):
                continue
            group.append(r)
        taken = {id(r) for r in group}
        self._queue = [r for r in self._queue if id(r) not in taken]
        return bucket, group


# --------------------------------------------------------------------------
# Retirement + streaming
# --------------------------------------------------------------------------

def record_token(request, token: int, now: float) -> bool:
    """Append one generated token; returns True when the request is
    finished (EOS or token budget) and should release its slot.  Mirrors
    the lock-step engine's retirement rule exactly (token-equality is a
    bench gate)."""
    if request.out_tokens is None:
        request.out_tokens = []
        request.first_token_time = now
    request.out_tokens.append(int(token))
    if int(token) == request.eos_id:
        request.done = True
    finished = request.done or len(request.out_tokens) >= \
        request.max_new_tokens
    if finished and request.finish_time is None:
        request.finish_time = now
    return finished


class CallbackQueue:
    """Deferred per-token streaming callbacks.

    ``push`` is called as tokens come off the fetch boundary; ``flush``
    runs the queued callbacks — the scheduler flushes *after* submitting
    the next step, so arbitrary third-party callback code executes while
    the GraphRunner works.  Callback exceptions propagate to the caller
    of flush (user code failing is a user error, not a scheduler state)."""

    def __init__(self):
        self._queue: List[Tuple[Callable, object, int, int]] = []
        self.delivered = 0

    def __len__(self) -> int:
        return len(self._queue)

    def push(self, request, token: int) -> None:
        if request.stream is not None:
            idx = len(request.out_tokens) - 1
            self._queue.append((request.stream, request, token, idx))

    def flush(self) -> None:
        queued, self._queue = self._queue, []
        try:
            while queued:
                cb, req, tok, idx = queued.pop(0)
                cb(req, tok, idx)
                self.delivered += 1
        finally:
            # a raising callback loses only its own delivery: everything
            # still queued (other requests' tokens) goes back in front
            self._queue[:0] = queued
