"""Continuous-batching serving scheduler with a slot-pooled KV cache.

    scheduler.py  — ContinuousBatchingScheduler, the co-executed main loop
    planner.py    — prefill-vs-decode step planning + fixed-shape frames
    slots.py      — SlotPool free-list allocation + host position mirrors
    paged.py      — paged arena layout + block-table allocation
    lifecycle.py  — arrivals, length bucketing, retirement, streaming
    pool_ops.py   — serve.slot_prefill / serve.slot_decode DL operations
    checkpoint.py — quiescent checkpoint/restore for exact continuation

See DESIGN.md §11/§12 for the architecture and shape-stability argument.
"""

from repro.serve.scheduler.lifecycle import (ArrivalQueue, CallbackQueue,
                                             bucket_len, record_token)
from repro.serve.scheduler.paged import BlockAllocator, PagedLayout
from repro.serve.scheduler.planner import (DecodePlan, IdlePlan,
                                           PrefillPlan, StepPlanner)
from repro.serve.scheduler.pool_ops import (build_pool_cache,
                                            check_supported, pads_allowed,
                                            slot_decode, slot_prefill)
from repro.serve.scheduler.scheduler import ContinuousBatchingScheduler
from repro.serve.scheduler.slots import SlotPool

__all__ = [
    "ContinuousBatchingScheduler", "SlotPool", "StepPlanner",
    "ArrivalQueue", "CallbackQueue", "PrefillPlan", "DecodePlan",
    "IdlePlan", "bucket_len", "record_token", "build_pool_cache",
    "check_supported", "pads_allowed", "slot_prefill", "slot_decode",
    "PagedLayout", "BlockAllocator",
]
