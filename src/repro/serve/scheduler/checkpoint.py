"""Scheduler checkpoint/restore: exact continuation of in-flight serving
(DESIGN.md §14).

A checkpoint captures a *quiescent* ContinuousBatchingScheduler — the
state between ``run()`` calls, when no lagged step is in flight — as one
directory: ``pool.npz`` holds the device-resident pool state (cache
leaves, position counters, sampled-token frame) plus the host planning
arrays, and ``sched.json`` holds the constructor recipe and the request
lifecycle (in-flight slot bindings, queued arrivals, planner budgets,
request ids).  ``restore_scheduler`` rebuilds the scheduler in a fresh
process and resumes decoding with exactly the greedy tokens the donor
process would have produced (the cross-process bench/test gate).

Model *parameters* are deliberately not persisted — the caller passes
them to ``restore`` just as to the constructor (they are checkpointed by
training, not by serving).  Timestamps are stored as ages relative to
the donor's clock and rebased onto the restoring clock, so latency
accounting stays monotone on the new clock.
"""

from __future__ import annotations

import json
import os
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.events import emit as ev
from repro.core.persist.checkpoint import pack_arrays, unpack_array
from repro.serve.engine import Request
from repro.serve.scheduler.telemetry import SCHED_DEFAULTS

FORMAT = 1


def _req_to_dict(req, now: float) -> dict:
    return {"prompt": [int(t) for t in np.asarray(req.prompt).ravel()],
            "max_new": int(req.max_new_tokens),
            "eos": int(req.eos_id),
            "rid": req.rid,
            "out": None if req.out_tokens is None
            else [int(t) for t in req.out_tokens],
            "done": bool(req.done),
            "age": max(0.0, now - (req.arrival_time or now)),
            "first_age": None if req.first_token_time is None
            else max(0.0, now - req.first_token_time)}


def _req_from_dict(d: dict, now: float) -> Request:
    req = Request(prompt=np.asarray(d["prompt"], np.int32),
                  max_new_tokens=int(d["max_new"]),
                  eos_id=int(d["eos"]),
                  arrival_time=now - float(d["age"]))
    req.rid = d["rid"]
    req.out_tokens = None if d["out"] is None else [int(t) for t in d["out"]]
    req.done = bool(d["done"])
    if d["first_age"] is not None:
        req.first_token_time = now - float(d["first_age"])
    return req


def _state_arrays(sch) -> dict:
    """Pool device state, path-independently ordered: cache leaves in
    registration order, then the position and token-frame rows."""
    if sch.use_terra:
        eng = sch._tf.engine
        svars = sch._cache_vars + [sch._pos_var, sch._tokf_var]
        return {f"s{i}": np.asarray(eng.variable_value(v))
                for i, v in enumerate(svars)}
    leaves = sch._cache_leaves + [sch._pos, sch._tokf]
    return {f"s{i}": np.asarray(x) for i, x in enumerate(leaves)}


def save_scheduler(sch, path: str) -> None:
    """Write one checkpoint directory; requires a quiescent scheduler."""
    if sch._pending is not None:
        raise RuntimeError("checkpoint requires a quiescent scheduler "
                           "(call between run() invocations)")
    if sch.use_terra:
        sch._tf.wait()
    os.makedirs(path, exist_ok=True)
    now = sch.clock()
    arrays = _state_arrays(sch)
    arrays["prefill_key"] = np.asarray(sch._prefill_key)
    arrays["pool_pos"] = np.asarray(sch.pool.pos)
    arrays["budget"] = np.asarray(sch.planner.budget)
    if sch.pool.block_table is not None:
        arrays["block_table"] = np.asarray(sch.pool.block_table)
    tmp = os.path.join(path, f"pool.tmp{os.getpid()}.npz")
    np.savez(tmp, **pack_arrays(arrays))
    os.replace(tmp, os.path.join(path, "pool.npz"))
    slots = [[s, _req_to_dict(r, now)] for s, r in sch.pool.active_items()]
    meta = {"fmt": FORMAT, "ctor": dict(sch._ckpt_kw),
            "rid": sch._rid, "submitted": sch.queue.submitted,
            "engine_iter_id": (sch._tf.engine.iter_id
                               if sch.use_terra else -1),
            "resident_tokens": sch.pool.resident_tokens,
            "peak_resident_tokens": sch.pool.peak_resident_tokens,
            "slots": slots,
            "queue": [_req_to_dict(r, now) for r in sch.queue._queue],
            "counters": {k: sch.sched_stats[k] for k in SCHED_DEFAULTS}}
    tmp = os.path.join(path, f"sched.json.tmp{os.getpid()}")
    with open(tmp, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, os.path.join(path, "sched.json"))
    sch.sched_stats["checkpoint_saves"] = \
        sch.sched_stats.get("checkpoint_saves", 0) + 1
    ev.checkpoint_save(sch.events, path, vars_saved=len(arrays),
                       requests=len(slots) + len(meta["queue"]))


def restore_scheduler(cls, path: str, cfg, params, *,
                      clock=None, **overrides):
    """Rebuild a scheduler from ``save_scheduler`` output.  ``overrides``
    update the persisted constructor kwargs (e.g. a different
    ``steady_state``); shape-bearing ones must match the donor's."""
    with open(os.path.join(path, "sched.json")) as f:
        meta = json.load(f)
    if meta.get("fmt") != FORMAT:
        raise ValueError(f"unsupported scheduler checkpoint {path}")
    kw = dict(meta["ctor"])
    kw.update(overrides)
    if clock is not None:
        kw["clock"] = clock
    sch = cls(cfg, params, **kw)
    z = np.load(os.path.join(path, "pool.npz"))
    now = sch.clock()
    n = sch._nc
    state = [jnp.asarray(unpack_array(z, f"s{i}")) for i in range(n + 2)]
    if sch.use_terra:
        eng = sch._tf.engine
        for var, buf in zip(sch._cache_vars + [sch._pos_var, sch._tokf_var],
                            state):
            eng.reset_variable(var, buf)
        eng.iter_id = int(meta["engine_iter_id"])
    else:
        sch._cache_leaves = state[:n]
        sch._pos, sch._tokf = state[n], state[n + 1]
    sch._prefill_key = jnp.asarray(unpack_array(z, "prefill_key"))
    pool = sch.pool
    pool.pos[:] = unpack_array(z, "pool_pos")
    if "block_table" in z.files and pool.block_table is not None:
        pool.block_table[:] = unpack_array(z, "block_table")
        used = {int(b) for b in pool.block_table.ravel() if b > 0}
        pool.allocator._free = [b for b in range(1, pool.allocator.num_blocks)
                                if b not in used]
    # dense pools count reserved rows too (row_tokens), so the resident
    # gauge restores on both layouts
    pool.resident_tokens = int(meta["resident_tokens"])
    pool.peak_resident_tokens = int(meta["peak_resident_tokens"])
    for slot, rd in meta["slots"]:
        req = _req_from_dict(rd, now)
        pool.requests[slot] = req
        pool._free.remove(slot)
    for rd in meta["queue"]:
        sch.queue._queue.append(_req_from_dict(rd, now))
    sch.queue.submitted = int(meta["submitted"])
    sch.planner.budget[:] = unpack_array(z, "budget")
    sch.planner.mark_dirty()
    sch._rid = int(meta["rid"])
    for k, v in meta["counters"].items():
        if k in SCHED_DEFAULTS:
            sch.sched_stats[k] = v
    sch.sched_stats["checkpoint_restores"] = \
        sch.sched_stats.get("checkpoint_restores", 0) + 1
    ev.checkpoint_restore(sch.events, path, vars_restored=n + 2,
                          requests=len(meta["slots"]) + len(meta["queue"]))
    return sch
