"""Paged KV-cache layout: fixed-size blocks + per-slot block tables.

The dense pool stores `[max_slots, max_len]` cache rows, so memory
scales with the *worst case* of every slot.  The paged pool (DESIGN.md
§12) stores a flat arena of `num_blocks` fixed-size blocks and gives
each slot a block table `[nbps]` mapping logical block index -> arena
block id.  Admission capacity is then bounded by *tokens resident*
(prompt + generation budget), not by `max_slots x max_len`.

Block 0 is reserved as the trash block: released slots have their block
table zeroed, so a decode step that is still in flight for a retired
slot (the scheduler runs one step deep) scatters its garbage write into
block 0, which is never read.  The same trick absorbs the one garbage
step a slot executes after its EOS is detected one harvest late — the
`+ 1` in ``blocks_needed`` reserves room for that write so it can never
land in another request's block.
"""

from __future__ import annotations

from typing import List, Optional


class PagedLayout:
    """Static geometry of the paged arena."""

    def __init__(self, block_size: int, num_blocks: int, max_len: int):
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        if max_len % block_size:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of "
                f"block_size ({block_size})")
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is trash)")
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_len = max_len
        # blocks-per-slot: block-table width (logical address space)
        self.nbps = max_len // block_size

    def blocks_needed(self, length: int, max_new: int) -> int:
        """Blocks to reserve for a request: prompt + generation budget
        + 1 position for the post-EOS garbage decode step."""
        tokens = length + max_new + 1
        return -(-tokens // self.block_size)        # ceil division


class BlockAllocator:
    """Free-list allocator over the arena; block 0 is never handed out.

    Allocation is all-or-nothing (``alloc`` returns None when the pool
    cannot cover the request) so admission backpressure is a clean
    queue-and-wait, never a partial grant.  Lowest-index-first keeps
    replays of the same workload deterministic, mirroring SlotPool.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is trash)")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(1, num_blocks))

    @property
    def capacity(self) -> int:
        """Total allocatable blocks (arena minus the trash block)."""
        return self.num_blocks - 1

    @property
    def free_count(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` blocks, lowest-first; None if they don't all fit."""
        if n < 0:
            raise ValueError("cannot allocate a negative block count")
        if n > len(self._free):
            return None
        self._free.sort()
        taken, self._free = self._free[:n], self._free[n:]
        return taken

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b <= 0 or b >= self.num_blocks:
                raise ValueError(f"block id {b} outside arena")
            if b in self._free:
                raise RuntimeError(f"double free of block {b}")
        self._free.extend(blocks)
