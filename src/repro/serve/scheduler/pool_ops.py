"""Slot-pool DL operations: ``serve.slot_prefill`` / ``serve.slot_decode``.

The continuous-batching scheduler keeps one fixed KV/recurrent cache for
the whole engine lifetime; requests borrow slots and return them at
retirement.  Both pool mutations are registered DL ops (core op registry,
DESIGN.md §2 granularity), so under Terra co-execution they land in the
TraceGraph as single nodes whose input/output leaves are the pool cache
Variables:

* ``serve.slot_prefill`` — run the model over a length-bucketed prompt
  batch against a *fresh* batch-local cache, sample the first token at
  each row's true last position, then scatter the batch rows into the
  pool at the assigned slot indices and set the per-slot position
  counters to the prompt lengths.
* ``serve.slot_decode`` — one masked decode step over *all* slots: each
  row attends at its own position (vector ``cache["len"]``, see
  models/attention.py), the new K/V lands at that row's position, and
  only *active* rows advance their counter / produce a real token.
  Inactive rows compute garbage that stays beyond their valid length —
  masked at every future read and overwritten by the next prefill into
  that slot — so slot churn never changes the op's shape.

The sampled-token frame ``tokf`` [max_slots, 1] is threaded *on device*:
decode embeds it directly and writes the frame for the next step
(``where(mask, tok, tokf)``); prefill scatters each admitted row's first
token into it.  The host therefore never needs step N's token to
dispatch step N+1 — the scheduler fetches the token frame one step late,
purely for delivery (DESIGN.md §12).

Paged mode (``page_size > 0``): attention K/V leaves become flat block
arenas ``[num_blocks, page_size, Hkv, D]`` addressed through a per-slot
block table ``bt`` [max_slots, nbps] fed each step; recurrent leaves
(O(1) state per slot) stay dense.  Prefill scatters whole bucket rows
block-wise through the admitted rows' tables (``bt_rows`` [b, nbps]).

Pytrees are flattened at the op boundary; a meta registry keeps the
(static) treedefs and per-leaf scatter axes out of band.
"""

from __future__ import annotations

import dataclasses
from typing import Any, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.ops import def_op
from repro.models import layers as L
from repro.models import model as M
from repro.models import transformer as T
from repro.serve.meta import MetaRegistry

# kinds whose cache reads tolerate right-padding (garbage entries beyond
# the valid length are masked out by the attention valid-length mask);
# recurrent kinds fold every position into their state, so their prompts
# must be admitted at exact length (no padding)
PAD_SAFE_KINDS = ("attn", "attn_swa", "attn_local", "moe")
RECURRENT_KINDS = ("ssd", "rglru")


def check_supported(cfg) -> None:
    """The slot pool supports self-attention and recurrent decoder stacks;
    encoder/cross-attention families need per-request side inputs that the
    pooled step has no lane for yet — the lock-step engine serves those."""
    kinds = tuple(cfg.block_pattern) + tuple(cfg.extra_blocks)
    bad = [k for k in kinds if k not in PAD_SAFE_KINDS + RECURRENT_KINDS]
    if bad or cfg.enc_layers:
        raise NotImplementedError(
            f"slot-pooled scheduling does not support {cfg.name}: block "
            f"kinds {bad or ['encoder']} need per-request cross/frontend "
            "state; use ServingEngine.run_batch for this family")


def pads_allowed(cfg) -> bool:
    """True when prompts may be right-padded to their length bucket."""
    kinds = tuple(cfg.block_pattern) + tuple(cfg.extra_blocks)
    return all(k in PAD_SAFE_KINDS for k in kinds)


def build_pool_cache(cfg, max_slots: int, max_len: int, page_size: int = 0,
                     num_blocks: int = 0):
    """Zero-initialised pool cache: ``init_cache`` minus the scalar
    ``len`` (replaced by the per-slot position vector).  Returns
    (leaves, treedef, batch_axes, paged): ``batch_axes[i]`` is the slot
    axis of leaf i — scanned layer caches carry a leading
    n_pattern_blocks axis, extra-block caches do not — and ``paged[i]``
    marks leaves laid out as block arenas instead of slot rows."""
    dt = jnp.dtype(cfg.dtype)

    def slot(kind, nb):
        if page_size and kind in PAD_SAFE_KINDS:
            Hkv, D = cfg.n_kv_heads, cfg.head_dim
            shp = (num_blocks, page_size, Hkv, D)
            shp = (nb,) + shp if nb is not None else shp
            return {"kp": jnp.zeros(shp, dt), "vp": jnp.zeros(shp, dt)}
        return M._slot_cache(cfg, kind, nb, max_slots, max_len)

    nb = cfg.n_pattern_blocks
    tmpl = {"layers": [slot(k, nb) for k in cfg.block_pattern],
            "extra": [slot(k, None) for k in cfg.extra_blocks]}
    axes_tree = {"layers": jax.tree.map(lambda _: 1, tmpl["layers"]),
                 "extra": jax.tree.map(lambda _: 0, tmpl["extra"])}

    def pg_tree(kind, sub):
        flag = bool(page_size) and kind in PAD_SAFE_KINDS
        return jax.tree.map(lambda _: flag, sub)

    pg = {"layers": [pg_tree(k, s)
                     for k, s in zip(cfg.block_pattern, tmpl["layers"])],
          "extra": [pg_tree(k, s)
                    for k, s in zip(cfg.extra_blocks, tmpl["extra"])]}
    leaves, treedef = jax.tree_util.tree_flatten(tmpl)
    axes = jax.tree_util.tree_leaves(axes_tree)
    paged = jax.tree_util.tree_leaves(pg)
    return leaves, treedef, tuple(axes), tuple(paged)


def _flatten_cache(cache) -> List[Any]:
    """Flatten a run_stack cache pytree in pool-leaf order (minus len)."""
    return jax.tree_util.tree_leaves({"layers": cache["layers"],
                                      "extra": cache["extra"]})


# --------------------------------------------------------------------------
# Meta registry: static treedefs/axes keyed by an attribute-sized id
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PoolMeta:
    cfg: Any
    params_def: Any
    cache_def: Any
    batch_axes: Tuple[int, ...]
    temperature: float
    max_len: int
    page_size: int = 0
    num_blocks: int = 0
    paged: Tuple[bool, ...] = ()


_META = MetaRegistry()


def register_pool_meta(cfg, params_def, cache_def, batch_axes,
                       temperature: float, max_len: int, page_size: int = 0,
                       num_blocks: int = 0, paged=()) -> int:
    return _META.register(PoolMeta(cfg, params_def, cache_def,
                                   tuple(batch_axes), float(temperature),
                                   int(max_len), int(page_size),
                                   int(num_blocks), tuple(paged)))


def pool_meta(mid: int) -> PoolMeta:
    return _META.get(mid)


# --------------------------------------------------------------------------
# Pure step bodies
# --------------------------------------------------------------------------

def _sample(logits, temperature: float, rng):
    if temperature > 0.0 and rng is not None:
        tok = jax.random.categorical(
            rng, logits.astype(jnp.float32) / temperature, axis=-1)
    else:
        tok = jnp.argmax(logits, axis=-1)
    return tok.astype(jnp.int32)


def _head_logits(cfg, params, x2d):
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.unembed(x2d, head)


def _pool_prefill(meta: PoolMeta, params, cache_leaves, pos, tokf, tokens,
                  slots, lengths, bt_rows, rng):
    """tokens [b, S] (padded to the bucket), slots/lengths [b] int32 ->
    (first token [b, 1], scattered pool leaves, updated pos, tokf)."""
    cfg = meta.cfg
    B, S = tokens.shape
    # batch-local cache at the pool's max_len: bit-identical math to the
    # lock-step prefill (same shapes through run_stack), scattered whole-row
    fresh = M.init_cache(cfg, B, meta.max_len)
    x = L.embed(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    x, fresh = T.run_stack(cfg, params, x, positions=jnp.arange(S)[None],
                           caches=fresh)
    x = T._norm(cfg, params["final_norm"], x)                  # [b, S, d]
    last = jnp.take_along_axis(
        x, (lengths - 1).astype(jnp.int32)[:, None, None], axis=1)[:, 0]
    tok = _sample(_head_logits(cfg, params, last), meta.temperature, rng)

    bs = meta.page_size
    new_leaves = []
    for pool_leaf, b_leaf, ax, pg in zip(cache_leaves, _flatten_cache(fresh),
                                         meta.batch_axes, meta.paged):
        b_leaf = b_leaf.astype(pool_leaf.dtype)
        if pg:
            # block-wise scatter of the dense bucket rows through the
            # admitted rows' block tables; unassigned table tail entries
            # are 0 -> the trash block (never read)
            if ax == 0:
                r = b_leaf.reshape((B, b_leaf.shape[1] // bs, bs)
                                   + b_leaf.shape[2:])
                new_leaves.append(pool_leaf.at[bt_rows].set(r))
            else:
                nb_ = b_leaf.shape[0]
                r = b_leaf.reshape((nb_, B, b_leaf.shape[2] // bs, bs)
                                   + b_leaf.shape[3:])
                new_leaves.append(pool_leaf.at[:, bt_rows].set(r))
        elif ax == 0:
            new_leaves.append(pool_leaf.at[slots].set(b_leaf))
        else:
            new_leaves.append(pool_leaf.at[:, slots].set(b_leaf))
    new_pos = pos.at[slots].set(lengths.astype(pos.dtype))
    new_tokf = tokf.at[slots].set(tok[:, None])
    return (tok[:, None],) + tuple(new_leaves) + (new_pos, new_tokf)


def _pool_decode(meta: PoolMeta, params, cache_leaves, pos, tokf,
                 mask, bt, rng):
    """tokf [max_slots, 1], pos/mask [max_slots] -> (this step's token,
    updated pool leaves, advanced pos, next-step token frame).  One fixed
    shape class forever."""
    cfg = meta.cfg
    cache = jax.tree_util.tree_unflatten(meta.cache_def, cache_leaves)
    caches = {"layers": cache["layers"], "extra": cache["extra"],
              "len": pos}
    if bt is not None:
        caches["bt"] = bt
    x = L.embed(params["embed"], tokf).astype(jnp.dtype(cfg.dtype))
    x, new_caches = T.run_stack(cfg, params, x, positions=pos[:, None],
                                caches=caches)
    x = T._norm(cfg, params["final_norm"], x)
    tok = _sample(_head_logits(cfg, params, x[:, 0]), meta.temperature, rng)
    tok = jnp.where(mask, tok, 0)[:, None]
    new_pos = pos + mask.astype(pos.dtype)
    new_tokf = jnp.where(mask[:, None], tok, tokf)
    return (tok,) + tuple(_flatten_cache(new_caches)) + (new_pos, new_tokf)


# --------------------------------------------------------------------------
# Registered DL ops (flat-leaf boundary)
# --------------------------------------------------------------------------

def _split(leaves, n_params: int, n_cache: int, meta_id: int):
    meta = _META.get(meta_id)
    params = jax.tree_util.tree_unflatten(meta.params_def,
                                          leaves[:n_params])
    cache_leaves = list(leaves[n_params:n_params + n_cache])
    rest = list(leaves[n_params + n_cache:])
    return meta, params, cache_leaves, rest


def _slot_prefill_impl(*leaves, _meta: int, _n_params: int, _n_cache: int,
                       _has_rng: bool):
    meta, params, cache_leaves, rest = _split(leaves, _n_params, _n_cache,
                                              _meta)
    pos, tokf, tokens, slots, lengths = rest[:5]
    rest = rest[5:]
    bt_rows = rest.pop(0) if meta.page_size else None
    rng = rest[0] if _has_rng else None
    return _pool_prefill(meta, params, cache_leaves, pos, tokf, tokens,
                         slots, lengths, bt_rows, rng)


def _slot_decode_impl(*leaves, _meta: int, _n_params: int, _n_cache: int,
                      _has_rng: bool):
    meta, params, cache_leaves, rest = _split(leaves, _n_params, _n_cache,
                                              _meta)
    pos, tokf, mask = rest[:3]
    rest = rest[3:]
    bt = rest.pop(0) if meta.page_size else None
    rng = rest[0] if _has_rng else None
    return _pool_decode(meta, params, cache_leaves, pos, tokf, mask, bt, rng)


def _slot_decode_kernel_impl(*leaves, **attrs):
    """Paged decode with the Pallas paged-attention kernel enabled; the
    flag is read at trace time, so the substituted node compiles the
    kernel path while the math (and the op signature) stays identical."""
    from repro.models import attention as A
    prev, A.PAGED_KERNEL = A.PAGED_KERNEL, True
    try:
        return _slot_decode_impl(*leaves, **attrs)
    finally:
        A.PAGED_KERNEL = prev


slot_prefill = def_op("serve.slot_prefill", _slot_prefill_impl)
slot_decode = def_op("serve.slot_decode", _slot_decode_impl)
