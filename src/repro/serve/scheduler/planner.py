"""Step planner: choose prefill-vs-decode each loop iteration and build
the fixed-shape device frames for the chosen step.

Policy (vLLM-style continuous batching, prefill-priority): whenever free
slots exist and admissible requests are queued, the next step is an
admission prefill — new requests start generating between decode steps
instead of waiting for the batch to drain; otherwise a masked decode
step over the whole pool; otherwise idle until the next arrival.

Frames are built so that device-facing shapes stay bounded:

* decode is always ``[max_slots, 1]`` + mask — one shape class forever;
* prefill pads the prompt rows to the group's length bucket and the row
  *count* to a power of two by repeating the last real row (a duplicate
  scatter writes identical values — deterministic), so prefill compile
  variants stay O(log slots * log max_len).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.executor.families import bucket_pow2


@dataclasses.dataclass
class PrefillPlan:
    requests: List[object]          # real (non-pad) rows, admission order
    bucket: int                     # padded prompt length
    tokens: np.ndarray              # [b_pow2, bucket] int32
    slots: np.ndarray               # [b_pow2] int32 (pads repeat the last)
    lengths: np.ndarray             # [b_pow2] int32 true prompt lengths


@dataclasses.dataclass
class DecodePlan:
    tokens: np.ndarray              # [max_slots, 1] int32 last sampled
    mask: np.ndarray                # [max_slots] bool active rows


@dataclasses.dataclass
class IdlePlan:
    wait: Optional[float]           # seconds until next arrival, or None


class StepPlanner:
    def __init__(self, cfg, queue, pool, max_len: int, batch_cap: int,
                 bucket_floor: int = 8):
        self.cfg = cfg
        self.queue = queue
        self.pool = pool
        self.max_len = max_len
        self.batch_cap = batch_cap
        self.bucket_floor = bucket_floor
        # last sampled token per slot — the only device->host value the
        # loop feeds back (the fetch boundary)
        self.tok_frame = np.zeros((pool.max_slots, 1), np.int32)

    # ------------------------------------------------------------------
    def next_plan(self, now: float):
        admission = self.queue.pop_admission(
            now, self.pool.free_count, self.cfg, self.max_len,
            self.batch_cap, self.bucket_floor)
        if admission is not None:
            return self._prefill_plan(*admission)
        if self.pool.active_count:
            return DecodePlan(self.tok_frame.copy(),
                              self.pool.active_mask())
        nxt = self.queue.next_arrival()
        return IdlePlan(None if nxt is None else max(0.0, nxt - now))

    # ------------------------------------------------------------------
    def _prefill_plan(self, bucket: int, requests: List[object]):
        b = len(requests)
        b_pad = bucket_pow2(b)
        tokens = np.zeros((b_pad, bucket), np.int32)
        slots = np.zeros(b_pad, np.int32)
        lengths = np.zeros(b_pad, np.int32)
        for i, r in enumerate(requests):
            L = len(r.prompt)
            tokens[i, :L] = np.asarray(r.prompt, np.int32)
            slots[i] = self.pool.alloc(r, L)
            lengths[i] = L
        if b_pad > b:                       # pad rows: repeat the last real
            tokens[b:] = tokens[b - 1]
            slots[b:] = slots[b - 1]
            lengths[b:] = lengths[b - 1]
        return PrefillPlan(requests, bucket, tokens, slots, lengths)
