"""Step planner: choose prefill-vs-decode each loop iteration and build
the fixed-shape device frames for the chosen step.

Policy (vLLM-style continuous batching, prefill-priority): whenever free
slots exist and admissible requests are queued (and, for paged pools,
the block arena covers them — SlotPool.admit_checker), the next step is
an admission prefill; otherwise a masked decode step over the pool;
otherwise idle until the next arrival.

Frames are built so that device-facing shapes stay bounded:

* decode is a ``[max_slots]`` mask (plus the ``[max_slots, nbps]`` block
  table in paged mode) — one shape class forever.  The sampled-token
  frame itself is device-resident (pool_ops threads it variable-to-
  variable), so no host token value is needed to dispatch.
* prefill pads the prompt rows to the group's length bucket and the row
  *count* to a power of two by repeating the last real row (a duplicate
  scatter writes identical values — deterministic), so prefill compile
  variants stay O(log slots * log max_len).

Decode frames are **identity-stable**: the same ndarray objects are
re-handed out until pool membership or a token budget changes
(``mark_dirty`` / ``consume``).  The co-execution walker feeds by object
identity, so stable frames make every steady-state decode's argument
check a pointer comparison (executor/steady.py).

``budget`` tracks decode steps still owed per slot.  The pipelined
scheduler harvests tokens one step late, so it cannot see EOS/budget
exhaustion before dispatching the next step; masking a slot out the
moment its budget hits zero bounds the overshoot to the single post-EOS
garbage step the paged layout already reserves room for.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.executor.families import bucket_pow2


@dataclasses.dataclass
class PrefillPlan:
    requests: List[object]          # real (non-pad) rows, admission order
    bucket: int                     # padded prompt length
    tokens: np.ndarray              # [b_pow2, bucket] int32
    slots: np.ndarray               # [b_pow2] int32 (pads repeat the last)
    lengths: np.ndarray             # [b_pow2] int32 true prompt lengths
    bt_rows: Optional[np.ndarray] = None    # [b_pow2, nbps] paged tables


@dataclasses.dataclass
class DecodePlan:
    mask: np.ndarray                # [max_slots] bool rows to step
    bt: Optional[np.ndarray] = None         # [max_slots, nbps] block table


@dataclasses.dataclass
class IdlePlan:
    wait: Optional[float]           # seconds until next arrival, or None


class StepPlanner:
    def __init__(self, cfg, queue, pool, max_len: int, batch_cap: int,
                 bucket_floor: int = 8):
        self.cfg = cfg
        self.queue = queue
        self.pool = pool
        self.max_len = max_len
        self.batch_cap = batch_cap
        self.bucket_floor = bucket_floor
        # decode steps still owed per slot (max_new minus the prefill token)
        self.budget = np.zeros(pool.max_slots, np.int64)
        self._dirty = True
        self._mask_frame = np.zeros(pool.max_slots, bool)
        self._bt_frame: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    def next_plan(self, now: float):
        admission = self.queue.pop_admission(
            now, self.pool.free_count, self.cfg, self.max_len,
            self.batch_cap, self.bucket_floor, self.pool.admit_checker())
        if admission is not None:
            return self._prefill_plan(*admission)
        if self._dirty:
            self._mask_frame = self.pool.active_mask() & (self.budget > 0)
            if self.pool.block_table is not None:
                self._bt_frame = self.pool.block_table.copy()
            self._dirty = False
        if self._mask_frame.any():
            return DecodePlan(self._mask_frame, self._bt_frame)
        nxt = self.queue.next_arrival()
        return IdlePlan(None if nxt is None else max(0.0, nxt - now))

    def consume(self, mask: np.ndarray) -> None:
        """Account one dispatched decode step against the masked slots'
        budgets; an exhausted budget invalidates the decode frames."""
        hit = mask & (self.budget > 0)
        self.budget[hit] -= 1
        if np.any(self.budget[hit] == 0):
            self._dirty = True

    def mark_dirty(self) -> None:
        """Pool membership changed (admission/retirement): rebuild the
        decode frames before the next decode dispatch."""
        self._dirty = True

    # ------------------------------------------------------------------
    def _prefill_plan(self, bucket: int, requests: List[object]):
        b = len(requests)
        b_pad = bucket_pow2(b)
        tokens = np.zeros((b_pad, bucket), np.int32)
        slots = np.zeros(b_pad, np.int32)
        lengths = np.zeros(b_pad, np.int32)
        for i, r in enumerate(requests):
            L = len(r.prompt)
            tokens[i, :L] = np.asarray(r.prompt, np.int32)
            slots[i] = self.pool.alloc(r, L)
            lengths[i] = L
            self.budget[slots[i]] = r.max_new_tokens - 1
        if b_pad > b:                       # pad rows: repeat the last real
            tokens[b:] = tokens[b - 1]
            slots[b:] = slots[b - 1]
            lengths[b:] = lengths[b - 1]
        bt_rows = None
        if self.pool.block_table is not None:
            bt_rows = self.pool.block_table[slots].copy()
        self._dirty = True
        return PrefillPlan(requests, bucket, tokens, slots, lengths, bt_rows)
