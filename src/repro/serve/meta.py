"""Out-of-band static-metadata registry for serving DL ops.

Ops cross the Terra boundary with flat tensor leaves and *hashable*
attributes (node identity, Appendix A); pytree treedefs, step closures
and scatter-axis tables are static per driver but not hashable, so they
live here keyed by an integer id that IS an op attribute.  Entries are
tiny (treedefs + callables) and live for the process: retired drivers'
decode nodes survive in their TraceGraph families as dead branches and
must still resolve their meta id when those graphs regenerate.
"""

from __future__ import annotations

import threading
from typing import Any, Dict


class MetaRegistry:
    def __init__(self):
        self._entries: Dict[int, Any] = {}
        self._lock = threading.Lock()
        self._next = 0

    def register(self, entry: Any) -> int:
        with self._lock:
            mid = self._next
            self._next += 1
            self._entries[mid] = entry
        return mid

    def get(self, mid: int) -> Any:
        return self._entries[mid]
