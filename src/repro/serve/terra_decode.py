"""Serving decode under Terra co-execution.

The serving engine's decode loop is an imperative Python program —
per-request bookkeeping, EOS early-exits, detokenizers — which is exactly
the workload class Terra targets (paper §2: serving is the other
first-class imperative program).  This module routes it through the Terra
runtime instead of a hand-jitted step:

* the whole jitted decode step becomes a **single DL op** (the paper's
  framework-granularity segment model, DESIGN.md §2: "TF ops = graph
  nodes" — op granularity is whatever the op registry says it is),
* model parameters and the KV/recurrent cache live as framework
  :class:`Variable`\\ s, so their buffers stay device-resident in the
  engine's VariableStore and thread segment-to-segment without bouncing
  through Python,
* only the sampled token crosses back per step (an Output Fetching point),
  leaving Python free for retirement bookkeeping while the GraphRunner
  queues the next step.

Pytrees are flattened at the boundary: ``_META`` keeps the (static)
treedefs out of band so the op's attributes stay hashable.
"""

from __future__ import annotations

import os
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.core import function as terra_function
from repro.core import ops as ops_mod
from repro.core.ops import def_op
from repro.core.tensor import Variable
from repro.serve.meta import MetaRegistry
from repro.serve.serve_step import build_decode_step

# meta id -> (params_treedef, cache_treedef, decode_fn)
_META = MetaRegistry()


def _register_meta(params_def, cache_def, decode_fn) -> int:
    return _META.register((params_def, cache_def, decode_fn))


def _decode_impl(*leaves, _meta: int, _n_params: int, _n_cache: int,
                 _has_rng: bool, _has_cross: bool):
    params_def, cache_def, decode_fn = _META.get(_meta)
    params = jax.tree_util.tree_unflatten(params_def, leaves[:_n_params])
    cache = jax.tree_util.tree_unflatten(
        cache_def, leaves[_n_params:_n_params + _n_cache])
    rest = list(leaves[_n_params + _n_cache:])
    tokens = rest.pop(0)
    rng = rest.pop(0) if _has_rng else None
    cross = rest.pop(0) if _has_cross else None
    tok, new_cache = decode_fn(params, cache, tokens, rng=rng,
                               cross_states=cross)
    return (tok,) + tuple(jax.tree_util.tree_leaves(new_cache))


_decode_op = def_op("serve.decode_step", _decode_impl)


class TerraDecoder:
    """Drives lock-step decode through a ``terra.function``.

    One call of the wrapped step function is one Terra iteration: the first
    two steps of the first batch trace, every later step co-executes.  The
    KV cache is rebound (``reset_variable``) from the prefill output at
    each batch start and the *same* cache variables are recycled across
    batches even when the batch size or sequence bucket changes: a new
    shape rebinds the variables to new avals, which selects (or traces) the
    matching shape-class TraceGraph family (DESIGN.md §8).  Each observed
    shape traces and compiles exactly once; alternating batch shapes after
    that flip between sibling graphs with zero retraces and zero
    recompiles.  Fresh variables are only minted when the cache *structure*
    (treedef / leaf count) changes — a different model, not a different
    batch.
    """

    def __init__(self, cfg, params, temperature: float = 0.0,
                 optimize: Optional[str] = None):
        if optimize is None:
            # serving's default is the SAFE pipeline, but the
            # $TERRA_OPTIMIZE kill-switch (e.g. "none") must stay able to
            # disable passes here too
            optimize = os.environ.get("TERRA_OPTIMIZE") or "safe"
        self.cfg = cfg
        self.temperature = temperature
        self._decode_fn = build_decode_step(cfg, temperature)
        leaves, self._params_def = jax.tree_util.tree_flatten(params)
        self._param_vars: List[Variable] = [
            Variable(l, name=f"srv.p{i}") for i, l in enumerate(leaves)]
        self._cache_vars: Optional[List[Variable]] = None
        self._cache_def = None
        self._meta: Optional[int] = None
        # serving pins the SAFE pipeline explicitly (DESIGN.md §10): the
        # decode step's token feed changes every call, so constant-feed
        # folding must never bake one batch's tokens into the graph —
        # "safe" excludes the fold pass while keeping DCE/CSE/coalescing
        self._tf = terra_function(self._step, optimize=optimize)

    # ------------------------------------------------------------------
    @property
    def phase(self) -> str:
        return self._tf.phase

    @property
    def stats(self):
        return self._tf.stats

    # ------------------------------------------------------------------
    def begin_batch(self, cache) -> None:
        """Bind the prefilled cache into the engine's variable store.

        Shape changes (batch size, sequence bucket) REUSE the existing
        cache variables: ``reset_variable`` rebinds them to the new avals
        and the engine's shape-class signature flips to the matching
        TraceGraph family — no divergence, no retrace of known shapes.
        Only a cache-structure change (different treedef) mints fresh
        variables, retiring the old set so its buffers don't stay pinned
        in the device-resident store forever."""
        leaves, cache_def = jax.tree_util.tree_flatten(cache)
        leaves = [jnp.asarray(l) for l in leaves]
        reuse = (self._cache_vars is not None
                 and cache_def == self._cache_def
                 and len(leaves) == len(self._cache_vars))
        eng = self._tf.engine
        if reuse:
            for var, leaf in zip(self._cache_vars, leaves):
                eng.reset_variable(var, leaf)
        else:
            if self._cache_vars is not None:
                for var in self._cache_vars:
                    eng.release_variable(var)
            # _META entries stay: retired decode nodes survive in their
            # TraceGraph families as dead branches and still trace through
            # their meta id (the entries are treedefs — tiny)
            self._cache_vars = [Variable(l, name=f"srv.c{i}")
                                for i, l in enumerate(leaves)]
            self._cache_def = cache_def
            self._meta = _register_meta(self._params_def, cache_def,
                                        self._decode_fn)

    # ------------------------------------------------------------------
    def step(self, tokens, cross_states=None):
        """One decode step; returns a (possibly placeholder) token tensor."""
        return self._tf(jnp.asarray(tokens), cross_states)

    def _step(self, tokens, cross_states):
        args = [v.read() for v in self._param_vars]
        args += [v.read() for v in self._cache_vars]
        args.append(tokens)
        has_rng = self.temperature > 0.0
        if has_rng:
            args.append(ops_mod._next_key())    # iteration-stable key feed
        has_cross = cross_states is not None
        if has_cross:
            args.append(cross_states)
        outs = _decode_op(*args, _meta=self._meta,
                          _n_params=len(self._param_vars),
                          _n_cache=len(self._cache_vars),
                          _has_rng=has_rng, _has_cross=has_cross)
        tok, cache_leaves = outs[0], outs[1:]
        for var, leaf in zip(self._cache_vars, cache_leaves):
            var.assign(leaf)
        return tok

    # ------------------------------------------------------------------
    def wait(self):
        self._tf.wait()

    def close(self):
        self._tf.close()
