"""Pallas TPU paged-attention decode kernel (DESIGN.md §12).

Single-token decode over a paged KV cache: K/V live in a flat arena of
``[num_blocks, bs, Hkv, D]`` fixed-size blocks and each batch row owns a
block table ``bt[b, j] -> arena block id``.  The block table and the
per-row valid lengths ride in as **scalar-prefetch** operands
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps can
dereference the table *before* the kernel body runs — each grid step DMAs
exactly the one arena block the row actually owns, never the dense
``[B, max_len]`` gather the reference path materializes.

Grid = (B, nbps) with the block axis innermost; Pallas TPU grids execute
sequentially, so the online-softmax accumulator in VMEM scratch carries
across a row's blocks and is finalized on the last one (same structure
as flash_attention.py).  Rows shorter than ``nbps`` blocks point their
tail table entries at the trash block 0; those positions are masked by
the valid-length mask, so the garbage they DMA never reaches the output.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(bt_ref, valid_ref, q_ref, k_ref, v_ref, o_ref,
                  acc_ref, m_ref, l_ref, *, bs: int, nbps: int, Hkv: int,
                  G: int, D: int, scale: float, window: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # [Hq, D]
    qr = q.reshape(Hkv, G, D)
    k = k_ref[0].astype(jnp.float32)                     # [bs, Hkv, D]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.einsum("hgd,khd->hgk", qr, k,
                   preferred_element_type=jnp.float32)   # [Hkv, G, bs]

    vl = valid_ref[b]
    pos = j * bs + jax.lax.broadcasted_iota(jnp.int32, (G, bs), 1)
    mask = pos < vl
    if window:
        mask &= pos >= vl - window
    s = jnp.where(mask[None], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("hgk,khd->hgd", p, v,
                                 preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(j == nbps - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[..., None]).reshape(
            Hkv * G, D).astype(o_ref.dtype)


def paged_attention(q, kp, vp, bt, valid, *, window: int = 0,
                    interpret: bool = False):
    """q: [B,1,Hq,D]; kp/vp: [num_blocks,bs,Hkv,D]; bt: [B,nbps] int;
    valid: [B] int valid lengths.  Returns [B,1,Hq,D]."""
    B, S, Hq, D = q.shape
    assert S == 1, "paged attention is a single-token decode kernel"
    bs, Hkv = kp.shape[1], kp.shape[2]
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    G = Hq // Hkv
    nbps = bt.shape[1]

    kernel = functools.partial(
        _paged_kernel, bs=bs, nbps=nbps, Hkv=Hkv, G=G, D=D,
        scale=D ** -0.5, window=window)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nbps),
        in_specs=[
            pl.BlockSpec((1, 1, Hq, D),
                         lambda b, j, bt, vl: (b, 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, D),
                         lambda b, j, bt, vl: (bt[b, j], 0, 0, 0)),
            pl.BlockSpec((1, bs, Hkv, D),
                         lambda b, j, bt, vl: (bt[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Hq, D),
                               lambda b, j, bt, vl: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hkv, G, D), jnp.float32),   # output accumulator
            pltpu.VMEM((Hkv, G), jnp.float32),      # running max
            pltpu.VMEM((Hkv, G), jnp.float32),      # running denominator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, 1, Hq, D), q.dtype),
        interpret=interpret,
    )(bt.astype(jnp.int32), valid.astype(jnp.int32), q, kp, vp)
