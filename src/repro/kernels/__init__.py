"""Pallas TPU kernels for the substrate's compute hot-spots.

The paper (a runtime/scheduling contribution) has no kernel of its own
(DESIGN.md §2); these cover the model substrate:

    flash_attention — causal/SWA/GQA online-softmax attention,
                      BlockSpec VMEM tiling, f32 scratch accumulators
    ssd_scan        — Mamba-2 SSD chunked scan with VMEM-resident state
    rmsnorm         — fused single-pass RMSNorm

ops.py exposes jit'd wrappers with interpret-mode CPU fallback;
ref.py holds the pure-jnp oracles used by tests/test_kernels.py.
"""

from repro.kernels.ops import flash_attention, rmsnorm, ssd_scan

__all__ = ["flash_attention", "rmsnorm", "ssd_scan"]
