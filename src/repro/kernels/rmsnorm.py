"""Pallas TPU fused RMSNorm: one HBM read, one write per row block.

Trivially memory-bound; fusing the square-mean, rsqrt and scale into one
VMEM-resident pass removes the extra round trips the unfused XLA lowering
can incur around the reduction."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(jnp.square(x), -1, keepdims=True) + eps)
    o_ref[...] = (y * (1.0 + g)).astype(o_ref.dtype)


def rmsnorm(x, g, *, eps: float = 1e-6, row_block: int = 256,
            interpret: bool = False):
    """x: [..., d]; g: [d]."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    xr = x.reshape(rows, d)
    rb = min(row_block, rows)
    while rows % rb:
        rb //= 2
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(xr, g)
    return out.reshape(orig_shape)
