"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid = (B, H, nc) with the chunk axis innermost: the [P, N] recurrent state
lives in f32 VMEM scratch and carries across sequential chunk steps; each
step performs the intra-chunk quadratic form and the state update as dense
MXU matmuls.  This fuses what the XLA path (models/ssm.ssd_chunked)
expresses as separate einsums + a lax.scan, keeping the decay matrices and
intermediate products in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                Q: int, P: int, N: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)         # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)       # [Q]
    A = a_ref[0].astype(jnp.float32)            # scalar decay rate (<0)
    Bm = b_ref[0].astype(jnp.float32)           # [Q, N]
    Cm = c_ref[0].astype(jnp.float32)           # [Q, N]

    a = dt * A                                   # [Q] log-decay per step
    cs = jnp.cumsum(a)                           # inclusive
    # L[i, j] = exp(sum_{j+1..i} a) for i >= j
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # [Q,Q]
    M = scores * L
    dx = x * dt[:, None]                          # [Q, P]
    y_diag = jax.lax.dot_general(M, dx, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)

    # contribution of the carried state: y_off[i] = exp(cs_i) * C_i h_prev
    h_prev = h_ref[...]                           # [P, N]
    ch = jax.lax.dot_general(Cm, h_prev, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, P]
    y_ref[0, 0] = (y_diag + jnp.exp(cs)[:, None] * ch).astype(y_ref.dtype)

    # state update: h_new = exp(sum a) h_prev + sum_i exp(cs_Q - cs_i) dt_i B_i x_i^T
    decay_tot = jnp.exp(cs[Q - 1])
    w = jnp.exp(cs[Q - 1] - cs)[:, None] * dx     # [Q, P]
    upd = jax.lax.dot_general(w, Bm, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # [P, N]
    h_ref[...] = h_prev * decay_tot + upd


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128,
             interpret: bool = False):
    """x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm/Cm: [B,S,N] -> y [B,S,H,P]."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nc = S // Q

    # layout: per (batch, head) streams
    xt = x.transpose(0, 2, 1, 3)                  # [B,H,S,P]
    dtt = dt.transpose(0, 2, 1)                   # [B,H,S]

    kernel = functools.partial(_ssd_kernel, Q=Q, P=P, N=N)
    yt = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, Bm, Cm)
    return yt.transpose(0, 2, 1, 3)
