"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """q: [B,H,Sq,D]; k/v: [B,Hkv,Skv,D]; GQA by head grouping.
    Returns [B,H,Sq,D] (f32 accumulation, cast back to q.dtype)."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    qr = q.reshape(B, Hkv, G, Sq, D).astype(jnp.float32)
    kr = k.astype(jnp.float32)
    vr = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr, kr) * (D ** -0.5)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vr)
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def ref_paged_attention(q, kp, vp, bt, valid, *, window: int = 0):
    """Paged decode oracle: q [B,1,Hq,D]; kp/vp [num_blocks,bs,Hkv,D];
    bt [B,nbps]; valid [B].  Gathers each row's blocks back into logical
    order and runs a masked dense softmax — the ground truth the kernel's
    block-streamed online softmax must match."""
    B, _, Hq, D = q.shape
    bs, Hkv = kp.shape[1], kp.shape[2]
    G = Hq // Hkv
    k = kp[bt].reshape(B, -1, Hkv, D).astype(jnp.float32)   # [B,Smax,Hkv,D]
    v = vp[bt].reshape(B, -1, Hkv, D).astype(jnp.float32)
    qr = q.reshape(B, Hkv, G, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qr, k)
    pos = jnp.arange(k.shape[1])[None, :]
    ok = pos < valid[:, None]
    if window:
        ok &= pos >= valid[:, None] - window
    s = jnp.where(ok[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


def ref_ssd(x, dt, A, Bm, Cm):
    """Sequential SSD recurrence (the literal state-space semantics).

    x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm/Cm: [B,S,N] -> y [B,S,H,P]."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]

    def step(h, inp):
        xt, dtt, bt, ct = inp            # [B,H,P], [B,H], [B,N], [B,N]
        da = jnp.exp(dtt * A[None, :])   # [B,H]
        upd = jnp.einsum("bn,bh,bhp->bhpn", bt, dtt, xt)
        h = h * da[:, :, None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", ct, h)
        return h, y

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = (x.astype(jnp.float32).transpose(1, 0, 2, 3),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          Bm.astype(jnp.float32).transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def ref_rmsnorm(x, g, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + eps)
    return (y * (1.0 + g.astype(jnp.float32))).astype(x.dtype)
